"""Pure-JAX AdamW with warmup-cosine schedule and global-norm clipping.

Optimizer state shards exactly like the params (ZeRO-1 via the same
PartitionSpecs), which is what makes the 110B cells fit 16 GB/chip.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(oc: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / max(oc.warmup_steps, 1)
    prog = jnp.clip((step - oc.warmup_steps)
                    / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return oc.lr * jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "count": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw_update(oc: OptConfig, params, grads, opt_state):
    count = opt_state["count"] + 1
    lr = schedule(oc, count)
    grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    b1, b2 = oc.b1, oc.b2

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / (1 - b1 ** count.astype(jnp.float32))
        vhat = v_new / (1 - b2 ** count.astype(jnp.float32))
        step_ = mhat / (jnp.sqrt(vhat) + oc.eps)
        decay = oc.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) - lr * (step_ + decay)
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    params_new = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return params_new, {"m": m_new, "v": v_new, "count": count}, gnorm
