"""Attention: GQA with RoPE / biases / qk-norm / sliding-window / local-block.

Three execution paths, all pure JAX (the Pallas kernel in
``repro.kernels.flash_attention`` is the TPU fast path; this module is the
portable path used for CPU smoke tests and for the dry-run lowering):

* ``_causal_blocked``  — full causal attention, Python-unrolled over q blocks,
  ``lax.scan`` over kv chunks with online softmax. Never materializes S×S;
  computes only the lower-triangular chunk pairs (causal-optimal FLOPs).
* ``_windowed_blocked`` — local / sliding-window attention: each q block of
  width W attends to its own and the previous block (2W window, masked down
  to W). FLOPs are O(S·W).
* ``_decode``          — single-token query against a KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.layers import Init, accum_dtype, compute_dtype, dense, rms_norm
from repro.nn.rope import apply_rope

NEG_INF = -1e30


def window_for(kind, cfg):
    if kind == "local":
        return cfg.local_window
    if kind == "swa":
        return cfg.swa_window
    return None  # attn / global: full causal


def init_attn(key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "wq": Init(ks[0], (d, cfg.q_dim), cfg.param_dtype),
        "wk": Init(ks[1], (d, cfg.kv_dim), cfg.param_dtype),
        "wv": Init(ks[2], (d, cfg.kv_dim), cfg.param_dtype),
        "wo": Init(ks[3], (cfg.q_dim, d), cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), cfg.param_dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), cfg.param_dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), cfg.param_dtype)
    if cfg.qk_norm:
        hd = cfg.resolved_head_dim
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(x, p["wq"], p.get("bq")).reshape(B, S, cfg.n_heads, hd)
    k = dense(x, p["wk"], p.get("bk")).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense(x, p["wv"], p.get("bv")).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.rope:
        q = apply_rope(q.swapaxes(1, 2), positions[:, None, :],
                       cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), positions[:, None, :],
                       cfg.rope_theta).swapaxes(1, 2)
    # (B, H, S, hd)
    return q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2)


def _gqa_shape(q, n_kv):
    """(B, Hq, S, hd) -> (B, Hkv, G, S, hd)."""
    B, Hq, S, hd = q.shape
    return q.reshape(B, n_kv, Hq // n_kv, S, hd)


def _online_merge(m, l, acc, scores, v_chunk):
    """One online-softmax update.
    scores: (B,Hkv,G,Sq,C) f32; v_chunk: (B,Hkv,C,hd)."""
    m_new = jnp.maximum(m, scores.max(axis=-1))
    p = jnp.exp(scores - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bhgqc,bhcd->bhgqd", p.astype(v_chunk.dtype), v_chunk,
        preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def _causal_blocked(q, k, v, cfg):
    """Full causal. q: (B,Hkv,G,S,hd); k,v: (B,Hkv,S,hd)."""
    B, Hkv, G, S, hd = q.shape
    C = min(cfg.kv_chunk, S)
    nq = S // C
    scale = hd ** -0.5
    outs = []
    for i in range(nq):  # static unroll over q blocks: causal-optimal FLOPs
        qi = q[:, :, :, i * C:(i + 1) * C]                      # (B,Hkv,G,C,hd)
        kv_len = (i + 1) * C
        kb = k[:, :, :kv_len].reshape(B, Hkv, i + 1, C, hd)
        vb = v[:, :, :kv_len].reshape(B, Hkv, i + 1, C, hd)
        m0 = jnp.full((B, Hkv, G, C), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, C), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, C, hd), jnp.float32)
        pos_q = i * C + jnp.arange(C)

        def body(carry, inp):
            m, l, acc = carry
            kj, vj, j = inp
            scores = jnp.einsum("bhgqd,bhcd->bhgqc", qi, kj,
                                preferred_element_type=jnp.float32) * scale
            pos_k = j * C + jnp.arange(C)
            mask = pos_k[None, :] <= pos_q[:, None]
            scores = jnp.where(mask, scores, NEG_INF)
            return _online_merge(m, l, acc, scores, vj), None

        js = jnp.arange(i + 1)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (kb.swapaxes(0, 2).swapaxes(1, 2),
                                 vb.swapaxes(0, 2).swapaxes(1, 2), js))
        outs.append(acc / jnp.maximum(l[..., None], 1e-30))
    return jnp.concatenate(outs, axis=3).astype(q.dtype)   # (B,Hkv,G,S,hd)


def _windowed_blocked(q, k, v, window, cfg):
    """Local/SWA attention: q block i attends kv blocks {i-1, i}."""
    B, Hkv, G, S, hd = q.shape
    W = min(window, S)
    if S % W != 0:   # fall back (smoke-test sizes)
        return _causal_blocked(q, k, v, cfg)
    nb = S // W
    scale = hd ** -0.5
    qb = q.reshape(B, Hkv, G, nb, W, hd)
    kb = k.reshape(B, Hkv, nb, W, hd)
    vb = v.reshape(B, Hkv, nb, W, hd)
    zeros = jnp.zeros_like(kb[:, :, :1])
    k2 = jnp.concatenate([jnp.concatenate([zeros, kb[:, :, :-1]], axis=2), kb], axis=3)
    v2 = jnp.concatenate([jnp.concatenate([zeros, vb[:, :, :-1]], axis=2), vb], axis=3)
    scores = jnp.einsum("bhgnqd,bhnkd->bhgnqk", qb, k2,
                        preferred_element_type=jnp.float32) * scale
    wq = jnp.arange(W)[:, None]          # in-block q offset
    wk = jnp.arange(2 * W)[None, :] - W  # kv offset relative to block start
    blk = jnp.arange(nb)[:, None, None]
    pos_q = blk * W + wq[None]
    pos_k = blk * W + wk[None]
    mask = (pos_k <= pos_q) & (pos_q - pos_k < W) & (pos_k >= 0)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgnqk,bhnkd->bhgnqd", probs.astype(v2.dtype), v2,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hkv, G, S, hd).astype(q.dtype)


def attn_forward(p, x, cfg, kind, positions, return_kv=False):
    """Training / prefill path. x: (B,S,D); positions: (B,S) int32."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    qg = _gqa_shape(q, cfg.n_kv_heads)
    window = window_for(kind, cfg)
    if window is not None and window < x.shape[1]:
        out = _windowed_blocked(qg, k, v, window, cfg)
    else:
        out = _causal_blocked(qg, k, v, cfg)
    B, S = x.shape[:2]
    out = out.reshape(B, cfg.n_heads, S, -1).swapaxes(1, 2).reshape(B, S, cfg.q_dim)
    y = dense(out, p["wo"], accum=accum_dtype(cfg))
    if return_kv:
        cdt = compute_dtype(jnp.bfloat16)
        return y, {"k": k.astype(cdt), "v": v.astype(cdt)}
    return y


def init_kv_cache(cfg, batch, capacity, dtype=None):
    dtype = dtype or compute_dtype(jnp.bfloat16)
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, capacity, hd), dtype),
        "v": jnp.zeros((batch, cfg.n_kv_heads, capacity, hd), dtype),
    }


def attn_decode(p, x, cfg, kind, cache, pos):
    """Single-token decode. x: (B,1,D); cache k/v: (B,Hkv,S,hd); pos: scalar."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)       # (B,H,1,hd)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, 0, pos, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, 0, pos, 0))
    qg = _gqa_shape(q, cfg.n_kv_heads)                 # (B,Hkv,G,1,hd)
    scores = jnp.einsum("bhgqd,bhcd->bhgqc", qg, ck,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    idx = jnp.arange(ck.shape[2])
    mask = idx <= pos
    window = window_for(kind, cfg)
    if window is not None:
        mask = mask & (pos - idx < window)
    scores = jnp.where(mask[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqc,bhcd->bhgqd", probs.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, cfg.n_heads, 1, hd).swapaxes(1, 2).reshape(B, 1, cfg.q_dim)
    y = dense(out.astype(x.dtype), p["wo"], accum=accum_dtype(cfg))
    return y, {"k": ck, "v": cv}
