"""Access-pattern-adaptive re-sharding tests.

A ``RoutingPlan`` split migrates a hot shard's half-range through the
ordinary ingest/seal machinery; the stitched store must stay
observationally identical to the loop-based single-store oracle across
any sequence of mid-stream splits — byte-identical CSRs at every version
(including pre-cutover ones re-queried afterwards), identical
k-hop/reachability/PageRank answers served by ``GraphQueryServer``
before, during, and after the cutover — and caches keyed by retired
routing plans must be dropped by the GC ladder, not leaked.

The hypothesis property test (routing determinism under arbitrary split
sequences) self-skips when hypothesis is absent, like
``tests/test_core_properties.py``; a deterministic variant always runs.
"""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:        # pragma: no cover - exercised in offline envs
    class _StrategyStub:
        """Stands in for hypothesis.strategies at decoration time only."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn

from repro.core.replica import ShardPlanner
from repro.core.versioned import Version
from repro.graph.dyngraph import (MutationBatch, synthesize_churn_stream,
                                  synthesize_skewed_stream)
from repro.graph.query import (KHop, PageRankQuery, Reachability,
                               SnapshotQueryEngine)
from repro.graph.reference import LoopDynamicGraph
from repro.graph.sharded import (AccessStats, RoutingPlan,
                                 ShardedDynamicGraph, _mix64)
from repro.launch.serve_graph import GraphQueryServer


def _assert_stitched_equal(sg: ShardedDynamicGraph, ref: LoopDynamicGraph,
                           version: Version) -> None:
    view = sg.join_view(version)
    offsets, src, dst, out_deg, in_deg = ref.join_view_arrays(version)
    np.testing.assert_array_equal(np.asarray(view.offsets), offsets)
    np.testing.assert_array_equal(np.asarray(view.src), src)
    np.testing.assert_array_equal(np.asarray(view.dst), dst)
    np.testing.assert_array_equal(view.np_out_deg, out_deg)
    np.testing.assert_array_equal(view.np_in_deg, in_deg)


def _oracle_view(ref: LoopDynamicGraph, version: Version):
    from repro.graph.dyngraph import build_join_view
    offsets, src, dst, out_deg, in_deg = ref.join_view_arrays(version)
    keys = (dst.astype(np.int64) << 32) | src.astype(np.int64)
    return build_join_view(version, ref.n_max, keys, src, dst,
                           in_deg, out_deg)


# ------------------------------------------------- split/oracle equivalence
def _run_midstream_split(n_shards, delete_frac, readd_frac,
                         parallel_apply=0):
    n, epochs, adds = 48, 8, 60
    batches = synthesize_churn_stream(n, epochs, adds, seed=17,
                                      delete_frac=delete_frac,
                                      readd_frac=readd_frac)
    sg = ShardedDynamicGraph(n_shards, n, 8192,
                             parallel_apply=parallel_apply)
    ref = LoopDynamicGraph(n, 8192)
    for e, b in enumerate(batches):
        sg.apply(b)
        ref.apply(b)
        if e == 2:
            sg.split_shard(int(np.argmax(sg.shard_edge_counts())))
        elif e == 5:
            sg.split_shard(int(np.argmax(sg.shard_edge_counts())))
    assert sg.n_shards == n_shards + 2
    assert len(sg.migrations) == 2
    for e in range(epochs):
        _assert_stitched_equal(sg, ref, Version(e, 0))
    np.testing.assert_array_equal(sg.v_created, ref.v_created)
    np.testing.assert_array_equal(sg.v_type, ref.v_type)
    sg.shutdown()


@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("delete_frac,readd_frac", [
    (0.0, 0.0),     # add-heavy
    (0.35, 0.4),    # churny: deletes + re-adds cross the migrated range
])
def test_midstream_split_matches_oracle(n_shards, delete_frac, readd_frac):
    """Byte-identical stitched CSRs at EVERY version across two mid-stream
    splits — including pre-cutover snapshots re-queried afterwards, whose
    rows must keep resolving from the migration-tombstoned source rows."""
    _run_midstream_split(n_shards, delete_frac, readd_frac)


@pytest.mark.threaded
@pytest.mark.parametrize("n_shards", [2, 4])
def test_midstream_split_matches_oracle_parallel(n_shards):
    """The re-sharding cutover with the parallel apply plane enabled: the
    migration slices and the cutover-version user batch apply inside
    concurrently-running shard seals and every snapshot must still stitch
    byte-identically (the acceptance bar for threaded equivalence)."""
    _run_midstream_split(n_shards, 0.35, 0.4, parallel_apply=n_shards)


@pytest.mark.parametrize("n_shards", [2, 4])
def test_server_answers_identical_across_cutover(n_shards):
    """GraphQueryServer answers (k-hop, reachability, warm-chained
    PageRank) are identical to an oracle engine's before, during (split
    activated but cutover epoch not yet sealed), and after a split."""
    n, epochs = 48, 6
    batches = synthesize_skewed_stream(n, epochs, 80, seed=31,
                                       delete_frac=0.2)
    sg = ShardedDynamicGraph(n_shards, n, 16384)
    server = GraphQueryServer(sg, tol=1e-10, max_iter=200)
    ref = LoopDynamicGraph(n, 16384)
    oracle = SnapshotQueryEngine(tol=1e-10, max_iter=200)

    def check_window():
        qs = [KHop(3, 2), KHop(17, 2), Reachability(1, 40, 4),
              PageRankQuery()]
        for q in qs:
            server.submit(q)
        results = server.flush()
        v = results[0].version
        expect = oracle.execute(_oracle_view(ref, v), qs)
        for r, exp in zip(results, expect, strict=True):
            np.testing.assert_array_equal(np.asarray(r.value),
                                          np.asarray(exp))
        return v

    for e, b in enumerate(batches):
        server.step(b)
        ref.apply(b)
        v = check_window()                      # before / after the splits
        assert v == b.version
        if e == 2:
            hot = int(np.argmax(sg.shard_edge_counts()))
            sg.split_shard(hot)
            # DURING: plan swapped, migration dispatched, cutover epoch not
            # yet sealed — answers still come from the pre-split snapshot
            assert check_window() == b.version
    assert sg.n_shards == n_shards + 1
    assert sg.migrations[0]["migrated_edges"] > 0


def test_migration_merges_with_user_batch_at_cutover_version():
    """Hand-built protocol check: key 3 migrates on a shard-1 split (its
    refinement bit is 1), key 5 stays. A user batch at exactly the cutover
    version ``(activation, 0)`` merges with the migration slice in arrival
    order, duplicate migrated edges keep LIFO delete semantics, and
    deletes of migrated edges route to the target shard."""
    sg = ShardedDynamicGraph(2, 16, 64)
    ref = LoopDynamicGraph(16, 64)
    b0 = MutationBatch(Version(0, 0),
                       add_src=np.array([0, 1, 0, 2], np.int32),
                       add_dst=np.array([3, 3, 3, 5], np.int32))
    sg.apply(b0)
    ref.apply(b0)
    pre_counts = sg.shard_edge_counts()
    summary = sg.split_shard(1)
    assert summary["activation_epoch"] == 1
    # edges to dst 3 migrate ((0,3) twice + (1,3)); (2,5) stays on shard 1
    assert summary["migrated_edges"] == 3
    # the migration is dispatched, NOT applied: shard stores are untouched
    # until the cutover epoch seals, and the pre-split snapshot still
    # stitches byte-identically under the already-swapped plan
    assert sg.shard_edge_counts() == pre_counts + [0]
    assert sg.latest_sealed() == Version(0, 0)
    _assert_stitched_equal(sg, ref, Version(0, 0))
    # user batch at the cutover version: re-adds (0,3) then deletes it
    # twice — the second delete must pop a MIGRATED duplicate on the target
    b1 = MutationBatch(Version(1, 0),
                       add_src=np.array([0, 7], np.int32),
                       add_dst=np.array([3, 5], np.int32),
                       del_src=np.array([0, 0], np.int32),
                       del_dst=np.array([3, 3], np.int32))
    sg.apply(b1)
    ref.apply(b1)
    for v in (Version(0, 0), Version(1, 0)):
        _assert_stitched_equal(sg, ref, v)
    # migrated rows really applied: target shard now holds dst-3 rows
    assert sg.shards[2].n_edges > 0
    # ...and later deletes of a migrated key route to the target and work
    b2 = MutationBatch(Version(2, 0),
                       del_src=np.array([1], np.int32),
                       del_dst=np.array([3], np.int32))
    sg.apply(b2)
    ref.apply(b2)
    _assert_stitched_equal(sg, ref, Version(2, 0))


def test_split_preconditions():
    """Splits require plan-based routing and a quiescent store; a custom
    route cannot carry a planner at all."""
    sg = ShardedDynamicGraph(2, 8, 64)
    sg.ingest(MutationBatch(Version(0, 0),
                            add_src=np.array([0], np.int32),
                            add_dst=np.array([1], np.int32)))
    assert not sg.is_quiescent()          # ingested epoch not sealed
    with pytest.raises(RuntimeError, match="quiescent"):
        sg.split_shard(0)
    assert sg.maybe_reshard() is None     # no planner: never splits
    sg.seal_epoch(0)
    assert sg.is_quiescent()
    sg.split_shard(0)                     # quiescent: fine
    # straggler-paced sealing is also non-quiescent territory
    sg.ingest(MutationBatch(Version(1, 0),
                            add_src=np.array([2], np.int32),
                            add_dst=np.array([3], np.int32)))
    sg.seal_shard(1, 1)
    assert not sg.is_quiescent()
    # regression: a prior split's migration slices sit PENDING until the
    # cutover epoch seals — a second split reading the source shard before
    # then would re-migrate rows the first move already claimed, so the
    # quiescence gate must refuse back-to-back splits
    sg2 = ShardedDynamicGraph(2, 16, 64)
    sg2.apply(MutationBatch(Version(0, 0),
                            add_src=np.array([0, 1, 0], np.int32),
                            add_dst=np.array([3, 3, 5], np.int32)))
    assert sg2.split_shard(1)["migrated_edges"] > 0
    assert not sg2.is_quiescent()
    with pytest.raises(RuntimeError, match="quiescent"):
        sg2.split_shard(1)
    ref2 = LoopDynamicGraph(16, 64)
    ref2.apply(MutationBatch(Version(0, 0),
                             add_src=np.array([0, 1, 0], np.int32),
                             add_dst=np.array([3, 3, 5], np.int32)))
    sg2.apply(MutationBatch(Version(1, 0),
                            add_src=np.array([2], np.int32),
                            add_dst=np.array([7], np.int32)))
    ref2.apply(MutationBatch(Version(1, 0),
                             add_src=np.array([2], np.int32),
                             add_dst=np.array([7], np.int32)))
    sg2.split_shard(1)                    # cutover sealed: fine again
    sg2.apply(MutationBatch(Version(2, 0),
                            add_src=np.array([4], np.int32),
                            add_dst=np.array([9], np.int32)))
    ref2.apply(MutationBatch(Version(2, 0),
                             add_src=np.array([4], np.int32),
                             add_dst=np.array([9], np.int32)))
    for e in range(3):
        _assert_stitched_equal(sg2, ref2, Version(e, 0))
    custom = ShardedDynamicGraph(2, 8, 64, route=lambda k: k % 2)
    with pytest.raises(ValueError, match="plan-based"):
        custom.split_shard(0)
    assert custom.maybe_reshard() is None
    with pytest.raises(ValueError, match="custom route"):
        ShardedDynamicGraph(2, 8, 64, route=lambda k: 0,
                            planner=ShardPlanner())


# ------------------------------------------------------------ GC regression
def test_split_drops_retired_plan_cache_entries():
    """Regression: after a split, cached artifacts keyed by the retired
    routing plan — stitched views, the involved shards' per-shard views,
    and PageRank ranks — must be dropped by the GC instead of being
    pinned by the version ladder; uninvolved shards keep their ladders,
    and retired versions stay addressable (rebuilt byte-identically)."""
    batches = synthesize_skewed_stream(40, 6, 60, seed=7, delete_frac=0.2)
    sg = ShardedDynamicGraph(2, 40, 8192)
    ref = LoopDynamicGraph(40, 8192)
    engine = SnapshotQueryEngine(tol=1e-8, max_iter=100)
    for b in batches[:4]:
        sg.apply(b)
        ref.apply(b)
        engine.pagerank(sg.join_view(b.version))   # per-shard+stitched+ranks
    assert sg.plan_floor() == 0                    # plan 0: nothing retired
    hot = int(np.argmax(sg.shard_edge_counts()))
    summary = sg.split_shard(hot)
    floor = sg.plan_floor()
    assert floor == Version(4, 0).pack()
    # BEFORE any post-cutover entry exists, the retired entries must keep
    # serving: a large-budget GC drops nothing
    assert sg.gc_views(keep_latest=8) == 0
    assert engine.gc(8, retire_below=floor) == 0
    assert Version(3, 0).pack() in sg._views
    # seal the cutover epoch, cache post-cutover entries, GC again
    sg.apply(batches[4])
    ref.apply(batches[4])
    engine.pagerank(sg.join_view(Version(4, 0)))
    assert sg.gc_views(keep_latest=8) > 0
    assert engine.gc(8, retire_below=floor) > 0
    assert all(k >= floor for k in sg._views)
    for i in (summary["source"], summary["target"]):
        assert all(k >= floor for k in sg.shards[i]._views)
    assert all(k >= floor for k in engine._rank_cache)
    # the uninvolved shard's ladder is untouched (no plan-wide wipe)
    other = next(i for i in range(2) if i != hot)
    assert any(k < floor for k in sg.shards[other]._views)
    # retired snapshots remain addressable and byte-identical
    for e in range(5):
        _assert_stitched_equal(sg, ref, Version(e, 0))
    # the rank warm-start chain crossed the cutover (no cold restart)
    assert engine.rank_cold_starts == 1


def test_gc_after_split_unpins_shard_batch_logs():
    """Regression: re-sharding GC must bound the involved shards' batch
    logs at the retired floor even while no post-cutover view is cached
    yet (a stalled serving path) — previously the retired views pinned
    each shard's log via its min cached view, so the log grew with the
    stream. Views stay byte-identical throughout."""
    batches = synthesize_skewed_stream(40, 6, 60, seed=9, delete_frac=0.2)
    sg = ShardedDynamicGraph(2, 40, 8192)
    ref = LoopDynamicGraph(40, 8192)
    for b in batches[:4]:
        sg.apply(b)
        ref.apply(b)
        sg.shard_views(b.version)          # per-shard ladders + logs
    summary = sg.split_shard(int(np.argmax(sg.shard_edge_counts())))
    sg.apply(batches[4])                   # seals the cutover epoch
    ref.apply(batches[4])
    floor = sg.plan_floor()
    # no post-cutover views cached yet: retired views keep serving, but
    # the involved shards' logs must still drop below the retired floor
    sg.gc_views(keep_latest=8)
    for i in (summary["source"], summary["target"]):
        shard = sg.shards[i]
        assert all(r.version >= floor for r in shard._batch_log), \
            f"shard {i} log pinned below the retired floor"
        assert shard._log_floor >= floor - 1
    # the uninvolved shard's ladder/log keep their pre-split reach
    other = next(i for i in range(2) if i != summary["source"])
    assert any(k < floor for k in sg.shards[other]._views)
    sg.apply(batches[5])
    ref.apply(batches[5])
    for e in range(6):
        _assert_stitched_equal(sg, ref, Version(e, 0))


def test_gc_floor_is_per_shard_not_global():
    """Regression: a LATER split of shard B must not wipe shard A's
    still-valid ladder views from after A's own (older) migration — each
    involved shard's retirement floor is its own last migration, not the
    active plan's activation."""
    batches = synthesize_skewed_stream(40, 9, 60, seed=19, delete_frac=0.1)
    sg = ShardedDynamicGraph(2, 40, 8192)
    for e, b in enumerate(batches):
        sg.apply(b)
        if e == 1:
            first = sg.split_shard(0)          # shard 0: activation 2
        elif e == 6:
            second = sg.split_shard(1)         # shard 1: activation 7
        sg.join_view(b.version)                # populate per-shard caches
    floor_a = Version(first["activation_epoch"], 0).pack()
    floor_b = Version(second["activation_epoch"], 0).pack()
    sg.gc_views(keep_latest=16)                # big budget: only retirement
    # shard 0 keeps views between ITS split and shard 1's later split...
    kept_a = sorted(sg.shards[0]._views)
    assert any(floor_a <= k < floor_b for k in kept_a)
    # ...but dropped its pre-own-split entries
    assert all(k >= floor_a for k in kept_a)
    # shards involved in the second split dropped below ITS activation
    for i in (second["source"], second["target"]):
        assert all(k >= floor_b for k in sg.shards[i]._views)


# ------------------------------------------------- planner + access ledger
def test_access_stats_and_planner_policy():
    stats = AccessStats(2, decay=0.5, query_weight=2.0)
    stats.record_mutations(np.array([100.0, 10.0]))
    stats.record_queries(np.array([0.0, 5.0]))
    np.testing.assert_allclose(stats.loads(), [100.0, 20.0])
    planner = ShardPlanner(imbalance_threshold=1.5, min_load=20.0,
                           min_epochs=2, max_shards=4)
    # cooldown: too few observed epochs
    assert planner.propose(stats.loads(), epochs_observed=0) is None
    stats.on_frontier_advance(0)
    stats.on_frontier_advance(1)
    assert stats.epochs_observed == 2
    np.testing.assert_allclose(stats.loads(), [25.0, 5.0])  # decayed
    d = planner.propose(stats.loads(), epochs_observed=stats.epochs_observed)
    assert d is not None and d.shard == 0 and "shard 0" in d.reason
    # a straggler catching up moves the frontier several epochs in ONE
    # advance notification: the tick must count epochs, not notifications
    stats.on_frontier_advance(4)
    assert stats.epochs_observed == 5
    np.testing.assert_allclose(stats.loads(), [25.0 / 8, 5.0 / 8])
    stats.on_frontier_advance(4)               # repeat notification: no-op
    assert stats.epochs_observed == 5
    # guard rails: idle store, shard cap, balanced load
    assert planner.propose([1.0, 0.5], epochs_observed=9) is None
    assert ShardPlanner(max_shards=2).propose([100.0, 1.0],
                                              epochs_observed=9) is None
    assert planner.propose([30.0, 29.0], epochs_observed=9) is None
    with pytest.raises(ValueError, match="imbalance_threshold"):
        ShardPlanner(imbalance_threshold=1.0)
    stats.reset(3)
    assert stats.epochs_observed == 0 and stats.loads().tolist() == [0, 0, 0]


def test_planner_driven_splits_on_skewed_stream():
    """End to end: a zipf-skewed stream trips the planner, splits respect
    the cooldown, and the store stays oracle-identical throughout."""
    n, epochs = 64, 8
    batches = synthesize_skewed_stream(n, epochs, 200, seed=13)
    planner = ShardPlanner(imbalance_threshold=1.2, min_load=100.0,
                           min_epochs=2, max_shards=8)
    sg = ShardedDynamicGraph(2, n, 16384, planner=planner)
    ref = LoopDynamicGraph(n, 16384)
    events = []
    for b in batches:
        sg.apply(b)
        ref.apply(b)
        ev = sg.maybe_reshard()
        if ev is not None:
            events.append(ev)
    assert events, "skewed stream must trigger at least one split"
    assert sg.n_shards == 2 + len(events)
    # cooldown: stats reset on split, so activations are >= min_epochs apart
    acts = [e["activation_epoch"] for e in events]
    assert all(b - a >= planner.min_epochs
               for a, b in zip(acts, acts[1:], strict=False))
    for e in range(epochs):
        _assert_stitched_equal(sg, ref, Version(e, 0))


def test_failed_window_does_not_record_query_touches():
    """Regression: a window that fails mid-execute is re-queued — its
    touches must not land in the access ledger (retries would otherwise
    inflate shard loads with phantom queries and could trip the
    planner). Successful windows buffer their touches on the read plane;
    the next ingest tick drains them into the ledger."""
    sg = ShardedDynamicGraph(2, 16, 64)
    server = GraphQueryServer(sg)
    server.step(MutationBatch(Version(0, 0),
                              add_src=np.array([0], np.int32),
                              add_dst=np.array([1], np.int32)))
    server.submit(KHop(1, k=1))
    server.submit("not a query")               # poisons the window
    with pytest.raises(TypeError):
        server.flush()
    assert not server._touch_buffer             # nothing buffered
    server._pending_cheap = [e for e in server._pending_cheap
                             if not isinstance(e.request.query, str)]
    server.flush()                              # retry without the poison
    assert len(server._touch_buffer) == 1       # buffered exactly once
    server._drain_touches()                     # the ingest tick's drain
    assert sg.access_stats.queries.sum() == 1   # counted exactly once
    server._drain_touches()                     # buffer cleared: no double
    assert sg.access_stats.queries.sum() == 1


def test_server_auto_reshard_records_events():
    """The serving loop's planner tick: step() fires the split between
    epochs and the event lands in reshard_events/stats()."""
    n, epochs = 64, 8
    batches = synthesize_skewed_stream(n, epochs, 200, seed=13)
    planner = ShardPlanner(imbalance_threshold=1.2, min_load=100.0,
                           min_epochs=2, max_shards=6)
    sg = ShardedDynamicGraph(2, n, 16384, planner=planner)
    server = GraphQueryServer(sg, tol=1e-6, max_iter=100)
    ref = LoopDynamicGraph(n, 16384)
    for b in batches:
        server.step(b)
        ref.apply(b)
        server.submit(KHop(int(b.add_dst[0]), k=1))
        server.flush()                      # feeds the query-touch ledger
    s = server.stats()
    assert server.reshard_events and s.reshard_events
    assert s.n_shards == 2 + len(server.reshard_events)
    assert s.routing_plan_id == len(server.reshard_events)
    assert "reason" in server.reshard_events[0]
    _assert_stitched_equal(sg, ref, Version(epochs - 1, 0))


# ------------------------------------------------- routing plan determinism
def _check_plan_invariants(n_base, plans, keys):
    for p in plans:
        # totality/uniqueness: every key matches exactly ONE leaf
        matches = np.zeros(len(keys), np.int64)
        residue = keys % p.n_base
        h = _mix64(keys)
        for leaf in p.leaves:
            mask = np.uint64((1 << leaf.depth) - 1)
            matches += ((residue == leaf.residue)
                        & ((h & mask) == np.uint64(leaf.path))).astype(int)
        assert (matches == 1).all()
    final = plans[-1]
    # replaying the history reproduces the assignment exactly
    np.testing.assert_array_equal(
        RoutingPlan.replay(n_base, final.history).assign(keys),
        final.assign(keys))
    # a split only ever moves keys OUT of the split shard; a merge only
    # ever moves the removed shard's keys onto the survivor
    for prev, nxt in zip(plans, plans[1:], strict=False):
        op, a, b, _act = nxt.history[-1]
        pa, na = prev.assign(keys), nxt.assign(keys)
        if op == "split":
            stay = pa != a
            np.testing.assert_array_equal(pa[stay], na[stay])
            assert np.isin(na[~stay], [a, b]).all()
        else:
            moved = pa == b
            np.testing.assert_array_equal(pa[~moved], na[~moved])
            assert (na[moved] == a).all()


def test_routing_plan_determinism_fixed_histories():
    """Deterministic variant of the property test (always runs)."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 1 << 40, 2048)
    for n_base, hots in [(1, [0, 0, 0, 1]), (2, [1, 2, 1]),
                         (4, [3, 0, 4, 5, 0])]:
        plans = [RoutingPlan.initial(n_base)]
        for i, hot in enumerate(hots):
            plans.append(plans[-1].split(hot, activation_epoch=i + 1))
        _check_plan_invariants(n_base, plans, keys)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 5), st.lists(st.integers(0, 10 ** 6), max_size=6),
       st.integers(0, 2 ** 31 - 1))
def test_routing_plan_partition_property(n_base, split_picks, key_seed):
    """Property: under ANY split sequence every key maps to exactly one
    shard, replaying the plan history reproduces the assignment, and a
    split never moves a key that was not on the split shard."""
    plans = [RoutingPlan.initial(n_base)]
    for i, pick in enumerate(split_picks):
        plans.append(plans[-1].split(pick % plans[-1].n_shards, i + 1))
    keys = np.random.default_rng(key_seed).integers(0, 1 << 40, 512)
    _check_plan_invariants(n_base, plans, keys)
