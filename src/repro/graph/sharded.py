"""Sharded dynamic-graph store — the paper's distributed data model on top
of the vectorized single store.

The evolving graph is distributed across ``core.snapshotter.DataNode``s,
one :class:`~repro.graph.dyngraph.DynamicGraph` shard per node, with
mutations routed by **destination vertex** — the same hash route
``IngestNode`` uses — so every edge (and every delete of it) lands on
exactly one shard and shard-local LIFO delete semantics equal the global
ones. Ingestion goes through ``IngestNode.dispatch_batch`` with the encoded
mutations riding along as a payload: the paper's no-wait rule applies
unchanged (a shard whose local frontier lags parks its slice in
``blocked_batches``; healthy shards keep ingesting), and a shard *applies*
its slice inside ``DataNode.seal_epoch`` via the ``on_seal`` hook, so the
local snapshot and the shard store seal atomically.

Each shard maintains its own delta-patched join view over its slice;
:meth:`ShardedDynamicGraph.join_view` stitches the per-shard CSRs into a
global :class:`~repro.graph.dyngraph.JoinView` that is byte-identical to
the single store's (per-shard rows are already in canonical (dst, src)
order and a key can only live on one shard, so a stable merge reproduces
the canonical global order exactly). The ``SnapshotCoordinator`` frontier
gates which epochs are queryable: a snapshot is only addressable once every
shard has sealed it, which is the paper's global-snapshot rule.

For distributed compute, :meth:`shard_views` exposes the pre-sharded
per-shard views directly — ``partition.partition_graph_sharded`` consumes
them without re-bucketing edges.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import numpy as np

from repro.core.snapshotter import DataNode, IngestNode, SnapshotCoordinator
from repro.core.versioned import Version
from repro.graph.dyngraph import (DEFAULT_CHURN_THRESHOLD, DynamicGraph,
                                  JoinView, MutationBatch, build_join_view,
                                  prune_views)

# payload row kinds, in the order DynamicGraph.apply processes them
K_VERTEX, K_ADD, K_DEL = 0, 1, 2


def encode_mutations(batch: MutationBatch) -> tuple[np.ndarray, np.ndarray,
                                                    np.ndarray]:
    """Flatten a MutationBatch into (keys, epochs, payload) for
    ``IngestNode.dispatch_batch``.

    keys are the routing keys (dst for edges, the vertex id for vertex
    adds); payload rows are ``(kind, a, b, packed_version)`` int64 — kind
    ordering (vertices, then edge adds, then deletes) matches the order
    ``DynamicGraph.apply`` processes a batch, so a shard replaying its rows
    in payload order reproduces the single store's semantics.
    """
    v = batch.version.pack()
    # MutationBatch.__post_init__ pads/validates, so the two arrays agree by
    # construction; a hand-built batch that bypassed it fails loudly here
    # instead of silently dropping vertex adds on the sharded path only
    n_typed = len(batch.add_vertices)
    if len(batch.vertex_types) != n_typed:
        raise ValueError(
            f"add_vertices ({n_typed}) and vertex_types "
            f"({len(batch.vertex_types)}) disagree in length")
    n_add = len(batch.add_src)
    n_del = len(batch.del_src)
    total = n_typed + n_add + n_del
    if not total:
        z = np.zeros(0, np.int64)
        return z, z, np.zeros((0, 4), np.int64)
    payload = np.empty((total, 4), np.int64)
    payload[:, 3] = v
    payload[:n_typed, 0] = K_VERTEX
    payload[:n_typed, 1] = batch.add_vertices
    payload[:n_typed, 2] = batch.vertex_types
    a = n_typed + n_add
    payload[n_typed:a, 0] = K_ADD
    payload[n_typed:a, 1] = batch.add_src
    payload[n_typed:a, 2] = batch.add_dst
    payload[a:, 0] = K_DEL
    payload[a:, 1] = batch.del_src
    payload[a:, 2] = batch.del_dst
    key_arr = np.empty(total, np.int64)
    key_arr[:n_typed] = batch.add_vertices      # vertex id routes home
    key_arr[n_typed:a] = batch.add_dst
    key_arr[a:] = batch.del_dst
    epochs = np.full(total, batch.version.epoch, np.int64)
    return key_arr, epochs, payload


def decode_payloads(payloads: list[np.ndarray]) -> list[MutationBatch]:
    """Reassemble a shard's payload rows (arrival order) into per-version
    MutationBatches, preserving within-batch mutation order."""
    if not payloads:
        return []
    rows = np.concatenate(payloads, axis=0) if len(payloads) > 1 \
        else payloads[0]
    out = []
    vcol = rows[:, 3]
    # stable group-by on the packed version: np.unique yields versions in
    # ascending (= apply) order and the boolean mask preserves within-version
    # arrival order, so a straggler shard replaying several parked slices in
    # one seal — possibly interleaved across versions — still reassembles
    # each batch intact. (The old fast path trusted rows[0] == rows[-1],
    # which an interleaved replay defeats.) Common case: one version per
    # seal, detected with a full scan, not an endpoint check.
    if (vcol == vcol[0]).all():
        versions = vcol[:1]
    else:
        versions = np.unique(vcol)
    for v in versions:
        grp = rows if len(versions) == 1 else rows[vcol == v]
        kind, a, b = grp[:, 0], grp[:, 1], grp[:, 2]
        vert = kind == K_VERTEX
        add = kind == K_ADD
        dele = kind == K_DEL
        out.append(MutationBatch(
            Version.unpack(int(v)),
            add_src=a[add].astype(np.int32),
            add_dst=b[add].astype(np.int32),
            del_src=a[dele].astype(np.int32),
            del_dst=b[dele].astype(np.int32),
            add_vertices=a[vert].astype(np.int32),
            vertex_types=b[vert].astype(np.int32)))
    return out


def stitch_join_views(version: Version,
                      views: list[JoinView]) -> JoinView:
    """Merge per-shard canonical CSRs into the global one.

    Every (src, dst) key lives on exactly one shard (dst-hash routing) and
    each shard's rows are already (dst, src)-sorted, so a stable argsort of
    the concatenated keys is a duplicate-safe k-way merge: the result is
    byte-identical to the single store's canonical CSR.
    """
    if not views:
        raise ValueError("no shard views to stitch")
    n = views[0].n
    keys = np.concatenate([v.np_keys for v in views])
    src = np.concatenate([v.np_src for v in views])
    dst = np.concatenate([v.np_dst for v in views])
    order = np.argsort(keys, kind="stable")
    in_deg = np.zeros(n, np.int64)
    out_deg = np.zeros(n, np.int64)
    for v in views:
        in_deg += v.np_in_deg
        out_deg += v.np_out_deg
    return build_join_view(version, n, keys[order], src[order], dst[order],
                           in_deg, out_deg)


class ShardedDynamicGraph:
    """N DynamicGraph shards behind an IngestNode + SnapshotCoordinator.

    ``e_max`` is the **per-shard** edge capacity. ``route`` maps a routing
    key (destination vertex / vertex id) to a shard id and must be
    NumPy-vectorizable for the batched dispatch fast path; the default is
    the same modular hash the examples use for ``IngestNode``.

    The synchronous driving pattern is one batch per epoch::

        sg.ingest(batch)                  # no-wait dispatch to shards
        sg.seal_epoch(batch.version.epoch)  # seal + apply + advance frontier

    (or ``sg.apply(batch)`` for both at once). Per-shard sealing
    (``seal_shard``) lets a straggler shard lag: its slice stays parked and
    the global frontier — and therefore ``join_view`` — holds back until it
    catches up.
    """

    def __init__(self, n_shards: int, n_max: int, e_max: int, *,
                 churn_threshold: float = DEFAULT_CHURN_THRESHOLD,
                 route: Optional[Callable] = None):
        if n_shards < 1:
            raise ValueError("need at least one shard")
        self.n_shards = n_shards
        self.n_max = n_max
        self.e_max = e_max
        self.route = route if route is not None else (lambda k: k % n_shards)
        self.shards = [DynamicGraph(n_max, e_max, churn_threshold)
                       for _ in range(n_shards)]
        self.nodes = [DataNode(i, on_seal=self._on_seal(i))
                      for i in range(n_shards)]
        self.coordinator = SnapshotCoordinator(self.nodes)
        self.ingest_node = IngestNode(self.nodes, route=self.route)
        self._views: dict[int, JoinView] = {}
        self._last_version = -1
        self._ingested_packed: list[int] = []   # every ingested version, asc
        # per-shard cumulative apply seconds — the benchmark's critical-path
        # model of parallel shard ingestion reads these
        self.shard_apply_seconds = [0.0] * n_shards

    def _on_seal(self, shard_id: int) -> Callable[[int, list], None]:
        def on_seal(epoch: int, payloads: list) -> None:
            t0 = time.perf_counter()
            shard = self.shards[shard_id]
            batches = decode_payloads(payloads)
            # pre-check capacity across the WHOLE epoch so a failed seal is
            # a no-op (DynamicGraph.apply is atomic per batch; this makes
            # the seal atomic per epoch) — the epoch stays pending and can
            # be re-sealed after intervention
            adds = sum(len(b.add_src) for b in batches)
            if shard.n_edges + adds > shard.e_max:
                raise MemoryError(
                    f"shard {shard_id}: epoch {epoch} adds {adds} edges to "
                    f"{shard.n_edges}/{shard.e_max}; seal aborted, epoch "
                    "left pending")
            for batch in batches:
                shard.apply(batch)
            self.shard_apply_seconds[shard_id] += time.perf_counter() - t0
        return on_seal

    # -- ingestion ---------------------------------------------------------
    def ingest(self, batch: MutationBatch) -> int:
        """No-wait dispatch of one mutation batch; returns the number of
        mutations dispatched now (the rest park until shards catch up).

        Multiple batches per epoch are fine, but an epoch is closed for
        ingestion once ANY shard has sealed it — a slice delivered to a
        sealed local snapshot could never be applied, so that is an error
        here rather than silent loss.
        """
        v = batch.version.pack()
        if v <= self._last_version:
            raise ValueError("mutation batches must have increasing versions")
        sealed = max(n.local_frontier for n in self.nodes)
        if batch.version.epoch <= sealed:
            raise ValueError(
                f"epoch {batch.version.epoch} is already sealed on some "
                f"shard (max local frontier {sealed}); ingest batches "
                "before sealing their epoch")
        # encode first: if it raises (malformed batch), no version
        # bookkeeping has happened and the same version can be retried —
        # otherwise latest_sealed() could later name a version whose
        # mutations were never applied
        keys, epochs, payload = encode_mutations(batch)
        self._last_version = v
        self._ingested_packed.append(v)
        if not keys.size:
            return 0
        return self.ingest_node.dispatch_batch(keys, epochs, payload)

    def seal_epoch(self, epoch: int) -> int:
        """Seal ``epoch`` on every shard (applying parked + pending slices)
        and advance the global frontier. Returns the new global frontier.

        Seals one epoch per shard per round with a blocked-batch retry
        between rounds: a slice parked because its shard lagged several
        epochs becomes dispatchable the moment the previous epoch seals,
        and must land before its own epoch seals.
        """
        while any(n.local_frontier < epoch for n in self.nodes):
            self.ingest_node.retry_blocked_batches()
            for node in self.nodes:
                if node.local_frontier < epoch:
                    node.seal_epoch(node.local_frontier + 1)
        self.ingest_node.retry_blocked_batches()
        return self.coordinator.advance()

    def seal_shard(self, shard_id: int, epoch: int) -> int:
        """Seal one shard through ``epoch`` (straggler-paced sealing) and
        advance the global frontier."""
        node = self.nodes[shard_id]
        while node.local_frontier < epoch:
            self.ingest_node.retry_blocked_batches()
            node.seal_epoch(node.local_frontier + 1)
        self.ingest_node.retry_blocked_batches()
        return self.coordinator.advance()

    def apply(self, batch: MutationBatch) -> None:
        """Ingest + seal in one step (the DynamicGraph-compatible path)."""
        self.ingest(batch)
        self.seal_epoch(batch.version.epoch)

    # -- snapshots ---------------------------------------------------------
    def latest_sealed(self) -> Optional[Version]:
        """Newest frontier-sealed snapshot version — the only snapshot an
        online query may be answered against (never a partially-sealed
        epoch). Returns the newest ingested version whose epoch every shard
        has sealed; ``Version(frontier, 0)`` if the sealed epochs carried no
        batches (a sealed empty snapshot is queryable); ``None`` before the
        first global seal."""
        frontier = self.coordinator.global_frontier
        if frontier < 0:
            return None
        log = self._ingested_packed
        for i in range(len(log) - 1, -1, -1):
            if (log[i] >> 32) <= frontier:
                # the frontier is monotone, so entries older than this hit
                # can never be the answer again — trim them so the log is
                # bounded by the unsealed backlog, not the stream length
                if i > 0:
                    del log[:i]
                return Version.unpack(log[0])
        return Version(frontier, 0)

    def on_frontier_advance(self, fn: Callable[[int], None]) -> None:
        """Subscribe ``fn(new_frontier)`` to global-seal notifications —
        fires whenever an epoch becomes sealed on every shard (i.e. a newer
        consistent snapshot became queryable)."""
        self.coordinator.subscribe(fn)

    def _gate(self, version: Version) -> None:
        if version.epoch > self.coordinator.global_frontier:
            raise ValueError(
                f"epoch {version.epoch} is not globally sealed (frontier "
                f"{self.coordinator.global_frontier}); snapshots become "
                "queryable once every shard seals them")

    def shard_views(self, version: Version,
                    use_kernel: bool = False) -> list[JoinView]:
        """Per-shard join views for a sealed snapshot — pre-sharded input
        for ``partition.partition_graph_sharded`` (no re-bucketing)."""
        self._gate(version)
        return [s.join_view(version, use_kernel=use_kernel)
                for s in self.shards]

    def join_view(self, version: Version,
                  use_kernel: bool = False) -> JoinView:
        """The stitched global CSR for a sealed snapshot (cached)."""
        key = version.pack()
        if key in self._views:
            return self._views[key]
        view = stitch_join_views(version,
                                 self.shard_views(version,
                                                  use_kernel=use_kernel))
        self._views[key] = view
        return view

    def gc_views(self, keep_latest: int = 4) -> int:
        """Ladder-GC every shard's view cache plus the stitched cache."""
        dropped = sum(s.gc_views(keep_latest) for s in self.shards)
        return dropped + prune_views(self._views, keep_latest)

    # -- merged vertex/edge state -----------------------------------------
    @property
    def n_edges(self) -> int:
        return sum(s.n_edges for s in self.shards)

    @property
    def v_created(self) -> np.ndarray:
        """Global creation stamps: a vertex exists from the earliest version
        any shard created it (explicit add on its home shard, or endpoint
        auto-creation wherever its edges landed)."""
        out = self.shards[0].v_created.copy()
        for s in self.shards[1:]:
            np.minimum(out, s.v_created, out=out)
        return out

    @property
    def v_type(self) -> np.ndarray:
        """Global vertex types. Typed adds only ever land on a vertex's home
        shard (vertex-id routing), so the home shard's type is authoritative
        — unless another shard auto-created the vertex strictly earlier, in
        which case the global semantics are an untyped (0) creation."""
        created = self.v_created
        ids = np.arange(self.n_max, dtype=np.int64)
        try:
            home = np.asarray(self.route(ids))
            if home.shape != ids.shape:
                raise TypeError
        except Exception:
            # route not vectorizable — elementwise, as in dispatch_batch
            home = np.asarray([self.route(int(k)) for k in ids], np.int64)
        out = np.zeros(self.n_max, np.int32)
        for i, s in enumerate(self.shards):
            mine = (home == i) & (s.v_created == created)
            out[mine] = s.v_type[mine]
        return out

    @property
    def n_vertices(self) -> int:
        return int((self.v_created != np.iinfo(np.int64).max).sum())

    def num_vertices(self, version: Optional[Version] = None) -> int:
        if version is None:
            return self.n_vertices
        return int((self.v_created <= version.pack()).sum())

    @property
    def view_delta_patches(self) -> int:
        return sum(s.view_delta_patches for s in self.shards)

    @property
    def view_full_builds(self) -> int:
        return sum(s.view_full_builds for s in self.shards)

    def shard_edge_counts(self) -> list[int]:
        return [s.n_edges for s in self.shards]
