"""Quickstart: train a small LM with the full stack (protocol-dataflow
training loop, versioned checkpoints, deterministic data views), then serve
from the newest snapshot.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.configs import all_configs, reduced
from repro.launch.serve import Server
from repro.launch.train import run
from repro.train.data import MarkovLM, unigram_entropy_floor


def main():
    cfg = reduced(all_configs()["qwen2.5-14b"], num_layers=2, d_model=128,
                  vocab_size=128, loss_chunk=512)
    print(f"config: {cfg.name}, {cfg.param_count():,} params")
    print(f"unigram entropy floor: "
          f"{unigram_entropy_floor(MarkovLM(cfg.vocab_size)):.3f} nats")
    with tempfile.TemporaryDirectory() as d:
        losses, state = run(cfg, steps=60, batch=16, seq=64, ckpt_dir=d,
                            ckpt_every=20, log_every=20)
        first = np.mean([losses[i] for i in sorted(losses)[:5]])
        last = np.mean([losses[i] for i in sorted(losses)[-5:]])
        print(f"train loss: {first:.3f} -> {last:.3f}")
        server = Server(cfg, state["params"])
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (2, 8)).astype(np.int32)
        print("generated:", server.generate(prompts, 8)[0].tolist())


if __name__ == "__main__":
    main()
