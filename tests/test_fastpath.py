"""Low-latency fast-path battery: versioned result cache, two-lane
scheduler, publish-time trace prewarm.

The contracts under test, per the fast-path section of
``docs/ARCHITECTURE.md``:

* cache coherence by construction: a cached answer is byte-identical to
  a cold compute at the same sealed version — at EVERY version of a
  churning stream, across split and merge cutovers — because the cache
  key space is the version itself (seal-swap invalidation, I10's
  argument applied to results),
* pinned replays key into their pinned version's own space: a foreign
  version's cached entry can never answer them,
* the two-lane scheduler cannot starve: an expensive-lane flood leaves
  the cheap lane answerable without executing a single expensive query,
  the expensive drain honors its budget, queued-but-expired entries shed
  as typed ``ERR_DEADLINE`` without executing, and concurrent lane
  dispatchers lose and duplicate nothing,
* prewarm is idempotent and invisible: racing it against queries and
  seals changes no answer and no replica telemetry,
* serving bookkeeping (latency windows, the query-touch buffer) stays
  bounded past 10^5 queries on a long-lived server.
"""
import threading
import time

import numpy as np
import pytest

from repro.graph import compute as gc
from repro.graph.dyngraph import DynamicGraph, synthesize_churn_stream
from repro.graph.query import (ERR_DEADLINE, DegreeTopK, KHop,
                               PageRankQuery, QueryRequest, Reachability,
                               SnapshotQueryEngine, query_fingerprint)
from repro.graph.sharded import ShardedDynamicGraph
from repro.launch.serve_graph import CHEAP_KINDS, GraphQueryServer


def _server(n=64, epochs=5, adds=60, n_shards=3, seed=13, **kw):
    batches = synthesize_churn_stream(n, epochs, adds, seed=seed,
                                      delete_frac=0.2)
    e_max = sum(len(b.add_src) for b in batches) + 16
    sg = ShardedDynamicGraph(n_shards, n, e_max)
    return GraphQueryServer(sg, **kw), batches


def _bytes_of(value) -> bytes:
    if isinstance(value, tuple):
        return b"|".join(np.asarray(v).tobytes() for v in value)
    return np.asarray(value).tobytes()


# ---------------------------------------------------------- cache coherence
def test_cached_answers_byte_equal_cold_compute_across_cutovers():
    """The coherence property: at every sealed version of a stream that
    splits AND merges mid-run, a cache hit is byte-identical to the cold
    compute — on a twin server with the cache off — at that exact
    version. The second pass of each query set must actually hit."""
    n, epochs = 48, 8
    batches = synthesize_churn_stream(n, epochs, 60, seed=23,
                                      delete_frac=0.35, readd_frac=0.4)
    e_max = sum(len(b.add_src) for b in batches) + 16
    sg = ShardedDynamicGraph(2, n, e_max)
    srv = GraphQueryServer(sg, auto_reshard=False, prewarm_traces=False,
                           tol=1e-6, max_iter=100)
    cold = GraphQueryServer(ShardedDynamicGraph(2, n, e_max),
                            auto_reshard=False, result_cache=False,
                            prewarm_traces=False, tol=1e-6, max_iter=100)
    split = None
    for e, b in enumerate(batches):
        srv.step(b)
        cold.step(b)
        if e == 2:
            split = sg.split_shard(0)
        elif e == 5:
            sg.merge_shards(split["target"])
        queries = [KHop(int(b.add_dst[0]) % n, k=2),
                   Reachability(0, n - 1, max_hops=6),
                   DegreeTopK(5), PageRankQuery(top_k=4)]
        hits0 = srv.engine.result_cache_stats()["hits"]
        first = [srv.query(q) for q in queries]     # cold at this version
        second = [srv.query(q) for q in queries]    # must hit the cache
        assert srv.engine.result_cache_stats()["hits"] \
            >= hits0 + len(queries)
        for q, r1, r2 in zip(queries, first, second, strict=True):
            assert r1.version.pack() == r2.version.pack()
            assert _bytes_of(r1.value) == _bytes_of(r2.value)
            want = cold.query(q)
            assert want.version.pack() == r2.version.pack()
            assert _bytes_of(want.value) == _bytes_of(r2.value)
    assert cold.engine.result_cache_stats()["hits"] == 0
    s = srv.stats()
    assert s.split_events == 1 and s.merge_events == 1
    assert s.result_cache_hits > 0


def test_pinned_replay_bypasses_foreign_version_cache():
    """A pinned replay must answer from its OWN version's key space: the
    same fingerprint cached at the serving version cannot leak into an
    older pin (and the replay then populates the pin's own space)."""
    server, batches = _server(epochs=5, prewarm_traces=False)
    oracle = DynamicGraph(64, 8192)
    for b in batches:
        server.step(b)
        oracle.apply(b)
    q = KHop(3, k=2)
    latest = server.query(q)                    # caches at the frontier
    assert server.engine.has_cached_result(latest.version, q)
    old = batches[1].version
    assert old.pack() != latest.version.pack()
    assert not server.engine.has_cached_result(old, q)
    pinned = None

    def on_done(resp):
        nonlocal pinned
        pinned = resp

    assert server.submit_request(
        QueryRequest(q, 1, pin_version=old), on_done=on_done) is None
    server.run_window()
    assert pinned.ok and pinned.version == old
    want = np.asarray(gc.k_hop(oracle.join_view(old), np.array([3]), 2))
    assert np.asarray(pinned.value).tobytes() == want.tobytes()
    # the replay landed in the pin's own space, not the frontier's
    assert server.engine.has_cached_result(old, q)
    # and the frontier's entry still answers the frontier
    again = server.query(q)
    assert _bytes_of(again.value) == _bytes_of(latest.value)


def test_cache_hits_cannot_be_poisoned_by_caller_mutation():
    """Hits hand out the memoized object itself, so an in-process caller
    that mutated a returned array would corrupt every later answer at
    that version — memoized ndarrays are read-only (tuples recursively),
    the mutation faults, and the cached bytes survive it."""
    server, batches = _server(epochs=3, prewarm_traces=False)
    for b in batches:
        server.step(b)
    for q in (KHop(3, k=2), DegreeTopK(5)):
        first = server.query(q)
        want = _bytes_of(first.value)
        arrays = (first.value if isinstance(first.value, tuple)
                  else (first.value,))
        for arr in arrays:
            with pytest.raises(ValueError):
                np.asarray(arr)[...] = 0
        again = server.query(q)                 # a hit, and unpoisoned
        assert _bytes_of(again.value) == want


def test_result_cache_rides_the_ladder_gc():
    """Sealed key spaces drop whole through the same ladder as the rank
    cache: a long stream cannot pin one result dict per epoch forever,
    and the drops are visible in the eviction counter."""
    server, batches = _server(epochs=10, rank_keep=2,
                              prewarm_traces=False)
    for b in batches:
        server.step(b)
        server.query(KHop(1, k=1))              # one entry per version
    with server.engine._rank_lock:
        cached_versions = len(server.engine._result_cache)
    assert cached_versions <= 4                 # ladder(2) never 10
    assert server.engine.result_cache_stats()["evictions"] > 0


def test_per_version_entry_cap_serves_without_memoizing():
    """Past ``result_cache_entries`` a version's space stops growing:
    answers still serve (correctly), overflow counts as evictions."""
    engine = SnapshotQueryEngine(result_cache_entries=2)
    g = DynamicGraph(16, 64)
    from repro.core.versioned import Version
    from repro.graph.dyngraph import MutationBatch
    g.apply(MutationBatch(Version(0, 0),
                          add_src=np.array([0, 1, 2], np.int32),
                          add_dst=np.array([1, 2, 3], np.int32)))
    view = g.join_view(Version(0, 0))
    queries = [KHop(i, k=1) for i in range(4)]
    values = engine.execute(view, queries)
    uncached = engine.execute(view, queries, use_cache=False)
    for got, want in zip(values, uncached, strict=True):
        assert np.asarray(got).tobytes() == np.asarray(want).tobytes()
    stats = engine.result_cache_stats()
    assert stats["entries"] == 2 and stats["evictions"] == 2
    # re-running: the two memoized hit, the two overflowed recompute
    engine.execute(view, queries)
    assert engine.result_cache_stats()["hits"] == 2


def test_fingerprint_canonicalization_unifies_spellings():
    """None/0 hop bounds and over-n top-k clamp to one key each, so
    equivalent spellings share a cache entry; distinct parameters never
    collide."""
    n = 32
    assert query_fingerprint(Reachability(1, 2, max_hops=None), n) \
        == query_fingerprint(Reachability(1, 2, max_hops=0), n)
    assert query_fingerprint(DegreeTopK(n + 50), n) \
        == query_fingerprint(DegreeTopK(n), n)
    assert query_fingerprint(KHop(1, k=2), n) \
        != query_fingerprint(KHop(1, k=3), n)
    assert query_fingerprint(PageRankQuery(top_k=3), n) \
        != query_fingerprint(PageRankQuery(), n)
    assert query_fingerprint("junk", n) is None


# ------------------------------------------------------- two-lane scheduler
def test_cheap_lane_answers_through_an_expensive_flood():
    """Starvation: with the expensive lane flooded by PageRank, a cheap
    window drains completely without executing a single expensive query
    — the flood stays queued on its own lane."""
    server, batches = _server(prewarm_traces=False, tol=1e-6, max_iter=100)
    server.step(batches[0])
    answered = []
    for i in range(20):
        assert server.submit_request(QueryRequest(PageRankQuery(top_k=3),
                                                  f"pr-{i}"),
                                     on_done=answered.append) is None
    for i in range(5):
        assert server.submit_request(QueryRequest(KHop(i, 1), f"kh-{i}"),
                                     on_done=answered.append) is None
    assert server.stats().queue_depth_by_lane == {"cheap": 5,
                                                  "expensive": 20}
    pr_calls = server.engine.vectorized_calls["pagerank"]
    pairs = server.run_window("cheap")
    assert [req.request_id for req, _ in pairs] \
        == [f"kh-{i}" for i in range(5)]
    assert all(r.ok for _, r in pairs)
    assert server.engine.vectorized_calls["pagerank"] == pr_calls
    assert server.stats().queue_depth_by_lane == {"cheap": 0,
                                                  "expensive": 20}
    # the flood then drains in budgeted slices, nothing lost
    while server.stats().queue_depth_by_lane["expensive"]:
        server.run_window("expensive")
    assert len(answered) == 25
    assert len({r.request_id for r in answered}) == 25
    assert all(r.ok for r in answered)


def test_expensive_drain_honors_budget_and_rearms():
    server, batches = _server(prewarm_traces=False, expensive_budget=4,
                              tol=1e-6, max_iter=100)
    server.step(batches[0])
    for i in range(10):
        server.submit_request(QueryRequest(PageRankQuery(top_k=2), i))
    server.work_expensive.clear()
    pairs = server.run_window("expensive")
    assert len(pairs) == 4                      # exactly the budget
    assert server.stats().queue_depth_by_lane["expensive"] == 6
    assert server.work_expensive.is_set()       # re-armed for the rest


def test_expired_entries_beyond_budget_shed_without_executing():
    """A queued-but-expired request behind the budget horizon must not
    wait out the convoy: the drain sheds it as ERR_DEADLINE now."""
    server, batches = _server(prewarm_traces=False, expensive_budget=2,
                              tol=1e-6, max_iter=100)
    server.step(batches[0])
    for i in range(2):
        server.submit_request(QueryRequest(PageRankQuery(top_k=2), i))
    late = []
    for i in range(3):
        server.submit_request(
            QueryRequest(PageRankQuery(top_k=2), f"late-{i}",
                         deadline_s=0.0), on_done=late.append)
    time.sleep(0.002)
    pairs = server.run_window("expensive")
    assert len(pairs) == 5                      # budget 2 + 3 shed
    assert server.stats().queue_depth_by_lane["expensive"] == 0
    assert len(late) == 3
    assert all(r.error.code == ERR_DEADLINE for r in late)
    assert server.stats().shed_deadline == 3


def test_cached_expensive_query_rides_the_cheap_lane():
    """The classifier's point: an expensive kind whose answer is already
    memoized at the serving version is a dict lookup — it queues cheap."""
    server, batches = _server(prewarm_traces=False, tol=1e-6, max_iter=100)
    server.step(batches[0])
    q = PageRankQuery(top_k=3)
    server.submit_request(QueryRequest(q, 1))
    assert server.stats().queue_depth_by_lane["expensive"] == 1
    server.run_window("expensive")              # now cached
    server.submit_request(QueryRequest(q, 2))
    assert server.stats().queue_depth_by_lane == {"cheap": 1,
                                                  "expensive": 0}
    [(_, resp)] = server.run_window("cheap")
    assert resp.ok
    assert "pagerank" not in CHEAP_KINDS        # it rode on the cache


def test_concurrent_lane_dispatchers_lose_and_duplicate_nothing():
    """Two dispatcher threads (one per lane) against racing submitters:
    every request is answered exactly once and the legacy lane=None
    ordering contract is never violated by the split queues."""
    server, batches = _server(prewarm_traces=False, tol=1e-6, max_iter=50)
    server.step(batches[0])
    total = 120
    answered = []
    answered_lock = threading.Lock()
    stop = threading.Event()
    errors = []

    def dispatcher(lane):
        try:
            while not stop.is_set():
                server.run_window(lane)
        except BaseException as e:              # pragma: no cover
            errors.append(e)

    def on_done(resp):
        with answered_lock:
            answered.append(resp)

    threads = [threading.Thread(target=dispatcher, args=(lane,))
               for lane in ("cheap", "expensive")]
    for t in threads:
        t.start()
    rng = np.random.default_rng(7)
    for i in range(total):
        q = (KHop(int(rng.integers(0, 64)), 1) if i % 3
             else PageRankQuery(top_k=2))
        assert server.submit_request(QueryRequest(q, i),
                                     on_done=on_done) is None
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        with answered_lock:
            if len(answered) == total:
                break
        time.sleep(0.01)
    stop.set()
    for t in threads:
        t.join()
    assert not errors
    assert len(answered) == total
    assert sorted(r.request_id for r in answered) == list(range(total))
    assert all(r.ok for r in answered)


# ------------------------------------------------------------ trace prewarm
def test_warm_traces_is_idempotent_and_changes_no_answer():
    """A second prewarm at the same widths is a no-op (a replay is a
    real kernel sweep, so re-running a warm trace would burn a core for
    a guaranteed jit-cache hit); neither pass touches result-cache or
    replica telemetry, and every answer stays byte-identical."""
    server, batches = _server(prewarm_traces=False)
    for b in batches:
        server.step(b)
    queries = [KHop(3, k=2), Reachability(1, 9, max_hops=4), DegreeTopK(5)]
    before = [server.query(q) for q in queries]
    with server._serve_lock:
        _, view, routed = server._serving
    rc0 = server.engine.result_cache_stats()
    replica0 = server.engine.replica_stats()
    w1 = server.engine.warm_traces(view, routed)
    w2 = server.engine.warm_traces(view, routed)
    assert w1 > 0 and w2 == 0
    assert server.engine.result_cache_stats()["misses"] == rc0["misses"]
    assert server.engine.replica_stats() == replica0
    for q, r in zip(queries, before, strict=True):
        assert _bytes_of(server.query(q).value) == _bytes_of(r.value)


def test_prewarm_races_queries_and_seals_safely():
    """The publish-path prewarm worker racing live queries and the next
    seal: every answer stays correct (twin-server oracle) and at least
    one prewarm completes."""
    n = 64
    server, batches = _server(n=n, epochs=8, prewarm_traces=True)
    twin, _ = _server(n=n, epochs=8, prewarm_traces=False,
                      result_cache=False)
    server.step(batches[0])
    twin.step(batches[0])
    ingest = server.start_background_ingest(iter(batches[1:]),
                                            delay_s=0.005)
    rng = np.random.default_rng(3)
    asked = []
    while ingest.is_alive():
        q = (KHop(int(rng.integers(0, n)), k=2) if rng.random() < 0.6
             else Reachability(int(rng.integers(0, n)),
                               int(rng.integers(0, n)), max_hops=4))
        r = server.query(q)
        asked.append((q, r))
    ingest.join()
    for b in batches[1:]:
        twin.step(b)
    for q, r in asked:
        want = None

        def on_done(resp):
            nonlocal want
            want = resp

        assert twin.submit_request(
            QueryRequest(q, 1, pin_version=r.version),
            on_done=on_done) is None
        twin.run_window()
        assert want.ok, want.error
        assert _bytes_of(want.value) == _bytes_of(r.value)
    deadline = time.monotonic() + 5
    while time.monotonic() < deadline \
            and server.stats().prewarm_runs == 0:
        time.sleep(0.01)
    assert server.stats().prewarm_runs > 0
    server.stop_prewarm()


# ------------------------------------------------------ bounded bookkeeping
def test_serving_bookkeeping_bounded_past_1e5_queries():
    """Regression: a long-lived serving-only server (no ingest tick to
    drain the touch buffer) must not grow its latency windows or the
    query-touch buffer without bound. 10^5+ queries through the real
    window path stay within the documented caps and stats() still
    computes."""
    n = 256
    server, batches = _server(n=n, epochs=1, adds=400,
                              prewarm_traces=False)
    server.step(batches[0])
    per_window, windows = 1000, 110             # 110k queries total
    for w in range(windows):
        for i in range(per_window):
            server.submit(KHop((w * 31 + i) % n, k=1))
        assert len(server.flush()) == per_window
    assert server.served == per_window * windows
    assert len(server.latencies_s) <= 8192
    assert all(len(dq) <= 2048
               for dq in server._kind_latencies.values())
    assert all(len(dq) <= 4096
               for dq in server._lane_latencies.values())
    with server._serve_lock:
        buffered = sum(int(a.size) for a in server._touch_buffer)
        assert buffered == server._touch_buffered
    assert buffered <= server.max_touch_buffer
    s = server.stats()
    assert s.query_p50_s > 0 and s.result_cache_hits > 0
    # the drain still lands the (bounded) remainder in the ledger
    server._drain_touches()
    assert int(server.graph.access_stats.queries.sum()) == buffered
    with server._serve_lock:
        assert server._touch_buffered == 0
