"""Serving tier: typed request envelope, wire codec, and the RPC front.

The contracts under test, per the serving-tier section of
``docs/ARCHITECTURE.md``:

* the wire codec round-trips every query/response shape byte-identically
  (arrays travel as dtype + shape + raw bytes, not as lossy JSON floats),
* admission control and latency budgets surface as TYPED responses
  (``overloaded`` / ``deadline`` / ``bad_pin`` / ``bad_query``) — never
  as hangs, lost requests, or exception strings,
* the soak: many concurrent socket clients against one server under
  simultaneous background ingest WITH a mid-run re-sharding split lose no
  responses, see no duplicate ids, and every successful answer is
  byte-identical to a single-store replay oracle at the sealed version it
  was served from — the epoch-pipelined lock split must not be able to
  serve a torn or stale-referenced snapshot,
* the deprecated ``submit()``/``flush()`` shims keep their semantics on
  top of the typed scheduler.
"""
import dataclasses
import socket
import threading
import time

import numpy as np
import pytest

from repro.core.replica import ShardPlanner
from repro.core.versioned import Version
from repro.graph.dyngraph import (DynamicGraph, synthesize_churn_stream,
                                  synthesize_skewed_stream)
from repro.graph import compute as gc
from repro.graph.query import (ERR_BAD_PIN, ERR_BAD_QUERY, ERR_DEADLINE,
                               ERR_OVERLOADED, DegreeTopK, KHop,
                               PageRankQuery, QueryRequest, QueryResponse,
                               Reachability, query_kind)
from repro.graph.sharded import ShardedDynamicGraph
from repro.launch import rpc
from repro.launch.serve_graph import GraphQueryServer, ServerStats


def _server(n=64, epochs=5, adds=60, n_shards=3, seed=13, **kw):
    batches = synthesize_churn_stream(n, epochs, adds, seed=seed,
                                      delete_frac=0.2)
    e_max = sum(len(b.add_src) for b in batches) + 16
    sg = ShardedDynamicGraph(n_shards, n, e_max)
    return GraphQueryServer(sg, **kw), batches


# ------------------------------------------------------------------ codec
@pytest.mark.parametrize("value", [
    np.arange(17, dtype=np.int64),
    np.random.default_rng(0).random(33),            # float64 exact bits
    np.zeros((3, 5), np.float32),
    np.array([True, False, True]),
    (np.arange(4, dtype=np.int32), np.linspace(0, 1, 4)),
    True,
    None,
])
def test_value_codec_round_trips_byte_identical(value):
    got = rpc.decode_value(rpc.encode_value(value))
    if isinstance(value, tuple):
        assert isinstance(got, tuple)
        for g, v in zip(got, value, strict=True):
            assert np.asarray(g).tobytes() == np.asarray(v).tobytes()
            assert np.asarray(g).dtype == np.asarray(v).dtype
    elif isinstance(value, np.ndarray):
        assert got.tobytes() == value.tobytes()
        assert got.dtype == value.dtype and got.shape == value.shape
    else:
        assert got == value


@pytest.mark.parametrize("q", [
    KHop(source=5, k=2),
    Reachability(src=1, dst=9, max_hops=4),
    Reachability(src=1, dst=9),                     # unbounded variant
    DegreeTopK(7, direction="out"),
    PageRankQuery(top_k=3),
    PageRankQuery(),
])
def test_query_codec_round_trips(q):
    enc = rpc.encode_query(q)
    assert enc["kind"] == query_kind(q)
    assert rpc.decode_query(enc["kind"], enc["query"]) == q


def test_decode_query_rejects_unknown_kind_and_bad_fields():
    with pytest.raises(ValueError, match="unknown query kind"):
        rpc.decode_query("bogus", {})
    with pytest.raises(TypeError):
        rpc.decode_query("k_hop", {"nope": 1})


def test_response_codec_round_trips_ok_and_error():
    ok = QueryResponse.answered(7, np.arange(5), Version(3, 1), 0.25)
    got = rpc.decode_response(rpc.encode_response(ok))
    assert got.ok and got.request_id == 7 and got.version == Version(3, 1)
    assert got.value.tobytes() == ok.value.tobytes()
    err = QueryResponse.failed("abc", ERR_DEADLINE, "too slow",
                               latency_s=0.5)
    got = rpc.decode_response(rpc.encode_response(err))
    assert not got.ok and got.request_id == "abc"
    assert got.error.code == ERR_DEADLINE and got.error.message == "too slow"
    assert got.latency_s == 0.5


def test_frame_layer_length_prefix_and_eof():
    a, b = socket.socketpair()
    try:
        frame = {"op": "query", "id": 1}
        a.sendall(rpc.encode_frame(frame))
        assert rpc.read_frame(b) == frame
        a.sendall(rpc.encode_frame(frame)[:3])      # torn mid-frame
        a.close()
        with pytest.raises(ConnectionError, match="mid-frame"):
            rpc.read_frame(b)
    finally:
        b.close()


# ---------------------------------------------------- typed scheduler paths
def test_submit_request_rejects_unknown_query_typed():
    server, _ = _server()
    resp = server.submit_request(QueryRequest(query="junk", request_id=9))
    assert resp is not None and not resp.ok
    assert resp.error.code == ERR_BAD_QUERY and resp.request_id == 9


def test_admission_control_sheds_typed_overload():
    server, batches = _server(max_pending=2)
    server.step(batches[0])
    assert server.submit_request(QueryRequest(KHop(0, 1), 1)) is None
    assert server.submit_request(QueryRequest(KHop(1, 1), 2)) is None
    shed = server.submit_request(QueryRequest(KHop(2, 1), 3))
    assert shed is not None and shed.error.code == ERR_OVERLOADED
    assert server.stats().shed_overload == 1
    pairs = server.run_window()                # accepted two still answer
    assert [r.request_id for _, r in pairs] == [1, 2]
    assert all(r.ok for _, r in pairs)


def test_expired_deadline_answers_typed_not_stale():
    server, batches = _server()
    server.step(batches[0])
    got = []
    assert server.submit_request(QueryRequest(KHop(0, 1), "late",
                                              deadline_s=0.0),
                                 on_done=got.append) is None
    time.sleep(0.002)
    [(req, resp)] = server.run_window()
    assert req.request_id == "late" and not resp.ok
    assert resp.error.code == ERR_DEADLINE
    assert got == [resp]                       # callback got the same answer
    assert server.stats().shed_deadline == 1


def test_pinned_request_replays_old_sealed_version():
    server, batches = _server()
    g = DynamicGraph(64, 4096)
    for b in batches:
        server.step(b)
        g.apply(b)
    old = batches[1].version
    [(_, resp)] = (server.submit_request(QueryRequest(
        KHop(3, 2), 1, pin_version=old)) or server.run_window())
    assert resp.ok and resp.version == old
    expect = np.asarray(gc.k_hop(g.join_view(old), np.array([3]), 2))
    assert np.asarray(resp.value).tobytes() == expect.tobytes()
    # a never-sealed pin is a typed error, not an exception
    [(_, bad)] = (server.submit_request(QueryRequest(
        KHop(3, 2), 2, pin_version=Version(99, 0))) or server.run_window())
    assert not bad.ok and bad.error.code == ERR_BAD_PIN


def test_stats_is_frozen_dataclass():
    server, batches = _server()
    server.step(batches[0])
    server.query(KHop(0, 1))
    s = server.stats()
    assert isinstance(s, ServerStats)
    assert s.served == 1 and s.windows >= 1 and s.queue_depth == 0
    assert s.serving_version == batches[0].version
    assert "k_hop" in s.per_kind_latency_s
    assert set(s.per_kind_latency_s["k_hop"]) == {"p50", "p95", "p99"}
    with pytest.raises(dataclasses.FrozenInstanceError):
        s.served = 5


def test_query_routes_through_shared_scheduler():
    """The single-shot path must share the window scheduler: its query
    collapses with pending same-kind submissions into ONE vectorized call
    and lands in the same served/window accounting."""
    server, batches = _server()
    server.step(batches[0])
    base_calls = server.engine.vectorized_calls["k_hop"]
    server.submit(KHop(1, 2))
    server.submit(KHop(2, 2))
    r = server.query(KHop(3, 2))
    assert r.query == KHop(3, 2)
    assert server.engine.vectorized_calls["k_hop"] == base_calls + 1
    assert server.stats().served == 3
    assert server.stats().windows == 1


# ------------------------------------------------------------- RPC serving
def test_rpc_round_trip_and_typed_wire_errors():
    server, batches = _server()
    for b in batches:
        server.step(b)
    front = rpc.GraphRPCServer(server, port=0).start()
    try:
        host, port = front.address
        with rpc.GraphRPCClient(host, port) as c:
            r = c.query(KHop(source=3, k=2))
            assert r.ok and r.version == batches[-1].version
            # malformed wire request -> typed bad_query, connection lives
            c._sock.sendall(rpc.encode_frame(
                {"op": "query", "id": 99, "kind": "bogus", "query": {}}))
            bad = c.recv()
            assert not bad.ok and bad.error.code == ERR_BAD_QUERY
            assert bad.request_id == 99
            # unknown op -> typed bad_query too
            c._sock.sendall(rpc.encode_frame({"op": "nope", "id": 100}))
            assert c.recv().error.code == ERR_BAD_QUERY
            # stats op serves the ServerStats fields over the wire
            s = c.stats()
            assert s["served"] >= 1 and s["n_shards"] == 3
            assert Version.unpack(s["serving_version"]) \
                == batches[-1].version
    finally:
        front.stop()


def test_rpc_overload_sheds_typed_response():
    server, batches = _server(max_pending=0)    # every request sheds
    server.step(batches[0])
    front = rpc.GraphRPCServer(server, port=0).start()
    try:
        host, port = front.address
        with rpc.GraphRPCClient(host, port) as c:
            r = c.query(KHop(source=0, k=1))
            assert not r.ok and r.error.code == ERR_OVERLOADED
    finally:
        front.stop()


def test_rpc_soak_concurrent_clients_ingest_and_reshard():
    """The acceptance soak: 8 socket clients hammer the front while the
    ingest thread streams a zipf-skewed stream that trips a mid-run
    planner split. No response is lost or duplicated, typed errors are
    the only failure surface, and every successful answer matches the
    single-store replay oracle byte for byte at its served version."""
    n, epochs = 64, 8
    batches = synthesize_skewed_stream(n, epochs, 200, seed=13)
    e_max = sum(len(b.add_src) for b in batches) + 16
    planner = ShardPlanner(imbalance_threshold=1.2, min_load=100.0,
                           min_epochs=2, max_shards=6)
    sg = ShardedDynamicGraph(2, n, e_max, planner=planner)
    server = GraphQueryServer(sg, tol=1e-6, max_iter=100)
    server.step(batches[0])                     # seal one epoch up front
    front = rpc.GraphRPCServer(server, port=0).start()
    host, port = front.address
    n_clients, per_client = 8, 25
    results: dict[int, list[QueryResponse]] = {}
    errors: list[BaseException] = []

    def client(ci: int) -> None:
        rng = np.random.default_rng(100 + ci)
        mine: list[QueryResponse] = []
        try:
            with rpc.GraphRPCClient(host, port) as c:
                pinned: Version | None = None
                for j in range(per_client):
                    roll = rng.random()
                    if roll < 0.5:
                        q = KHop(int(rng.integers(0, n)), k=2)
                    elif roll < 0.8:
                        q = Reachability(int(rng.integers(0, n)),
                                         int(rng.integers(0, n)),
                                         max_hops=6)
                    else:
                        q = DegreeTopK(5)
                    # every 5th query replays a version seen earlier —
                    # pinned reads must survive concurrent re-sharding
                    pin = pinned if (j % 5 == 4) else None
                    r = c.query(q, pin_version=pin, deadline_s=30.0)
                    assert r.request_id == j + 1, "response misrouted"
                    mine.append(r)
                    if r.ok and pinned is None:
                        pinned = r.version
        except BaseException as e:              # pragma: no cover
            errors.append(e)
        results[ci] = mine

    ingest = server.start_background_ingest(iter(batches[1:]),
                                            delay_s=0.01)
    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ingest.join()
    front.stop()

    assert not errors
    # no lost or duplicated responses, ids correlate per connection
    for ci in range(n_clients):
        assert len(results[ci]) == per_client, f"client {ci} lost answers"
        ids = [r.request_id for r in results[ci]]
        assert ids == list(range(1, per_client + 1))
    flat = [r for rs in results.values() for r in rs]
    ok = [r for r in flat if r.ok]
    # typed errors only (a pin can retire if a split GCs old plans)
    assert all(r.error.code in (ERR_BAD_PIN, ERR_DEADLINE, ERR_OVERLOADED)
               for r in flat if not r.ok)
    assert len(ok) >= n_clients * per_client * 0.9
    assert server.reshard_events, "stream must trip at least one split"
    # replay oracle: single store, same stream; every answer byte-exact
    g = DynamicGraph(n, e_max)
    for b in batches:
        g.apply(b)
    sent_queries = {}      # regenerate each client's query sequence
    for ci in range(n_clients):
        rng = np.random.default_rng(100 + ci)
        qs = []
        for _ in range(per_client):
            roll = rng.random()
            if roll < 0.5:
                qs.append(KHop(int(rng.integers(0, n)), k=2))
            elif roll < 0.8:
                qs.append(Reachability(int(rng.integers(0, n)),
                                       int(rng.integers(0, n)),
                                       max_hops=6))
            else:
                qs.append(DegreeTopK(5))
        sent_queries[ci] = qs
    audited = 0
    for ci in range(n_clients):
        for q, r in zip(sent_queries[ci], results[ci], strict=True):
            if not r.ok:
                continue
            view = g.join_view(r.version)
            if isinstance(q, KHop):
                exp = np.asarray(gc.k_hop(view, np.array([q.source]), q.k))
                assert np.asarray(r.value).tobytes() == exp.tobytes()
            elif isinstance(q, Reachability):
                assert r.value == gc.reachability(view, q.src, q.dst,
                                                  q.max_hops)
            else:
                ids, degs = r.value
                exp_ids, exp_degs = gc.degree_topk(view, q.k)
                assert np.asarray(ids).tobytes() == \
                    np.asarray(exp_ids).tobytes()
                assert np.asarray(degs).tobytes() == \
                    np.asarray(exp_degs).tobytes()
            audited += 1
    assert audited == len(ok)


def test_rpc_stop_is_idempotent_and_releases_port():
    server, batches = _server()
    server.step(batches[0])
    front = rpc.GraphRPCServer(server, port=0).start()
    host, port = front.address
    front.stop()
    front.stop()                                # second stop is a no-op
    with pytest.raises(OSError):
        socket.create_connection((host, port), timeout=0.5)
