"""Socket RPC front for the graph query server: wire codec + listener.

This is the network half of the serving tier (``docs/ARCHITECTURE.md``,
"Serving tier"): a :class:`GraphRPCServer` puts a TCP listener in front of
one in-process :class:`~repro.launch.serve_graph.GraphQueryServer`, so
many concurrent clients share ONE store, ONE published snapshot and ONE
query scheduler — their same-kind queries collapse into the same
vectorized window, exactly as if one caller had batched them.

Wire format (deliberately dependency-free — stdlib ``socket`` + ``json``
+ ``base64``): every frame is a 4-byte big-endian unsigned length prefix
followed by that many bytes of UTF-8 JSON. Query values survive the trip
**byte-identically**: an ndarray is encoded as its dtype string, shape and
the base64 of ``tobytes()``, so the soak test's replay oracle can compare
served bytes against a single-store recompute with ``==`` on the buffers,
not an epsilon. Snapshot versions travel as their packed ``(epoch,
batch)`` int (``Version.pack``).

Request frames::

    {"op": "query", "id": <int|str>, "kind": "k_hop", "query": {...},
     "pin": <packed-version|null>, "deadline_s": <float|null>}
    {"op": "stats", "id": <int|str>}

Response frames mirror :class:`~repro.graph.query.QueryResponse`::

    {"id": ..., "ok": true,  "value": <enc>, "version": <packed>,
     "latency_s": <float>}
    {"id": ..., "ok": false, "error": {"code": "...", "message": "..."},
     "latency_s": <float>}

Threading model: one accept thread, one reader thread per connection, and
one dispatcher thread PER SCHEDULER LANE (cheap/expensive; a single
dispatcher when the server runs single-queue) that runs the shared
scheduler (``GraphQueryServer.run_window``) whenever work is queued on
its lane — so a multi-iteration PageRank window on the expensive
dispatcher never blocks the cheap dispatcher's dict-lookup windows.
Readers never execute queries — they decode, pass the typed
:class:`~repro.graph.query.QueryRequest` to ``submit_request`` with an
``on_done`` that frames the response back onto their own connection, and
go back to reading. Admission control therefore happens at the server's
single bounded queue: when it is full the shed ``ERR_OVERLOADED``
response comes back on the submitting connection immediately (written
inline by the reader), so an overloaded server degrades into fast typed
rejections instead of unbounded queueing. Per-connection write locks
(plain locals, one socket each) keep concurrently-delivered frames from
interleaving.

The dispatcher never dies with a failed window: the scheduler's
all-or-nothing contract re-queues undelivered requests, and the
dispatcher retries after a short pause — e.g. queries that race ahead of
the first global seal simply wait (their deadline, if any, still
applies).
"""
from __future__ import annotations

import base64
import dataclasses
import json
import random
import socket
import struct
import threading
import time
from typing import Callable, Optional, Union

import numpy as np

from repro.core.versioned import Version
from repro.graph.query import (ERR_BAD_QUERY, ERR_OVERLOADED, DegreeTopK,
                               KHop, PageRankQuery, Query, QueryRequest,
                               QueryResponse, Reachability)
from repro.launch.serve_graph import GraphQueryServer

_LEN = struct.Struct(">I")
MAX_FRAME = 64 * 1024 * 1024    # refuse absurd frames instead of OOMing

_QUERY_TYPES = {"k_hop": KHop, "reachability": Reachability,
                "degree_topk": DegreeTopK, "pagerank": PageRankQuery}


# ---------------------------------------------------------------- codec
def encode_frame(obj: dict) -> bytes:
    """One wire frame: 4-byte big-endian length + UTF-8 JSON body."""
    body = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    if len(body) > MAX_FRAME:
        raise ValueError(f"frame of {len(body)} bytes exceeds MAX_FRAME")
    return _LEN.pack(len(body)) + body


def read_frame(sock: socket.socket) -> Optional[dict]:
    """Read one frame off ``sock``; None on clean EOF at a frame
    boundary. Raises ``ConnectionError`` on a mid-frame disconnect and
    ``ValueError`` on an oversized length prefix."""
    header = _read_exact(sock, _LEN.size, eof_ok=True)
    if header is None:
        return None
    (length,) = _LEN.unpack(header)
    if length > MAX_FRAME:
        raise ValueError(f"frame of {length} bytes exceeds MAX_FRAME")
    body = _read_exact(sock, length, eof_ok=False)
    return json.loads(body.decode("utf-8"))


def _read_exact(sock: socket.socket, n: int, *,
                eof_ok: bool) -> Optional[bytes]:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if eof_ok and not buf:
                return None
            raise ConnectionError("peer closed mid-frame")
        buf += chunk
    return bytes(buf)


def encode_value(value) -> object:
    """JSON-encode a query answer, byte-exactly for arrays: ndarray ->
    ``{"__nd__": [dtype-str, shape, base64(tobytes())]}`` (dtype strings
    keep byte order, so decode reproduces the exact buffer); tuples ->
    ``{"__tup__": [...]}`` so (ids, degrees) pairs round-trip as tuples;
    numpy scalars -> Python scalars."""
    if isinstance(value, np.ndarray):
        arr = np.ascontiguousarray(value)
        return {"__nd__": [arr.dtype.str, list(arr.shape),
                           base64.b64encode(arr.tobytes()).decode("ascii")]}
    if isinstance(value, tuple):
        return {"__tup__": [encode_value(v) for v in value]}
    if isinstance(value, list):
        return [encode_value(v) for v in value]
    if isinstance(value, np.generic):
        return value.item()
    return value


def decode_value(enc) -> object:
    """Inverse of :func:`encode_value` (byte-identical arrays)."""
    if isinstance(enc, dict) and "__nd__" in enc:
        dtype_str, shape, b64 = enc["__nd__"]
        data = base64.b64decode(b64.encode("ascii"))
        return np.frombuffer(data, dtype=np.dtype(dtype_str)).reshape(shape)
    if isinstance(enc, dict) and "__tup__" in enc:
        return tuple(decode_value(v) for v in enc["__tup__"])
    if isinstance(enc, list):
        return [decode_value(v) for v in enc]
    return enc


def encode_query(q: Query) -> dict:
    from repro.graph.query import query_kind
    return {"kind": query_kind(q), "query": dataclasses.asdict(q)}


def decode_query(kind: str, fields: dict) -> Query:
    """Raises ``ValueError``/``TypeError`` on an unknown kind or malformed
    fields — the listener maps either to an ``ERR_BAD_QUERY`` response."""
    cls = _QUERY_TYPES.get(kind)
    if cls is None:
        raise ValueError(f"unknown query kind {kind!r}")
    return cls(**fields)


def encode_response(resp: QueryResponse) -> dict:
    out = {"id": resp.request_id, "ok": resp.ok,
           "latency_s": resp.latency_s}
    if resp.ok:
        out["value"] = encode_value(resp.value)
        out["version"] = resp.version.pack() if resp.version else None
        if resp.degraded:
            # only when set: pre-durability peers never sent the key, so
            # absence stays the healthy default on both ends of the wire
            out["degraded"] = True
    else:
        out["error"] = {"code": resp.error.code,
                        "message": resp.error.message}
    return out


def decode_response(frame: dict) -> QueryResponse:
    if frame["ok"]:
        packed = frame.get("version")
        return QueryResponse.answered(
            frame["id"], decode_value(frame["value"]),
            Version.unpack(packed) if packed is not None else None,
            frame["latency_s"], degraded=frame.get("degraded", False))
    err = frame["error"]
    return QueryResponse.failed(frame["id"], err["code"],
                                err.get("message", ""),
                                latency_s=frame["latency_s"])


# ------------------------------------------------------------- server
class GraphRPCServer:
    """TCP front over one :class:`GraphQueryServer` (see module docs for
    the wire format and threading model). ``start()`` binds and spins up
    the accept + dispatcher threads; :attr:`address` is the bound
    ``(host, port)`` — bind ``port=0`` for an ephemeral port. ``stop()``
    closes the listener and every live connection and joins the
    threads."""

    def __init__(self, server: GraphQueryServer, *,
                 host: str = "127.0.0.1", port: int = 0,
                 backlog: int = 64, batch_wait_s: float = 0.002):
        self.server = server
        self.host = host
        self.port = port
        self.backlog = backlog
        # scheduler batching window: after the first request wakes the
        # dispatcher, wait this long before running the window so
        # concurrently-arriving clients collapse into one vectorized call
        # instead of a string of size-1 windows (latency cost: one
        # batch_wait per round trip, amortized across every rider)
        self.batch_wait_s = batch_wait_s
        self._sock: Optional[socket.socket] = None
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        # guards the live-connection set (reader threads add/remove
        # themselves; stop() snapshots it to close stragglers)
        self._conn_lock = threading.Lock()
        self._conns: set[socket.socket] = set()

    @property
    def address(self) -> tuple[str, int]:
        if self._sock is None:
            raise RuntimeError("server not started")
        return self._sock.getsockname()[:2]

    def start(self) -> "GraphRPCServer":
        sock = socket.create_server((self.host, self.port),
                                    backlog=self.backlog, reuse_port=False)
        sock.settimeout(0.2)        # so the accept loop notices stop()
        self._sock = sock
        threads = [("rpc-accept", self._accept_loop, ())]
        if self.server.two_lane:
            # one dispatcher per scheduler lane: the cheap dispatcher
            # keeps draining dict-lookup/one-sweep windows while the
            # expensive dispatcher works through PageRank convoys in
            # budgeted slices — the lanes share the engine, not the queue
            threads += [
                ("rpc-dispatch-cheap", self._dispatch_loop,
                 ("cheap", self.server.work_cheap)),
                ("rpc-dispatch-exp", self._dispatch_loop,
                 ("expensive", self.server.work_expensive))]
        else:
            threads += [("rpc-dispatch", self._dispatch_loop,
                         (None, self.server.work_available))]
        for name, target, args in threads:
            t = threading.Thread(target=target, args=args, daemon=True,
                                 name=name)
            t.start()
            self._threads.append(t)
        return self

    def stop(self) -> None:
        self._stop.set()
        # wake every dispatcher flavor
        self.server.work_available.set()
        self.server.work_cheap.set()
        self.server.work_expensive.set()
        self.server.stop_prewarm()
        if self._sock is not None:
            self._sock.close()
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()
        for t in self._threads:
            t.join(timeout=5.0)

    # -- threads ----------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _addr = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return              # listener closed by stop()
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conn_lock:
                self._conns.add(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True, name="rpc-conn")
            t.start()
            self._threads.append(t)

    def _dispatch_loop(self, lane=None, work=None) -> None:
        """A thread that runs query windows for every connection — this
        is where cross-client batching happens: all requests queued on
        this dispatcher's lane since its last window (no matter which
        reader enqueued them) execute as one scheduler window. With
        ``two_lane`` there are two of these — one per lane, each waiting
        on its own wake event — so cheap windows never queue behind an
        expensive window's compute; the single-dispatcher (``lane=None``)
        flavor preserves the PR 8 behavior for the benchmark baseline."""
        if work is None:
            work = self.server.work_available
        while not self._stop.is_set():
            if not work.wait(timeout=0.2):
                continue
            if self.batch_wait_s:
                time.sleep(self.batch_wait_s)   # let a batch accumulate
            work.clear()
            try:
                self.server.run_window(lane)
            except Exception:
                # all-or-nothing window: everything undelivered was
                # re-queued (e.g. nothing sealed yet) — retry shortly
                time.sleep(0.005)
                work.set()

    def _serve_conn(self, conn: socket.socket) -> None:
        send_lock = threading.Lock()   # per-connection: frames atomic

        def reply(frame: dict) -> None:
            data = encode_frame(frame)
            try:
                with send_lock:
                    conn.sendall(data)
            except OSError:
                pass               # peer went away; reader will notice

        try:
            while not self._stop.is_set():
                try:
                    frame = read_frame(conn)
                except (ConnectionError, ValueError, OSError):
                    break
                if frame is None:
                    break
                self._handle(frame, reply)
        finally:
            with self._conn_lock:
                self._conns.discard(conn)
            conn.close()

    def _handle(self, frame: dict, reply) -> None:
        rid = frame.get("id", 0)
        op = frame.get("op")
        if op == "stats":
            s = self.server.stats()
            enc = {k: encode_value(v) for k, v in
                   dataclasses.asdict(s).items()}
            v = s.serving_version
            enc["serving_version"] = v.pack() if v is not None else None
            reply({"id": rid, "ok": True, "latency_s": 0.0, "value": enc})
            return
        if op != "query":
            reply(encode_response(QueryResponse.failed(
                rid, ERR_BAD_QUERY, f"unknown op {op!r}")))
            return
        try:
            query = decode_query(frame.get("kind"),
                                 frame.get("query") or {})
            pin = frame.get("pin")
            request = QueryRequest(
                query=query, request_id=rid,
                pin_version=(Version.unpack(pin)
                             if pin is not None else None),
                deadline_s=frame.get("deadline_s"))
        except (TypeError, ValueError, KeyError) as exc:
            reply(encode_response(QueryResponse.failed(
                rid, ERR_BAD_QUERY, str(exc))))
            return
        shed = self.server.submit_request(
            request, on_done=lambda resp: reply(encode_response(resp)))
        if shed is not None:       # typed overload/bad-query: answer NOW
            reply(encode_response(shed))


# ------------------------------------------------------------- client
class GraphRPCClient:
    """Blocking client for the wire protocol. One TCP connection; NOT
    thread-safe (give each client thread its own instance — that is
    exactly what the soak test and benchmark do).

    :meth:`query` is the synchronous round trip, with bounded
    exponential-backoff-with-jitter retry over two transient failure
    classes: a typed ``ERR_OVERLOADED`` shed, and transport faults
    (connect refused, EOF/reset mid-round-trip, socket timeout) — the
    latter reconnect before retrying. Retries honor ``deadline_s`` as a
    total budget: the client never sleeps past the deadline, and when it
    gives up it surfaces the ORIGINAL typed response (or re-raises the
    transport error when there was none). Non-retryable typed errors
    (``ERR_BAD_QUERY``, ``ERR_BAD_PIN``, ``ERR_DEADLINE``, ...) return
    immediately. Retried queries are at-least-once: a transport fault
    after the server executed but before the response landed replays the
    request — safe here because every query is a read at a sealed
    snapshot.

    :meth:`send`/:meth:`recv` expose the raw pipelined half-steps (no
    retry — a pipeliner owns its own in-flight bookkeeping): keep several
    requests in flight on one connection and collect responses (matched
    by ``request_id``; the server may answer out of submission order
    across windows)."""

    def __init__(self, host: str, port: int, *,
                 timeout_s: Optional[float] = 30.0,
                 max_retries: int = 5, retry_base_s: float = 0.01,
                 retry_cap_s: float = 0.5,
                 jitter: Optional[Callable[[], float]] = None):
        self.host = host
        self.port = port
        self.timeout_s = timeout_s
        self.max_retries = int(max_retries)
        self.retry_base_s = float(retry_base_s)
        self.retry_cap_s = float(retry_cap_s)
        # jitter source in [0, 1); injectable so the retry tests pin the
        # sleep schedule deterministically
        self._jitter = random.random if jitter is None else jitter
        self._sock: Optional[socket.socket] = None
        self._next_id = 1
        self._connect()

    def _connect(self) -> None:
        sock = socket.create_connection((self.host, self.port),
                                        timeout=self.timeout_s)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock = sock

    def _drop(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _backoff(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (0-based): exponential from
        ``retry_base_s``, capped at ``retry_cap_s``, half-jittered into
        ``[b/2, b]`` so a thundering herd of shed clients decorrelates
        without ever retrying immediately."""
        b = min(self.retry_cap_s, self.retry_base_s * (2.0 ** attempt))
        return b * (0.5 + 0.5 * self._jitter())

    def close(self) -> None:
        self._drop()

    def __enter__(self) -> "GraphRPCClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def send(self, q: Query, *, pin_version: Optional[Version] = None,
             deadline_s: Optional[float] = None,
             request_id: Union[int, str, None] = None) -> Union[int, str]:
        """Frame one query request onto the wire (no wait, no retry).
        Returns the request id the response will carry. Reconnects first
        if a previous transport fault dropped the connection."""
        if self._sock is None:
            self._connect()
        if request_id is None:
            request_id = self._next_id
            self._next_id += 1
        frame = {"op": "query", "id": request_id, **encode_query(q),
                 "pin": pin_version.pack() if pin_version else None,
                 "deadline_s": deadline_s}
        self._sock.sendall(encode_frame(frame))
        return request_id

    def recv(self) -> QueryResponse:
        """Block for the next response frame on this connection."""
        if self._sock is None:
            raise ConnectionError("not connected")
        frame = read_frame(self._sock)
        if frame is None:
            raise ConnectionError("server closed the connection")
        return decode_response(frame)

    def query(self, q: Query, *, pin_version: Optional[Version] = None,
              deadline_s: Optional[float] = None) -> QueryResponse:
        """One synchronous query round trip (single request in flight, so
        the next response is necessarily ours), retried per the class
        docs. ``deadline_s`` is the TOTAL budget across retries; each
        attempt ships the remaining budget so the server's own deadline
        shedding stays consistent with the client's."""
        deadline_at = (time.monotonic() + deadline_s
                       if deadline_s is not None else None)
        shed: Optional[QueryResponse] = None
        error: Optional[OSError] = None
        for attempt in range(self.max_retries + 1):
            budget = deadline_s
            if deadline_at is not None:
                budget = max(0.0, deadline_at - time.monotonic())
            try:
                self.send(q, pin_version=pin_version, deadline_s=budget)
                resp = self.recv()
            except (ConnectionError, OSError) as exc:
                self._drop()        # reconnect lazily on the next attempt
                error = exc
            else:
                if resp.ok or resp.error.code != ERR_OVERLOADED:
                    return resp
                shed, error = resp, None
            if attempt >= self.max_retries:
                break
            delay = self._backoff(attempt)
            if deadline_at is not None and \
                    time.monotonic() + delay > deadline_at:
                break               # never sleep past the deadline
            time.sleep(delay)
        if shed is not None:
            return shed             # the original typed response
        raise error

    def stats(self) -> dict:
        """Server stats snapshot (``ServerStats`` fields as a dict;
        ``serving_version`` as a packed int or None)."""
        if self._sock is None:
            self._connect()
        self._sock.sendall(encode_frame({"op": "stats",
                                         "id": self._next_id}))
        self._next_id += 1
        frame = read_frame(self._sock)
        if frame is None:
            raise ConnectionError("server closed the connection")
        return decode_value(frame["value"])
