"""Durable graph plane: write-ahead mutation log, graph checkpoints, and
shard fault injection.

The sharded store (``graph/sharded.py``) is fast but volatile: nothing in
the ingest path touches disk, so a crash loses the whole graph. This
module adds the three durability primitives the store wires together (see
``docs/ARCHITECTURE.md`` "Durability & recovery" for the correctness
argument):

**Write-ahead mutation log.** Every sealed ``(shard, epoch)`` appends one
record to the shard's segment file: the epoch's already-byte-stable
``(kind, a, b, packed32_version)`` int32 payload rows, exactly as the
seal applied them. Records are length-prefixed with a CRC32 over the
packed seal version + body, so replaying a shard's records through
``decode_payloads`` + ``DynamicGraph.apply`` reproduces the shard
byte-for-byte. A record is written for EVERY seal — empty epochs write a
zero-row record — which is what makes the durable frontier well defined
(an epoch is durable iff its commit record exists in the control log AND
every shard alive at that epoch has an intact record for it).

Failure handling is asymmetric by design: an *incomplete* record at the
end of a segment is a torn write (the process died mid-append) — it is
truncated away with a warning and recovery proceeds at the durable
frontier. A *complete* record whose CRC does not match, or a length
prefix that cannot frame a record, is corruption — :class:`
WalCorruptionError` names the segment and byte offset and recovery
refuses to guess.

**Control log.** One per store (``control.wal``, same framing, JSON
bodies): a ``meta`` record with the store's construction parameters, one
``plan`` record per re-sharding cutover (the ``RoutingPlan`` history
entry plus the migrated row count), and one ``commit`` record per
globally-sealed epoch carrying the user-ingested packed versions of that
epoch — what lets recovery reconstruct ``latest_sealed()`` exactly
(migration rows are not ingested versions).

**Fsync policy.** ``"always"`` fsyncs every append (maximum durability),
``"batch"`` (the default) group-commits: fsync every ``fsync_every``
records and at rotation/close — the knob the < 15% WAL-overhead
benchmark gate assumes — and ``"never"`` leaves flushing to the OS. The
durable frontier takes the *minimum* over commit and shard-record
completeness, so a lost unsynced suffix degrades recovery depth, never
correctness — which is exactly why a generous batch cadence is safe: the
checkpoint ladder (rotation fsyncs on close) bounds replay depth
independently of the sync count.

**Rotation & truncation.** Segments rotate when a graph checkpoint lands
(:class:`GraphCheckpointManager` snapshots the per-shard stamp/edge
arrays plus plan history and access ledger); segments whose epochs the
checkpoint covers are deleted. The control log is never truncated — it
is the authoritative plan/commit history and grows ~100 bytes per epoch.

**Fault injection.** :class:`FaultInjector` is the seal plane's chaos
hook: the store consults it at seal entry, so an injected fault aborts
the epoch *before* any apply — the epoch stays pending and re-sealable
(invariant I6) and the serving layer keeps answering at the last
published snapshot (degraded mode, invariant I11).

Thread-safety: each :class:`ShardWal` is owned by exactly one shard's
seal and is only ever touched by that shard's apply-plane thread (plus
the serial thread between epochs); :class:`GraphWal`'s control-file
state is guarded by its writer lock (``reprolint`` pins the relation).
"""
from __future__ import annotations

import json
import os
import pathlib
import struct
import threading
import time
import warnings
import zlib
from typing import Optional

import numpy as np

from repro.core.versioned import Version
from repro.train.checkpoint import CheckpointManager

# record header: (body length, crc32 over packed+body, packed seal version)
_HDR = struct.Struct(">IIQ")
_PACKED = struct.Struct(">Q")
ROW_BYTES = 16                  # one (kind, a, b, version) int32 payload row
MAX_BODY = 1 << 30              # framing sanity bound: 64M rows per record
_EMPTY_ROWS = np.zeros((0, 4), np.int32)


class WalCorruptionError(RuntimeError):
    """Mid-segment WAL corruption: a complete record whose CRC does not
    match, or a frame that cannot be parsed. Names the segment and byte
    offset; unlike a torn tail this is never silently truncated."""

    def __init__(self, segment, offset: int, reason: str):
        self.segment = str(segment)
        self.offset = int(offset)
        self.reason = reason
        super().__init__(f"{self.segment} @ byte {self.offset}: {reason}")


class ShardFaultError(RuntimeError):
    """A fault injected into a shard's seal (see :class:`FaultInjector`).
    Raised at seal entry, before any apply, so the epoch stays cleanly
    pending and re-sealable."""


def encode_record(packed_version: int, body: bytes) -> bytes:
    """Frame one WAL record: length-prefixed, CRC32 over the packed seal
    version + body (so a swapped version field fails the checksum too)."""
    crc = zlib.crc32(body, zlib.crc32(_PACKED.pack(packed_version)))
    return _HDR.pack(len(body), crc, packed_version) + body


def rows_to_body(rows: np.ndarray) -> bytes:
    """Payload rows -> byte-stable record body (little-endian int32,
    C-order — the same bytes on every platform)."""
    return np.ascontiguousarray(rows, dtype="<i4").tobytes()


def body_to_rows(body: bytes, segment, offset: int) -> np.ndarray:
    """Record body -> ``(N, 4)`` int32 payload rows; a body that is not a
    whole number of rows is corruption, not a torn write (framing already
    proved the record complete)."""
    if len(body) % ROW_BYTES:
        raise WalCorruptionError(
            segment, offset,
            f"body of {len(body)} bytes is not a whole number of "
            f"{ROW_BYTES}-byte payload rows")
    return np.frombuffer(body, "<i4").reshape(-1, 4).astype(np.int32,
                                                            copy=False)


def scan_segment(path, *, tail_ok: bool = True
                 ) -> tuple[list[tuple[int, bytes, int]], int]:
    """Parse one segment file into ``[(packed_version, body, offset)]``
    plus the clean byte length (where a torn tail, if any, starts).

    A record cut off by the end of the file is a torn write: warn and
    stop (the caller may truncate at the returned clean length). With
    ``tail_ok=False`` (non-final segments, which rotation closed after a
    complete record) even a torn tail raises. A complete record failing
    its CRC, or an unframeable length prefix, always raises
    :class:`WalCorruptionError`.
    """
    data = pathlib.Path(path).read_bytes()
    records: list[tuple[int, bytes, int]] = []
    off = 0
    size = len(data)
    while off < size:
        if size - off < _HDR.size:
            break                       # torn mid-header
        body_len, crc, packed = _HDR.unpack_from(data, off)
        if body_len > MAX_BODY:
            raise WalCorruptionError(
                path, off, f"length prefix {body_len} exceeds the "
                f"{MAX_BODY}-byte record bound")
        end = off + _HDR.size + body_len
        if end > size:
            break                       # torn mid-body
        body = data[off + _HDR.size:end]
        want = zlib.crc32(body, zlib.crc32(_PACKED.pack(packed)))
        if want != crc:
            raise WalCorruptionError(
                path, off, f"CRC mismatch (stored {crc:#010x}, "
                f"computed {want:#010x})")
        records.append((packed, body, off))
        off = end
    if off < size:
        if not tail_ok:
            raise WalCorruptionError(
                path, off, f"{size - off} trailing bytes in a closed "
                "segment (rotation always ends on a record boundary)")
        warnings.warn(
            f"torn WAL tail in {path}: dropping {size - off} bytes at "
            f"offset {off} (incomplete record from an interrupted append)",
            stacklevel=2)
    return records, off


def _fsync_file(f) -> None:
    f.flush()
    os.fsync(f.fileno())


class ShardWal:
    """Append-only per-shard WAL: one record per sealed epoch, segment
    files named by their first epoch (``seg-<epoch>.wal``).

    Owned by exactly one shard — the store keeps these in a shard-indexed
    list so the seal closure (which may run on the parallel apply plane)
    only ever touches its own writer; no lock is needed (reprolint's
    seal-plane rules treat the list like the other shard-owned state).
    """

    def __init__(self, directory, shard_id: int, *, fsync: str = "batch",
                 fsync_every: int = 32):
        if fsync not in ("always", "batch", "never"):
            raise ValueError(f"unknown fsync policy {fsync!r}")
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.shard_id = shard_id
        self.fsync = fsync
        self.fsync_every = int(fsync_every)
        self._f = None
        self._path: Optional[pathlib.Path] = None
        self._since_sync = 0

    def _open(self, start_epoch: int) -> None:
        self._path = self.dir / f"seg-{start_epoch:08d}.wal"
        self._f = open(self._path, "ab")

    def append(self, epoch: int, rows: np.ndarray) -> None:
        """Append the sealed epoch's payload rows (possibly zero rows —
        every seal writes a record so the durable frontier stays well
        defined). Writes the same bytes as :func:`encode_record` +
        :func:`rows_to_body` but CRCs and writes straight from the array
        buffer — this is the ingest hot path the < 15% overhead gate
        measures, and the intermediate ``tobytes``/concat copies were a
        third of its cost."""
        if self._f is None:
            self._open(epoch)
        packed = Version(epoch, 0).pack()
        arr = np.ascontiguousarray(rows, dtype="<i4")
        body = memoryview(arr).cast("B") if arr.size else b""
        crc = zlib.crc32(body, zlib.crc32(_PACKED.pack(packed)))
        self._f.write(_HDR.pack(len(body), crc, packed))
        self._f.write(body)
        if self.fsync == "always":
            _fsync_file(self._f)
        elif self.fsync == "batch":
            self._since_sync += 1
            if self._since_sync >= self.fsync_every:
                _fsync_file(self._f)
                self._since_sync = 0

    def sync(self) -> None:
        if self._f is not None and self.fsync != "never":
            _fsync_file(self._f)
            self._since_sync = 0

    def close(self) -> None:
        if self._f is not None:
            if self.fsync != "never":
                _fsync_file(self._f)
            self._f.close()
            self._f = None

    def rotate(self, start_epoch: int) -> None:
        """Close the current segment (on a record boundary — which is why
        only the newest segment may carry a torn tail) and start a fresh
        one for ``start_epoch``. Keyed to the checkpoint ladder: the
        store rotates when a checkpoint lands."""
        self.close()
        self._open(start_epoch)

    def drop_segments_below(self, start_epoch: int) -> int:
        """Delete closed segments whose first epoch precedes
        ``start_epoch`` — called after a checkpoint covering them landed
        durably. Returns the number of segments dropped."""
        dropped = 0
        for p in sorted(self.dir.glob("seg-*.wal")):
            if p != self._path and _segment_start(p) < start_epoch:
                p.unlink()
                dropped += 1
        return dropped

    def segments(self) -> list[pathlib.Path]:
        return sorted(self.dir.glob("seg-*.wal"), key=_segment_start)


def _segment_start(path: pathlib.Path) -> int:
    return int(path.stem.split("-", 1)[1])


def scan_shard_records(directory) -> dict[int, tuple[np.ndarray,
                                                     pathlib.Path, int]]:
    """Read a shard's whole WAL: ``{epoch: (rows, segment, offset)}``.

    Only the newest segment may end in a torn tail (older ones were
    closed on a record boundary by rotation); corruption raises. Offsets
    let recovery truncate complete-but-uncommitted records away so a
    re-seal after recovery cannot double-append.
    """
    segs = sorted(pathlib.Path(directory).glob("seg-*.wal"),
                  key=_segment_start)
    out: dict[int, tuple[np.ndarray, pathlib.Path, int]] = {}
    for i, seg in enumerate(segs):
        records, _ = scan_segment(seg, tail_ok=(i == len(segs) - 1))
        for packed, body, off in records:
            epoch = Version.unpack(packed).epoch
            out[epoch] = (body_to_rows(body, seg, off), seg, off)
    return out


def truncate_shard_after(directory, last_epoch: int) -> int:
    """Drop every record with epoch > ``last_epoch`` from a shard's WAL
    (they are a suffix: epochs append in order). Returns records dropped.
    Recovery calls this so re-ingested epochs re-append cleanly."""
    dropped = 0
    for seg in sorted(pathlib.Path(directory).glob("seg-*.wal"),
                      key=_segment_start, reverse=True):
        records, clean = scan_segment(seg)
        keep = [off for packed, _, off in records
                if Version.unpack(packed).epoch <= last_epoch]
        if len(keep) == len(records) and clean == seg.stat().st_size:
            break                       # nothing newer remains below
        dropped += len(records) - len(keep)
        if keep:
            cut = records[len(keep)][2] if len(keep) < len(records) \
                else clean
            with open(seg, "r+b") as f:
                f.truncate(cut)
            break
        seg.unlink()
    return dropped


class GraphWal:
    """Store-level WAL manager: the control log plus the per-shard
    segment-writer factory.

    The control log records, in append order: one ``meta`` record (store
    construction parameters), a ``plan`` record per re-sharding cutover,
    and a ``commit`` record per globally-sealed epoch (its user-ingested
    packed versions). Bodies are JSON; framing and failure handling are
    shared with the shard segments. ``_lock`` is the WAL writer lock
    guarding the control-file handle and its fsync batcher (the store's
    serial thread is the only caller today; the lock pins the discipline
    for the multi-host plane the ROADMAP sketches).
    """

    def __init__(self, directory, *, fsync: str = "batch",
                 fsync_every: int = 32):
        if fsync not in ("always", "batch", "never"):
            raise ValueError(f"unknown fsync policy {fsync!r}")
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.fsync = fsync
        self.fsync_every = int(fsync_every)
        self._lock = threading.Lock()
        self._control_f = open(self.control_path(self.dir), "ab")
        self._control_synced = 0

    @staticmethod
    def control_path(directory) -> pathlib.Path:
        return pathlib.Path(directory) / "control.wal"

    @staticmethod
    def shard_dir(directory, shard_id: int) -> pathlib.Path:
        return pathlib.Path(directory) / f"shard-{shard_id:04d}"

    def shard_wal(self, shard_id: int) -> ShardWal:
        return ShardWal(self.shard_dir(self.dir, shard_id), shard_id,
                        fsync=self.fsync, fsync_every=self.fsync_every)

    # -- control appends ---------------------------------------------------
    def _append_control(self, epoch: int, record: dict) -> None:
        payload = json.dumps(record, sort_keys=True).encode()
        framed = encode_record(Version(max(epoch, 0), 0).pack(), payload)
        with self._lock:
            self._control_f.write(framed)
            if self.fsync == "always":
                _fsync_file(self._control_f)
            elif self.fsync == "batch":
                self._control_synced += 1
                if self._control_synced >= self.fsync_every:
                    _fsync_file(self._control_f)
                    self._control_synced = 0

    def write_meta(self, params: dict) -> None:
        self._append_control(0, {"type": "meta", **params})

    def record_plan_event(self, op: str, a: int, b: int,
                          activation: int, migrated: int) -> None:
        """One record per re-sharding cutover — the durable twin of the
        ``RoutingPlan`` history entry ``(op, a, b, activation)`` (for a
        split, ``a``/``b`` are source/new shard; for a merge,
        survivor/removed), plus the migrated row count the store's
        ``migrations`` telemetry keeps."""
        self._append_control(activation, {
            "type": "plan", "op": op, "a": a, "b": b,
            "activation": activation, "migrated": migrated})

    def commit_epoch(self, epoch: int, ingested_packed: list[int]) -> None:
        """Mark ``epoch`` globally sealed, carrying its user-ingested
        packed versions (the entries ``latest_sealed()`` answers from;
        migration rows are deliberately absent)."""
        self._append_control(epoch, {
            "type": "commit", "epoch": epoch,
            "versions": [int(v) for v in ingested_packed]})

    def sync(self) -> None:
        with self._lock:
            if self.fsync != "never":
                _fsync_file(self._control_f)
                self._control_synced = 0

    def close(self) -> None:
        with self._lock:
            if self.fsync != "never":
                _fsync_file(self._control_f)
            self._control_f.close()

    # -- control scan (recovery) -------------------------------------------
    @staticmethod
    def read_control(directory) -> tuple[Optional[dict], list[dict],
                                         dict[int, list[int]]]:
        """Parse the control log: ``(meta, plan_events, commits)``.
        ``commits`` maps epoch -> the user-ingested packed versions of
        that epoch. Torn tail warns; corruption raises."""
        path = GraphWal.control_path(directory)
        meta: Optional[dict] = None
        events: list[dict] = []
        commits: dict[int, list[int]] = {}
        if not path.exists():
            return meta, events, commits
        records, _ = scan_segment(path)
        for _, body, off in records:
            try:
                rec = json.loads(body)
            except ValueError as exc:
                raise WalCorruptionError(
                    path, off, f"undecodable control record: {exc}") \
                    from exc
            kind = rec.get("type")
            if kind == "meta":
                meta = rec
            elif kind == "plan":
                events.append(rec)
            elif kind == "commit":
                commits[rec["epoch"]] = rec["versions"]
            else:
                raise WalCorruptionError(
                    path, off, f"unknown control record type {kind!r}")
        return meta, events, commits

    @staticmethod
    def truncate_control_after(directory, last_epoch: int) -> None:
        """Drop commit records with epoch > ``last_epoch`` and plan
        records with activation > ``last_epoch`` (always a suffix —
        control records append in epoch order)."""
        path = GraphWal.control_path(directory)
        if not path.exists():
            return
        records, clean = scan_segment(path)
        cut = clean
        for _, body, off in records:
            rec = json.loads(body)
            beyond = (rec.get("type") == "commit"
                      and rec["epoch"] > last_epoch) or \
                     (rec.get("type") == "plan"
                      and rec["activation"] > last_epoch)
            if beyond:
                cut = off
                break
        if cut < path.stat().st_size:
            with open(path, "r+b") as f:
                f.truncate(cut)


class FaultInjector:
    """Seal-plane chaos hook: kill, stall, or drop a shard's seal.

    The store consults :meth:`check` at seal ENTRY — before any apply —
    so an injected fault aborts the epoch as a clean no-op: the epoch
    stays pending and re-sealable (invariant I6), the global frontier
    holds, and the serving layer degrades to the last published snapshot
    instead of ever exposing a partial one.

    ``fail`` arms a one-shot fault (optionally for one specific epoch);
    ``drop`` takes a shard down persistently until :meth:`heal`;
    ``stall`` delays the seal without failing it (the slow-shard story).
    Thread-safe: seals consult it from the parallel apply plane.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._fail_once: dict[int, Optional[int]] = {}
        self._down: set[int] = set()
        self._stall: dict[int, float] = {}
        self.faults_fired = 0

    def fail(self, shard_id: int, epoch: Optional[int] = None) -> None:
        """Arm a one-shot seal failure on ``shard_id`` (any epoch, or
        only ``epoch``)."""
        with self._lock:
            self._fail_once[shard_id] = epoch

    def drop(self, shard_id: int) -> None:
        """Take a shard down: every seal fails until :meth:`heal`."""
        with self._lock:
            self._down.add(shard_id)

    def stall(self, shard_id: int, seconds: float) -> None:
        """Delay (without failing) the shard's next seals by ``seconds``
        each until cleared by ``stall(shard, 0)`` or :meth:`heal`."""
        with self._lock:
            if seconds > 0:
                self._stall[shard_id] = float(seconds)
            else:
                self._stall.pop(shard_id, None)

    def heal(self, shard_id: Optional[int] = None) -> None:
        """Clear faults for one shard (or all, when None)."""
        with self._lock:
            if shard_id is None:
                self._fail_once.clear()
                self._down.clear()
                self._stall.clear()
            else:
                self._fail_once.pop(shard_id, None)
                self._down.discard(shard_id)
                self._stall.pop(shard_id, None)

    def check(self, shard_id: int, epoch: int) -> None:
        """Called by the store at seal entry; raises
        :class:`ShardFaultError` for an armed fault. Sleeps (outside the
        injector lock) for an armed stall."""
        fire = False
        with self._lock:
            delay = self._stall.get(shard_id, 0.0)
            if shard_id in self._down:
                fire = True
            elif shard_id in self._fail_once:
                want = self._fail_once[shard_id]
                if want is None or want == epoch:
                    del self._fail_once[shard_id]
                    fire = True
            if fire:
                self.faults_fired += 1
        if delay > 0:
            time.sleep(delay)
        if fire:
            raise ShardFaultError(
                f"injected fault: shard {shard_id} cannot seal epoch "
                f"{epoch}")


class GraphCheckpointManager(CheckpointManager):
    """Durable snapshots of a whole :class:`ShardedDynamicGraph`.

    Extends the train plane's :class:`CheckpointManager` (crash-atomic
    ``.npz`` + manifest, versioned GC) with a graph-shaped state dict:
    per-shard stamp/edge arrays trimmed to ``n_edges``, the vertex
    table, and a JSON ``meta`` leaf (plan history, retired set,
    migrations, access ledger scalars, ingest log) encoded as a uint8
    array so one ``.npz`` holds the whole store. ``load_graph`` bypasses
    ``restore``'s like-structure protocol: recovery has no live store to
    mirror yet.
    """

    def save_graph(self, store, *, epoch: int) -> None:
        meta = {
            "epoch": int(epoch),
            "plan_history": [list(ev) for ev in store.plan.history],
            "retired": sorted(store.retired),
            "migrations": store.migrations,
            "last_version": int(store._last_version),
            "ingested_packed": [int(v) for v in store._ingested_packed],
            "stats": {
                "mutations": store.access_stats.mutations.tolist(),
                "queries": store.access_stats.queries.tolist(),
                "epochs_observed": store.access_stats.epochs_observed,
            },
        }
        state: dict = {
            "meta": np.frombuffer(
                json.dumps(meta, sort_keys=True).encode(), np.uint8),
            "vertex_heat": store.access_stats.vertex_heat,
        }
        for i, shard in enumerate(store.shards):
            e = shard.n_edges
            last = shard.versions[-1].pack() if shard.versions else -1
            state[f"shard_{i}"] = {
                "src": shard.src[:e].copy(),
                "dst": shard.dst[:e].copy(),
                "created": shard.created[:e].copy(),
                "deleted": shard.deleted[:e].copy(),
                "v_created": shard.v_created.copy(),
                "v_type": shard.v_type.copy(),
                "last_version": np.asarray(last, np.int64),
            }
        self.save(state, epoch=epoch, step=0)

    def load_graph(self) -> Optional[dict]:
        """Latest graph checkpoint as ``{"epoch", "meta", "shards"}`` (or
        None when no checkpoint exists). ``shards`` is a list of array
        dicts, index == shard id."""
        versions = self.index.versions("ckpt")
        if not versions:
            return None
        fname = self.index.get("ckpt", versions[-1])
        with np.load(self.dir / fname) as z:
            flat = {k: z[k] for k in z.files}
        meta = json.loads(bytes(flat.pop("meta").tobytes()).decode())
        heat = flat.pop("vertex_heat")
        shards: list[dict] = []
        i = 0
        while f"shard_{i}/src" in flat:
            shards.append({k: flat[f"shard_{i}/{k}"]
                           for k in ("src", "dst", "created", "deleted",
                                     "v_created", "v_type",
                                     "last_version")})
            i += 1
        return {"epoch": meta["epoch"], "meta": meta,
                "vertex_heat": heat, "shards": shards}
