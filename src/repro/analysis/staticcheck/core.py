"""reprolint core: rule registry, suppressions, baselines, gating.

The checker families (``lockcheck``, ``tracecheck``, ``stampcheck``,
``sealcheck``) register *rules* (an id like ``RL001`` plus a one-line
summary) and *checkers* (callables that take a parsed module and yield
:class:`Finding`s). This module owns everything family-agnostic:

* the registries and the ``register_rule`` / ``register_checker`` hooks,
* per-line suppressions — ``# reprolint: disable=RL001`` (or
  ``disable=all``) on the flagged line silences it, and
  ``# reprolint: disable-file=RL001`` anywhere silences the whole file,
* path scoping — each checker declares the directory names it applies to
  (the lock checker runs everywhere; trace-stability only makes sense
  where jitted code lives). Files under a ``staticcheck_fixtures``
  directory bypass scoping so the fixture corpus exercises every rule,
* output (human one-line-per-finding, ``--json``) and the committed
  baseline: a ``{"RULE:path": count}`` map of deliberately-kept findings;
  :func:`gate` fails only on findings *beyond* the baseline allowance.

Checkers are pure AST analyses — nothing is imported or executed, so the
suite runs on any tree (including the known-violation fixtures) without
needing its dependencies.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from typing import Callable, Iterable, Optional

# rule id -> one-line summary (what the rule enforces)
RULES: dict[str, str] = {}
# checker callables, each with a `.scope` attribute (dir-name frozenset or
# None for everywhere) attached by register_checker
CHECKERS: list[Callable] = []

_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*disable=([A-Za-z0-9_,\s]+)")
_SUPPRESS_FILE_RE = re.compile(
    r"#\s*reprolint:\s*disable-file=([A-Za-z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source location."""
    path: str            # repo-relative, '/'-separated
    line: int            # 1-indexed
    col: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclasses.dataclass
class FileContext:
    """Everything a checker gets about one file."""
    path: pathlib.Path
    rel: str
    source: str
    tree: ast.Module

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        return Finding(self.rel, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), rule, message)


def register_rule(rule_id: str, summary: str) -> str:
    if rule_id in RULES:
        raise ValueError(f"rule {rule_id} registered twice")
    RULES[rule_id] = summary
    return rule_id


def register_checker(scope: Optional[Iterable[str]] = None):
    """Decorator: register ``fn(ctx) -> Iterable[Finding]``. ``scope`` is
    the set of path segments (directory names) the checker applies to;
    None applies everywhere."""
    def deco(fn):
        fn.scope = frozenset(scope) if scope is not None else None
        CHECKERS.append(fn)
        return fn
    return deco


def checker_applies(checker: Callable, rel: str) -> bool:
    parts = rel.replace("\\", "/").split("/")
    if "staticcheck_fixtures" in parts:
        return True          # the fixture corpus exercises every rule
    return checker.scope is None or bool(checker.scope.intersection(parts))


# ----------------------------------------------------------- suppressions
def _suppressed_rules(line: str) -> Optional[set[str]]:
    m = _SUPPRESS_RE.search(line)
    if not m:
        return None
    return {r.strip() for r in m.group(1).split(",") if r.strip()}


def file_suppressions(source: str) -> tuple[dict[int, set[str]], set[str]]:
    """(per-line rule sets by 1-indexed line, whole-file rule set)."""
    per_line: dict[int, set[str]] = {}
    whole: set[str] = set()
    for i, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_FILE_RE.search(line)
        if m:
            whole |= {r.strip() for r in m.group(1).split(",") if r.strip()}
            continue
        rules = _suppressed_rules(line)
        if rules:
            per_line[i] = rules
    return per_line, whole


def apply_suppressions(findings: Iterable[Finding],
                       source: str) -> list[Finding]:
    per_line, whole = file_suppressions(source)
    out = []
    for f in findings:
        if f.rule in whole or "all" in whole:
            continue
        rules = per_line.get(f.line, ())
        if f.rule in rules or "all" in rules:
            continue
        out.append(f)
    return out


# ------------------------------------------------------------- file runner
def check_source(source: str, rel: str,
                 path: Optional[pathlib.Path] = None) -> list[Finding]:
    """Run every in-scope checker over one source blob."""
    tree = ast.parse(source, filename=rel)
    ctx = FileContext(path or pathlib.Path(rel), rel, source, tree)
    findings: list[Finding] = []
    for checker in CHECKERS:
        if checker_applies(checker, rel):
            findings.extend(checker(ctx))
    return sorted(set(apply_suppressions(findings, source)))


def check_file(path: pathlib.Path, root: pathlib.Path) -> list[Finding]:
    rel = path.resolve().relative_to(root.resolve()).as_posix()
    return check_source(path.read_text(), rel, path)


def iter_python_files(paths: Iterable[pathlib.Path],
                      exclude_parts: Iterable[str] = ()) -> list[pathlib.Path]:
    exclude = set(exclude_parts)
    out: list[pathlib.Path] = []
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            out.append(p)
            continue
        for f in sorted(p.rglob("*.py")):
            if not exclude.intersection(f.parts):
                out.append(f)
    return out


def check_paths(paths: Iterable[pathlib.Path], root: pathlib.Path,
                exclude_parts: Iterable[str] = ()) -> list[Finding]:
    findings: list[Finding] = []
    for f in iter_python_files(paths, exclude_parts):
        findings.extend(check_file(f, root))
    return sorted(findings)


# ---------------------------------------------------------------- baseline
def baseline_key(f: Finding) -> str:
    return f"{f.rule}:{f.path}"


def load_baseline(path: pathlib.Path) -> dict[str, int]:
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    return {str(k): int(v) for k, v in data.get("findings", {}).items()}


def gate(findings: list[Finding],
         baseline: dict[str, int]) -> tuple[list[Finding], dict[str, int]]:
    """Split findings into (new beyond baseline, per-key counts used).

    A baseline entry ``"RL001:src/x.py": 2`` allows two RL001 findings in
    that file; the third (and any finding with no entry) is *new*. Which
    findings inside an allowed group are 'the' baselined ones is
    irrelevant to gating, so the first N by location are absorbed.
    """
    used: dict[str, int] = {}
    new: list[Finding] = []
    for f in findings:
        key = baseline_key(f)
        if used.get(key, 0) < baseline.get(key, 0):
            used[key] = used.get(key, 0) + 1
        else:
            new.append(f)
    return new, used


def to_json(findings: list[Finding]) -> str:
    return json.dumps(
        {"findings": [dataclasses.asdict(f) for f in findings],
         "count": len(findings)}, indent=2)
