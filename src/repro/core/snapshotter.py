"""Asynchronous global-snapshot progress tracking — paper §2.3.1 (Fig 4).

Kineograph uses a *central* snapshoter: all mutations of epoch e+1 wait until
the global snapshot of epoch e is sealed. The paper's improvement (which we
implement) is *no-wait dispatch*: the ingest node only checks that the target
data node's **local** snapshot frontier covers the previous epochs; mutations
from different epochs dispatch concurrently. The global snapshot frontier is
the min over local frontiers and advances in the background (in the real
system via a Paxos quorum; here a deterministic state machine with the same
external guarantees — see DESIGN.md §2 'Paxos').

Invariants (property-tested):
  * the global frontier is monotone non-decreasing,
  * a computation scheduled on snapshot v only launches once global >= v,
  * dispatch never blocks on the *global* frontier (only on the target
    node's local frontier).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable, Optional

from repro.core.versioned import Version


@dataclasses.dataclass
class Mutation:
    key: int          # routing key (e.g. destination vertex id)
    epoch: int
    payload: object = None


class DataNode:
    """Holds a shard of the data; seals local snapshots per epoch."""

    def __init__(self, node_id: int):
        self.node_id = node_id
        self.pending: dict[int, list[Mutation]] = defaultdict(list)
        self.local_frontier = -1          # highest epoch locally sealed
        self.applied: list[Mutation] = []

    def receive(self, mut: Mutation) -> None:
        self.pending[mut.epoch].append(mut)

    def seal_epoch(self, epoch: int) -> None:
        """Define the local snapshot for `epoch` (applies its mutations)."""
        if epoch != self.local_frontier + 1:
            raise ValueError(
                f"node {self.node_id}: seal {epoch} out of order "
                f"(local frontier {self.local_frontier})")
        self.applied.extend(self.pending.pop(epoch, []))
        self.local_frontier = epoch


class SnapshotCoordinator:
    """Tracks the global frontier = min(local frontiers); runs callbacks of
    computations whose snapshot dependency becomes available."""

    def __init__(self, nodes: list[DataNode]):
        self.nodes = nodes
        self._global = -1
        self._waiting: list[tuple[int, Callable[[], None]]] = []
        self._history: list[int] = []

    @property
    def global_frontier(self) -> int:
        return self._global

    def advance(self) -> int:
        new = min(n.local_frontier for n in self.nodes)
        if new < self._global:
            raise AssertionError("global snapshot frontier went backwards")
        self._global = new
        self._history.append(new)
        still = []
        for epoch, cb in self._waiting:
            if epoch <= self._global:
                cb()
            else:
                still.append((epoch, cb))
        self._waiting = still
        return self._global

    def schedule_on_snapshot(self, epoch: int, fn: Callable[[], None]):
        """Paper: 'the computing is launched until all the global snapshots
        it will process become available'."""
        if epoch <= self._global:
            fn()
        else:
            self._waiting.append((epoch, fn))


class IngestNode:
    """Dispatches mutations asynchronously (paper's no-wait rule)."""

    def __init__(self, nodes: list[DataNode], route: Callable[[int], int]):
        self.nodes = nodes
        self.route = route
        self.blocked: list[Mutation] = []
        self.dispatched = 0

    def dispatch(self, mut: Mutation) -> bool:
        """Dispatch if the target node's LOCAL snapshot of all previous
        epochs is defined; never consults the global frontier."""
        node = self.nodes[self.route(mut.key)]
        if node.local_frontier >= mut.epoch - 1:
            node.receive(mut)
            self.dispatched += 1
            return True
        self.blocked.append(mut)
        return False

    def retry_blocked(self) -> int:
        muts, self.blocked = self.blocked, []
        return sum(self.dispatch(m) for m in muts)
