"""TS004 fixture: padding widths that are not provably pow2."""


def pad_plan(sources):
    width = len(sources) + 1             # TS004: arbitrary width
    return width


def pad_block(n, block):
    pad_width = n + (-n) % block         # TS004: block-quantized, not pow2
    return pad_width
