"""Sharded dynamic-graph store — the paper's distributed data model on top
of the vectorized single store, with access-pattern-adaptive re-sharding.

See ``docs/ARCHITECTURE.md`` for the layer-by-layer map of the
ingest -> seal -> view -> query pipeline and the re-sharding correctness
argument; this docstring summarizes the store itself.

The evolving graph is distributed across ``core.snapshotter.DataNode``s,
one :class:`~repro.graph.dyngraph.DynamicGraph` shard per node, with
mutations routed by **destination vertex** through a versioned
:class:`RoutingPlan` (plan 0 is the classic ``key % n_shards`` dst-hash).
Every edge (and every delete of it) lands on exactly one shard, so
shard-local LIFO delete semantics equal the global ones. Ingestion goes
through ``IngestNode.dispatch_batch`` with the encoded mutations riding
along as a payload: the paper's no-wait rule applies unchanged (a shard
whose local frontier lags parks its slice in ``blocked_batches``; healthy
shards keep ingesting), and a shard *applies* its slice inside
``DataNode.seal_epoch`` via the ``on_seal`` hook, so the local snapshot
and the shard store seal atomically.

Each shard maintains its own delta-patched join view over its slice;
:meth:`ShardedDynamicGraph.join_view` stitches the per-shard CSRs into a
global :class:`~repro.graph.dyngraph.JoinView` that is byte-identical to
the single store's (per-shard rows are already in canonical (dst, src)
order and a key can only live on one shard, so a stable merge reproduces
the canonical global order exactly). The ``SnapshotCoordinator`` frontier
gates which epochs are queryable: a snapshot is only addressable once every
shard has sealed it, which is the paper's global-snapshot rule.

**Dynamic re-sharding** (paper §2.2: the data manager "improves data
locality thus can adapt to data access patterns of different algorithms"):
an :class:`AccessStats` ledger tracks per-shard load (mutation routing
counts plus query touches fed in by the serving layer). When the
:class:`~repro.core.replica.ShardPlanner` flags a hot shard,
:meth:`ShardedDynamicGraph.split_shard` activates a successor
:class:`RoutingPlan` that splits the hot shard's key range in half
(consistent-hash style: one extra bit of a key hash), creates the new
shard, and migrates the moving half *as ordinary mutation payloads* — one
delete per moving live row dispatched to the source shard, one add to the
target — all stamped with the cutover version ``(activation_epoch, 0)``.
The migration therefore applies atomically when the activation epoch
seals, older snapshots keep resolving from the source shard's rows (their
delete stamps are the cutover version, which older masks exclude), and
``latest_sealed()`` views remain byte-identical to the single-store oracle
before, during, and after the cutover. Cutover requires a *quiescent*
store (frontier == every local frontier == last ingested epoch, nothing
parked), which the cooperative serving loop guarantees between epochs.

For distributed compute, :meth:`shard_views` exposes the pre-sharded
per-shard views directly — ``partition.partition_graph_sharded`` consumes
them without re-bucketing.

Thread-safety: like ``DynamicGraph``, this class is not internally
locked; the serving layer (``launch.serve_graph.GraphQueryServer``)
serializes every mutating touch behind one lock and runs query compute on
immutable stitched views outside it. ``parallel_apply`` adds an *internal*
apply plane below that discipline: ``seal_epoch`` fans the per-shard seals
out onto a persistent thread pool (shard state is disjoint, the store's
vectorized apply path releases the GIL inside its NumPy kernels) and
joins them before returning, so callers observe the same serial
semantics — one thread in, one thread out.
"""
from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Callable, Optional

import numpy as np

from repro.core.replica import ShardPlanner
from repro.core.snapshotter import DataNode, IngestNode, SnapshotCoordinator
from repro.core.versioned import (Version, pack32_checked, pack32_clamped,
                                  unpack32)
from repro.graph.dyngraph import (DEFAULT_CHURN_THRESHOLD, MAXV, DynamicGraph,
                                  JoinView, MutationBatch, build_join_view,
                                  prune_retired, prune_views, splitmix64)
from repro.graph.wal import (FaultInjector, GraphCheckpointManager, GraphWal,
                             ShardWal, scan_shard_records,
                             truncate_shard_after)

# payload row kinds, in the order DynamicGraph.apply processes them
K_VERTEX, K_ADD, K_DEL = 0, 1, 2

_EMPTY_ROWS = np.zeros((0, 4), np.int32)

# the refinement hash consulted by RoutingPlan.assign for split bits:
# independent of the base ``key % n_base`` residue, so a split halves a
# shard's keys uniformly regardless of their residue structure (same
# SplitMix64 finalizer the live-edge index hashes slots with)
_mix64 = splitmix64


@dataclasses.dataclass(frozen=True)
class ShardLeaf:
    """One shard's key range under a :class:`RoutingPlan`.

    A key belongs to this leaf iff ``key % n_base == residue`` and the low
    ``depth`` bits of ``_mix64(key)`` equal ``path``. Every shard owns
    exactly one leaf (splits append a new shard for the new half-range),
    and the leaves tile the key space: each key matches exactly one leaf.
    """
    shard: int
    residue: int
    depth: int
    path: int


@dataclasses.dataclass(frozen=True)
class RoutingPlan:
    """Versioned key->shard assignment with consistent-hash range splits
    and merges.

    Plan 0 (:meth:`initial`) reproduces the static dst-hash of PR 2
    exactly: shard ``i`` owns ``key % n_base == i`` at depth 0. Each
    :meth:`split` derives the successor plan: the hot shard's leaf gains
    one refinement bit (bit value 0 stays), and a NEW shard
    (id = :attr:`n_total`, the physical allocation counter) takes the
    bit-1 half — so only the migrating half-range moves and every other
    shard's assignment is untouched. :meth:`merge` is the inverse: a cold
    leaf's whole range folds back into its *sibling* (the leaf it was
    split from, or that was split from it), the merged leaf loses one
    refinement bit, and the merged-away shard owns nothing under the
    successor plan (the store retires it in place — shard ids are
    positional and never reused, which is why ``n_total`` does not shrink).

    Plans are immutable; ``history`` records every re-sharding event as
    ``("split", hot, new, activation_epoch)`` /
    ``("merge", survivor, removed, activation_epoch)`` so :meth:`replay`
    reproduces any plan deterministically (property-tested in
    ``tests/test_resharding.py``). ``activation_epoch`` is the first epoch
    routed by this plan — mutations of earlier epochs were routed (and
    applied) under the predecessor.
    """
    plan_id: int
    activation_epoch: int
    n_base: int
    leaves: tuple[ShardLeaf, ...]
    n_total: int = 0
    history: tuple[tuple[str, int, int, int], ...] = ()

    def __post_init__(self):
        if self.n_total < len(self.leaves):   # hand-built plan: every leaf
            object.__setattr__(self, "n_total",  # owner was once allocated
                               1 + max(leaf.shard for leaf in self.leaves))

    @classmethod
    def initial(cls, n_shards: int) -> "RoutingPlan":
        """Plan 0: the static ``key % n_shards`` dst-hash route."""
        return cls(0, 0, n_shards,
                   tuple(ShardLeaf(i, i, 0, 0) for i in range(n_shards)),
                   n_shards)

    @classmethod
    def replay(cls, n_base: int,
               history: tuple[tuple[str, int, int, int], ...]
               ) -> "RoutingPlan":
        """Rebuild the plan a split/merge history produced. Deterministic:
        the same history always yields the same leaves, hence the same
        assignment for every key."""
        plan = cls.initial(n_base)
        for op, a, b, activation in history:
            if op == "split":
                plan = plan.split(a, activation)
                if plan.leaves[-1].shard != b:
                    raise ValueError(
                        f"history names new shard {b} but replay "
                        f"produced {plan.leaves[-1].shard}")
            elif op == "merge":
                if plan.sibling_of(b) != a:
                    raise ValueError(
                        f"history merges shard {b} into {a} but its "
                        f"sibling under replay is {plan.sibling_of(b)}")
                plan = plan.merge(b, activation)
            else:
                raise ValueError(f"unknown history op {op!r}")
        return plan

    @property
    def n_shards(self) -> int:
        """LIVE shard count (leaves in the plan). After a merge this is
        smaller than ``n_total``, the physical shards the store holds."""
        return len(self.leaves)

    def leaf_of(self, shard: int) -> ShardLeaf:
        """The leaf ``shard`` owns, or ``ValueError`` if it owns none
        (merged away, or never allocated)."""
        for leaf in self.leaves:
            if leaf.shard == shard:
                return leaf
        raise ValueError(f"shard {shard} owns no leaf under plan "
                         f"{self.plan_id} (retired or never allocated)")

    def sibling_of(self, shard: int) -> Optional[int]:
        """The shard owning ``shard``'s sibling leaf — same residue, same
        depth, paths differing only in the top refinement bit — or None
        when no such leaf exists (depth 0, or the sibling range was split
        further). Merging is only defined between siblings: their union
        is exactly one depth-1 leaf."""
        leaf = self.leaf_of(shard)
        if leaf.depth == 0:
            return None
        want = leaf.path ^ (1 << (leaf.depth - 1))
        for other in self.leaves:
            if (other.residue == leaf.residue and other.depth == leaf.depth
                    and other.path == want):
                return other.shard
        return None

    def mergeable_pairs(self) -> list[tuple[int, int]]:
        """Current sibling pairs as ``(survivor, removed)`` candidates,
        bit-0 half first (the shard a split kept) — the planner's merge
        menu. Deterministic order (by survivor id)."""
        pairs = []
        for leaf in self.leaves:
            if leaf.depth > 0 and not leaf.path & (1 << (leaf.depth - 1)):
                sib = self.sibling_of(leaf.shard)
                if sib is not None:
                    pairs.append((leaf.shard, sib))
        return sorted(pairs)

    def _table(self) -> tuple[np.ndarray, int]:
        """Dense ``(residue, low-D refinement bits) -> shard`` lookup,
        built once per (immutable) plan and cached on the instance. D is
        the deepest leaf's depth; a leaf at depth d owns every table entry
        whose low d bits match its path, so the leaves tile each residue's
        2^D entries exactly."""
        cached = getattr(self, "_tbl", None)
        if cached is None:
            depth = max(leaf.depth for leaf in self.leaves)
            table = np.full((self.n_base, 1 << depth), -1, np.int64)
            for leaf in self.leaves:
                table[leaf.residue, leaf.path::1 << leaf.depth] = leaf.shard
            assert (table >= 0).all(), "leaves do not tile the key space"
            # flattened for the 1-D gather in assign: row-major means the
            # flat index is (residue << depth) | refinement_bits
            cached = (table.ravel(), depth)
            object.__setattr__(self, "_tbl", cached)   # frozen dataclass
        return cached

    def assign(self, keys) -> np.ndarray:
        """Vectorized key->shard assignment under this plan.

        Accepts a scalar (returns int — the ``IngestNode.dispatch`` scalar
        path) or an array (returns an int64 array of the same shape).
        Every key matches exactly one leaf, so the result is total. One
        gather through the cached leaf table instead of a per-leaf mask
        pass — on an unsplit plan this is a single ``%`` ufunc."""
        arr = np.asarray(keys)
        scalar = arr.ndim == 0
        k = np.atleast_1d(arr).astype(np.int64)
        table, depth = self._table()
        if depth == 0:
            out = k % self.n_base
        else:
            h = _mix64(k) & np.uint64((1 << depth) - 1)
            out = table[((k % self.n_base) << depth)
                        | h.view(np.int64)]
        return int(out[0]) if scalar else out

    def split(self, hot_shard: int, activation_epoch: int) -> "RoutingPlan":
        """Successor plan: halve ``hot_shard``'s range, giving the bit-1
        half to a new shard (id = ``n_total``, the next physical slot)."""
        leaf = self.leaf_of(hot_shard)
        new_shard = self.n_total
        leaves = list(self.leaves)
        leaves[leaves.index(leaf)] = ShardLeaf(hot_shard, leaf.residue,
                                               leaf.depth + 1, leaf.path)
        leaves.append(ShardLeaf(new_shard, leaf.residue, leaf.depth + 1,
                                leaf.path | (1 << leaf.depth)))
        return RoutingPlan(
            self.plan_id + 1, activation_epoch, self.n_base, tuple(leaves),
            self.n_total + 1,
            self.history + (("split", hot_shard, new_shard,
                             activation_epoch),))

    def merge(self, removed_shard: int,
              activation_epoch: int) -> "RoutingPlan":
        """Successor plan: fold ``removed_shard``'s whole range into its
        sibling's leaf, which loses one refinement bit. The removed shard
        owns nothing afterwards; ``n_total`` is unchanged (shard ids are
        never reused). Raises ``ValueError`` when the leaf has no sibling
        (depth 0, or the sibling range was split further — coarsening can
        only un-do a split)."""
        survivor = self.sibling_of(removed_shard)
        if survivor is None:
            raise ValueError(
                f"shard {removed_shard} has no sibling leaf under plan "
                f"{self.plan_id}; only split halves can merge back")
        gone = self.leaf_of(removed_shard)
        kept = self.leaf_of(survivor)
        merged = ShardLeaf(survivor, kept.residue, kept.depth - 1,
                           kept.path & ((1 << (kept.depth - 1)) - 1))
        leaves = list(self.leaves)
        leaves[leaves.index(kept)] = merged
        leaves.remove(gone)
        return RoutingPlan(
            self.plan_id + 1, activation_epoch, self.n_base, tuple(leaves),
            self.n_total,
            self.history + (("merge", survivor, removed_shard,
                             activation_epoch),))


class AccessStats:
    """Per-shard load ledger: the planner's observation window.

    Two exponentially-decayed counters per shard — ``mutations`` (rows
    routed there at ingest) and ``queries`` (query touch vertices the
    serving layer reports via
    :meth:`ShardedDynamicGraph.record_query_touches`). ``loads()`` is
    their weighted sum; the decay is applied once per globally-sealed
    epoch, so the window tracks recent epochs and a formerly-hot shard
    cools off. ``epochs_observed`` counts sealed epochs since the last
    :meth:`reset` (splits reset the ledger — fresh plan, fresh window —
    which doubles as the planner's cooldown clock).

    With ``n_vertices > 0`` the ledger additionally keeps a per-VERTEX
    EWMA of query touches (``vertex_heat``) — the replica plane's
    nomination signal: the hottest query anchors get their adjacency
    mirrored (``core.replica.MirrorPlanner`` turns this vector into the
    mirror set). Vertex heat decays on the same per-epoch tick as the
    shard counters but survives :meth:`reset`: a routing-plan change
    re-bins shard loads, it does not change which *vertices* are hot.
    """

    def __init__(self, n_shards: int, *, decay: float = 0.5,
                 query_weight: float = 1.0, n_vertices: int = 0):
        if not 0.0 < decay <= 1.0:
            raise ValueError("decay must be in (0, 1]")
        self.decay = decay
        self.query_weight = query_weight
        self.mutations = np.zeros(n_shards, np.float64)
        self.queries = np.zeros(n_shards, np.float64)
        self.vertex_heat = np.zeros(int(n_vertices), np.float64)
        self.epochs_observed = 0
        self._last_frontier = -1

    def record_mutations(self, counts: np.ndarray) -> None:
        self.mutations += counts

    def record_queries(self, counts: np.ndarray) -> None:
        self.queries += counts

    def record_vertex_touches(self, vertex_ids) -> None:
        """Per-vertex heat feed (query anchors; ids outside [0, n) are
        ignored — a query may name a vertex that does not exist yet)."""
        if not self.vertex_heat.size:
            return
        ids = np.asarray(vertex_ids, np.int64)
        ids = ids[(ids >= 0) & (ids < self.vertex_heat.size)]
        if ids.size:
            self.vertex_heat += np.bincount(
                ids, minlength=self.vertex_heat.size)

    def on_frontier_advance(self, frontier: int) -> None:
        """Decay tick, one per newly-sealed EPOCH. A straggler catching up
        can move the global frontier several epochs in one advance (one
        subscriber notification), so the tick is driven by the frontier
        value, not the notification count — otherwise multi-epoch
        advances would under-decay the window and stretch the planner's
        cooldown."""
        epochs = frontier - self._last_frontier
        if epochs <= 0:
            return
        self._last_frontier = frontier
        self.epochs_observed += epochs
        if self.decay < 1.0:
            self.mutations *= self.decay ** epochs
            self.queries *= self.decay ** epochs
            if self.vertex_heat.size:
                self.vertex_heat *= self.decay ** epochs

    def loads(self) -> np.ndarray:
        """Per-shard load vector the planner scores."""
        return self.mutations + self.query_weight * self.queries

    def reset(self, n_shards: int) -> None:
        """Start a fresh observation window (sized for ``n_shards``).
        The frontier watermark and the vertex-heat vector are global
        state, not window state, so both survive the reset — a plan
        change re-bins shard loads without cooling hot vertices."""
        self.mutations = np.zeros(n_shards, np.float64)
        self.queries = np.zeros(n_shards, np.float64)
        self.epochs_observed = 0


def encode_payload_rows(batch: MutationBatch) -> np.ndarray:
    """A batch's ``(kind, a, b, packed32_version)`` int32 payload rows —
    the byte-stable unit the dispatch payloads and the write-ahead log
    (``graph/wal.py``) share. Row order is vertices, then edge adds, then
    deletes: the order ``DynamicGraph.apply`` processes a batch, so
    ``decode_payloads(encode_payload_rows(b))`` reproduces ``b`` exactly —
    field for field, element for element.

    The version column uses the same order-preserving int32 data-plane
    packing as the stamp arrays (checked here, ahead of any ingest
    bookkeeping), which halves the payload bytes moved per row through
    dispatch grouping and decode.

    Raises ``ValueError`` if ``add_vertices`` and ``vertex_types`` disagree
    in length (a batch mutated after construction, bypassing
    ``MutationBatch.__post_init__``).
    """
    v = pack32_checked(batch.version)
    # MutationBatch.__post_init__ pads/validates, so the two arrays agree by
    # construction; a hand-built batch that bypassed it fails loudly here
    # instead of silently dropping vertex adds on the sharded path only
    n_typed = len(batch.add_vertices)
    if len(batch.vertex_types) != n_typed:
        raise ValueError(
            f"add_vertices ({n_typed}) and vertex_types "
            f"({len(batch.vertex_types)}) disagree in length")
    n_add = len(batch.add_src)
    n_del = len(batch.del_src)
    total = n_typed + n_add + n_del
    if not total:
        return _EMPTY_ROWS
    payload = np.empty((total, 4), np.int32)
    payload[:, 3] = v
    payload[:n_typed, 0] = K_VERTEX
    payload[:n_typed, 1] = batch.add_vertices
    payload[:n_typed, 2] = batch.vertex_types
    a = n_typed + n_add
    payload[n_typed:a, 0] = K_ADD
    payload[n_typed:a, 1] = batch.add_src
    payload[n_typed:a, 2] = batch.add_dst
    payload[a:, 0] = K_DEL
    payload[a:, 1] = batch.del_src
    payload[a:, 2] = batch.del_dst
    return payload


def encode_mutations(batch: MutationBatch) -> tuple[np.ndarray, np.ndarray,
                                                    np.ndarray]:
    """Flatten a MutationBatch into (keys, epochs, payload) for
    ``IngestNode.dispatch_batch``.

    keys are the routing keys (dst for edges, the vertex id for vertex
    adds); payload rows come from :func:`encode_payload_rows` (which also
    carries the malformed-batch and version-overflow checks).
    """
    payload = encode_payload_rows(batch)
    total = len(payload)
    if not total:
        z = np.zeros(0, np.int64)
        return z, z, payload
    n_typed = len(batch.add_vertices)
    n_add = len(batch.add_src)
    a = n_typed + n_add
    key_arr = np.empty(total, np.int64)
    key_arr[:n_typed] = batch.add_vertices      # vertex id routes home
    key_arr[n_typed:a] = batch.add_dst
    key_arr[a:] = batch.del_dst
    epochs = np.full(total, batch.version.epoch, np.int64)
    return key_arr, epochs, payload


def decode_payloads(payloads: list[np.ndarray]) -> list[MutationBatch]:
    """Reassemble a shard's payload rows (arrival order) into per-version
    MutationBatches, preserving within-batch mutation order.

    Rows of the same packed version — e.g. a re-sharding migration slice
    and a user batch that share the cutover version — merge into ONE batch
    in arrival order, which is exactly the single store's apply order for
    that version.
    """
    if not payloads:
        return []
    rows = np.concatenate(payloads, axis=0) if len(payloads) > 1 \
        else payloads[0]
    out = []
    vcol = rows[:, 3]
    # stable group-by on the packed version: np.unique yields versions in
    # ascending (= apply) order and the boolean mask preserves within-version
    # arrival order, so a straggler shard replaying several parked slices in
    # one seal — possibly interleaved across versions — still reassembles
    # each batch intact. (The old fast path trusted rows[0] == rows[-1],
    # which an interleaved replay defeats.) Common case: one version per
    # seal, detected with a full scan, not an endpoint check.
    if (vcol == vcol[0]).all():
        versions = vcol[:1]
    else:
        versions = np.unique(vcol)
    for v in versions:
        grp = rows if len(versions) == 1 else rows[vcol == v]
        kind, a, b = grp[:, 0], grp[:, 1], grp[:, 2]
        vert = kind == K_VERTEX
        add = kind == K_ADD
        dele = kind == K_DEL
        out.append(MutationBatch(
            unpack32(int(v)),
            add_src=a[add].astype(np.int32, copy=False),
            add_dst=b[add].astype(np.int32, copy=False),
            del_src=a[dele].astype(np.int32, copy=False),
            del_dst=b[dele].astype(np.int32, copy=False),
            add_vertices=a[vert].astype(np.int32, copy=False),
            vertex_types=b[vert].astype(np.int32, copy=False)))
    return out


def _merge_same_version(batches: list[MutationBatch]) -> list[MutationBatch]:
    """Fold adjacent same-version batches (version-sorted input) into one
    by field concatenation — the in-arrival-order row merge
    ``decode_payloads`` performs for encoded rows, lifted to whole
    batches. ``DynamicGraph.apply`` rejects repeated versions, so rows of
    one version MUST reach it as one batch."""
    out: list[MutationBatch] = []
    for b in batches:
        if out and out[-1].version == b.version:
            a = out[-1]
            out[-1] = MutationBatch(
                a.version,
                add_src=np.concatenate([a.add_src, b.add_src]),
                add_dst=np.concatenate([a.add_dst, b.add_dst]),
                del_src=np.concatenate([a.del_src, b.del_src]),
                del_dst=np.concatenate([a.del_dst, b.del_dst]),
                add_vertices=np.concatenate([a.add_vertices,
                                             b.add_vertices]),
                vertex_types=np.concatenate([a.vertex_types,
                                             b.vertex_types]))
        else:
            out.append(b)
    return out


class _ShardSlice:
    """Deferred per-shard slice of one ingested MutationBatch.

    The steady-state ingest fast path routes ONCE (``node_ids`` over the
    concatenated routing keys), groups with one stable GIL-releasing
    argsort, and hands every shard one of these — its ascending original
    row positions across the batch's three sections (typed vertex adds,
    edge adds, edge deletes) — instead of encoding payload rows and
    gathering a slice per shard on the ingest thread. :meth:`materialize`
    — called inside the shard's seal, i.e. on the parallel apply plane —
    splits the positions at the section boundaries (O(log) searchsorted;
    a stable sort keeps them ascending, so the slice order matches the
    encoded path's row order exactly) and builds the shard-local
    ``MutationBatch`` with O(own rows) gathers: no payload encode, no
    decode, and no O(whole batch) work per shard.
    """

    __slots__ = ("batch", "rows", "n_typed", "n_add")

    def __init__(self, batch: MutationBatch, rows: np.ndarray,
                 n_typed: int, n_add: int):
        self.batch = batch
        self.rows = rows
        self.n_typed = n_typed
        self.n_add = n_add

    def materialize(self) -> MutationBatch:
        b, rows = self.batch, self.rows
        nv, na = self.n_typed, self.n_add
        i1, i2 = np.searchsorted(rows, (nv, nv + na))
        mv = rows[:i1]
        ma = rows[i1:i2] - nv
        md = rows[i2:] - (nv + na)
        return MutationBatch(b.version,
                             add_src=b.add_src[ma], add_dst=b.add_dst[ma],
                             del_src=b.del_src[md], del_dst=b.del_dst[md],
                             add_vertices=b.add_vertices[mv],
                             vertex_types=b.vertex_types[mv])


def stitch_join_views(version: Version,
                      views: list[JoinView]) -> JoinView:
    """Merge per-shard canonical CSRs into the global one.

    Every (src, dst) key lives on exactly one shard (plan-based dst
    routing — a migration moves a key wholesale, so this holds across
    splits too) and each shard's rows are already (dst, src)-sorted, so a
    stable argsort of the concatenated keys is a duplicate-safe k-way
    merge: the result is byte-identical to the single store's canonical
    CSR. Raises ``ValueError`` on an empty view list.
    """
    if not views:
        raise ValueError("no shard views to stitch")
    n = views[0].n
    keys = np.concatenate([v.np_keys for v in views])
    src = np.concatenate([v.np_src for v in views])
    dst = np.concatenate([v.np_dst for v in views])
    order = np.argsort(keys, kind="stable")
    in_deg = np.zeros(n, np.int64)
    out_deg = np.zeros(n, np.int64)
    for v in views:
        in_deg += v.np_in_deg
        out_deg += v.np_out_deg
    return build_join_view(version, n, keys[order], src[order], dst[order],
                           in_deg, out_deg)


@dataclasses.dataclass(frozen=True)
class ReplicaPlan:
    """Seal-coherent replica state for ONE sealed snapshot — the versioned
    sibling of :class:`RoutingPlan` on the read side.

    ``mirrored`` marks the hot vertices whose COMPLETE live out-adjacency
    is mirrored in ``(mirror_src, mirror_dst)`` (canonical (dst, src)
    row order, gathered from the sealed global view — so a mirror row is
    byte-for-byte a row of the snapshot it mirrors). ``src_presence`` is
    the locality index: ``src_presence[j, u]`` is True iff shard ``j``
    holds at least one live out-edge of vertex ``u`` at this snapshot —
    what :func:`replica_route` consults to skip shards that cannot
    contribute to a frontier.

    Coherence is by construction, not by protocol (invariant I10 in
    ``docs/ARCHITECTURE.md``): a plan is built at the publish-at-seal
    boundary from snapshot ``version``'s own views and is only ever
    consulted for windows executing at exactly that version — the
    write-invalidation of the keyed :class:`~repro.core.replica
    .ReplicaManager` protocol falls out for free, because a mutation can
    only land in a LATER sealed snapshot, which gets a fresh plan.
    """
    plan_id: int                # routing plan this was built under
    version: Version            # the one snapshot these mirrors serve
    mirrored: np.ndarray        # (n,) bool — vertex adjacency is mirrored
    mirror_src: np.ndarray      # (mm,) out-edges of mirrored vertices...
    mirror_dst: np.ndarray      # (mm,) ...complete at `version`, canonical
    src_presence: np.ndarray    # (n_shards, n) bool locality index

    @property
    def n_mirrored(self) -> int:
        return int(self.mirrored.sum())


def replica_route(plan: ReplicaPlan, shard_views: list[JoinView],
                  anchors, hops: Optional[int]) -> tuple[
                      np.ndarray, np.ndarray, int, int, int]:
    """Replica-first routing for one same-kind window: compute the union
    frontier closure of ``anchors`` (k-hop sources / reachability sources)
    out to ``hops`` expansions (None = until the frontier drains), pulling
    each hop's neighbors from the MIRROR for mirrored frontier vertices
    and only from shards whose ``src_presence`` says they hold out-edges
    of the non-mirrored rest.

    Returns ``(sub_src, sub_dst, fanout, mirror_hits, mirror_misses)``:
    the restricted edge set (mirror rows + full rows of every shard
    touched), the number of distinct shards touched, and per-vertex
    mirror hit/miss counts. The edge set contains every out-edge of every
    vertex whose edges a ``hops``-step frontier sweep from ``anchors`` can
    read — mirrors are complete per vertex and presence is exact per
    (shard, vertex) — and only rows of the same sealed snapshot, so
    running the ordinary batched kernels on it is byte-identical to
    running them on the stitched global view (the replica-plane
    equivalence tests assert exactly this across split and merge
    cutovers)."""
    n = plan.mirrored.shape[0]
    ids = np.asarray(anchors, np.int64).reshape(-1)
    frontier = np.unique(ids[(ids >= 0) & (ids < n)])
    reached = np.zeros(n, bool)
    reached[frontier] = True
    touched = np.zeros(len(shard_views), bool)
    use_mirror = False
    hits = misses = 0
    fmask = np.empty(n, bool)
    expansions = n if hops is None else int(hops)
    for _ in range(expansions):
        if not frontier.size:
            break
        is_m = plan.mirrored[frontier]
        f_mir, f_rest = frontier[is_m], frontier[~is_m]
        hits += int(f_mir.size)
        misses += int(f_rest.size)
        parts = []
        if f_mir.size:
            use_mirror = True
            fmask[:] = False
            fmask[f_mir] = True
            parts.append(plan.mirror_dst[fmask[plan.mirror_src]])
        if f_rest.size:
            touched |= plan.src_presence[:, f_rest].any(axis=1)
            fmask[:] = False
            fmask[f_rest] = True
            for j in np.flatnonzero(plan.src_presence[:, f_rest]
                                    .any(axis=1)):
                v = shard_views[j]
                parts.append(v.np_dst[fmask[v.np_src]])
        if not parts:
            break
        neigh = np.concatenate(parts).astype(np.int64, copy=False)
        frontier = np.unique(neigh[~reached[neigh]])
        reached[frontier] = True
    src_parts, dst_parts = [], []
    if use_mirror:
        src_parts.append(plan.mirror_src)
        dst_parts.append(plan.mirror_dst)
    for j in np.flatnonzero(touched):
        src_parts.append(shard_views[j].np_src)
        dst_parts.append(shard_views[j].np_dst)
    if src_parts:
        sub_src = np.concatenate(src_parts)
        sub_dst = np.concatenate(dst_parts)
    else:
        sub_src = np.zeros(0, np.int32)
        sub_dst = np.zeros(0, np.int32)
    return sub_src, sub_dst, int(touched.sum()), hits, misses


class ShardedDynamicGraph:
    """N DynamicGraph shards behind an IngestNode + SnapshotCoordinator,
    re-shardable at runtime from observed access patterns.

    Args:
        n_shards: initial shard count (splits may grow it).
        n_max: global vertex capacity (every shard sees the full id space).
        e_max: **per-shard** edge capacity.
        churn_threshold: per-shard delta-view fallback threshold
            (see ``DynamicGraph``).
        route: optional custom routing callable ``key -> shard``
            (NumPy-vectorizable for the batched fast path). Providing one
            disables plan-based routing — and with it re-sharding
            (``split_shard``/``maybe_reshard`` raise / no-op).
        planner: optional :class:`~repro.core.replica.ShardPlanner`
            consulted by :meth:`maybe_reshard`. Without one, re-sharding
            only happens via explicit :meth:`split_shard` calls.
        stats_decay / query_weight: :class:`AccessStats` window shape.
        parallel_apply: size of the persistent thread pool
            :meth:`seal_epoch` dispatches per-shard seals (and therefore
            per-shard ``DynamicGraph.apply`` work) onto. ``0``/``1`` (the
            default) keeps the serial apply plane. Shards share no mutable
            state — each seal touches only its own node, shard store and
            ``shard_apply_seconds`` slot — and the store's batched NumPy
            apply path releases the GIL inside its array kernels, so
            N-shard epochs genuinely overlap. See :meth:`seal_epoch` for
            the failure semantics; call :meth:`shutdown` to reap the pool
            eagerly (it is otherwise reaped with the store).

    The synchronous driving pattern is one batch per epoch::

        sg.ingest(batch)                  # no-wait dispatch to shards
        sg.seal_epoch(batch.version.epoch)  # seal + apply + advance frontier
        sg.maybe_reshard()                # optional: planner-driven split

    (or ``sg.apply(batch)`` for ingest + seal at once). Per-shard sealing
    (``seal_shard``) lets a straggler shard lag: its slice stays parked and
    the global frontier — and therefore ``join_view`` — holds back until it
    catches up.

    Not internally locked — see the module docstring for the serving
    layer's locking discipline.
    """

    def __init__(self, n_shards: int, n_max: int, e_max: int, *,
                 churn_threshold: float = DEFAULT_CHURN_THRESHOLD,
                 route: Optional[Callable] = None,
                 planner: Optional[ShardPlanner] = None,
                 stats_decay: float = 0.5, query_weight: float = 1.0,
                 parallel_apply: int = 0,
                 wal_dir=None, wal_fsync: str = "batch",
                 wal_fsync_every: int = 32, checkpoint_every: int = 0,
                 checkpoint_keep: int = 2,
                 fault_injector: Optional[FaultInjector] = None):
        if n_shards < 1:
            raise ValueError("need at least one shard")
        self.n_max = n_max
        self.e_max = e_max
        self.churn_threshold = churn_threshold
        self.parallel_apply = int(parallel_apply)
        self._pool = None
        if route is not None:
            if planner is not None:
                raise ValueError(
                    "a custom route disables plan-based re-sharding; "
                    "drop the planner or the route")
            self.plan: Optional[RoutingPlan] = None
            self.route = route
        else:
            self.plan = RoutingPlan.initial(n_shards)
            self.route = self.plan.assign
        self.planner = planner
        self.access_stats = AccessStats(n_shards, decay=stats_decay,
                                        query_weight=query_weight,
                                        n_vertices=n_max)
        self.shards = [DynamicGraph(n_max, e_max, churn_threshold)
                       for _ in range(n_shards)]
        self.nodes = [DataNode(i, on_seal=self._on_seal(i))
                      for i in range(n_shards)]
        # nodes is a SHARED list: coordinator and ingest node observe
        # appended shards (splits) without re-wiring
        self.coordinator = SnapshotCoordinator(self.nodes)
        self.ingest_node = IngestNode(self.nodes, route=self.route)
        self.coordinator.subscribe(self.access_stats.on_frontier_advance)
        self._views: dict[int, JoinView] = {}
        self._last_version = -1
        self._ingested_packed: list[int] = []   # every ingested version, asc
        # completed re-sharding records: {"kind", "plan_id", "source",
        # "target", "activation_epoch", "migrated_edges"} — telemetry +
        # plan-aware GC (a merge's source is the retired shard)
        self.migrations: list[dict] = []
        # shards merged away: they stay in ``shards``/``nodes`` (shard ids
        # are positional across the store, and pre-cutover snapshots still
        # resolve from their tombstoned rows) but the plan routes them
        # nothing, so they seal empty epochs from the cutover on
        self.retired: set[int] = set()
        # per-shard cumulative apply seconds — the benchmark's critical-path
        # model of parallel shard ingestion reads these
        self.shard_apply_seconds = [0.0] * n_shards
        # -- durability plane (graph/wal.py) -------------------------------
        self.fault_injector = fault_injector
        self.wal: Optional[GraphWal] = None
        # one append-mode writer per physical shard (None when durability
        # is off or during replay) — shard-owned like ``shards``/``nodes``
        self.wal_shards: list[Optional[ShardWal]] = [None] * n_shards
        self.checkpoint_every = int(checkpoint_every)
        self._ckpt: Optional[GraphCheckpointManager] = None
        self._wal_replaying = False          # replay must not re-append
        self._wal_committed = -1             # newest control-committed epoch
        self._last_ckpt_epoch = -1
        # user-ingested packed versions per not-yet-committed epoch — the
        # control log's commit records carry these so recovery can rebuild
        # latest_sealed() exactly (migration rows are deliberately absent)
        self._epoch_versions: dict[int, list[int]] = {}
        if wal_dir is not None:
            if self.plan is None:
                raise ValueError(
                    "the durable WAL needs plan-based routing (a custom "
                    "route cannot be serialized for recovery)")
            self._attach_wal(
                GraphWal(wal_dir, fsync=wal_fsync,
                         fsync_every=wal_fsync_every),
                checkpoint_keep=checkpoint_keep, fresh=True)

    @property
    def n_shards(self) -> int:
        """PHYSICAL shard count (grows by one per split; never shrinks —
        a merge retires a shard in place rather than deleting it, because
        shard ids are positional and old snapshots still resolve from the
        retired shard's rows). Live count is ``len(live_shards())``."""
        return len(self.shards)

    def live_shards(self) -> list[int]:
        """Shard ids the active plan routes keys to (physical minus
        retired), ascending."""
        return [i for i in range(len(self.shards)) if i not in self.retired]

    def _on_seal(self, shard_id: int) -> Callable[[int, list], None]:
        def on_seal(epoch: int, payloads: list) -> None:
            # the chaos hook fires at seal ENTRY — before any apply — so
            # an injected fault aborts the epoch as a clean no-op: it
            # stays pending and re-sealable (I6/I11). Read the seam into
            # a local; replay is fault-free by definition.
            inj = self.fault_injector
            if inj is not None and not self._wal_replaying:
                inj.check(shard_id, epoch)
            t0 = time.perf_counter()
            shard = self.shards[shard_id]
            # payloads arrive in three shapes: whole MutationBatches (the
            # single-shard passthrough), deferred _ShardSlices (the
            # steady-state fast path — materialized HERE, on the parallel
            # apply plane), and encoded row arrays (the straggler/parked
            # and migration paths). Kinds can share an epoch (a slice
            # parked before the shard caught up) but never a version, so
            # merging on the packed version restores apply order.
            direct = []
            arrays = []
            for p in payloads:
                if isinstance(p, _ShardSlice):
                    direct.append(p.materialize())
                elif isinstance(p, MutationBatch):
                    direct.append(p)
                else:
                    arrays.append(p)
            batches = decode_payloads(arrays)
            if direct:
                # encoded rows always precede a same-version direct batch
                # in arrival order (the only same-version pairing is a
                # re-sharding migration slice + the user batch at the
                # cutover version, and the migration dispatches first), so
                # a stable sort + adjacent merge reproduces the encoded
                # path's row order exactly
                batches = _merge_same_version(
                    sorted(batches + direct, key=lambda b: b.version.pack()))
            # pre-check capacity across the WHOLE epoch so a failed seal is
            # a no-op (DynamicGraph.apply is atomic per batch; this makes
            # the seal atomic per epoch) — the epoch stays pending and can
            # be re-sealed after intervention
            adds = sum(len(b.add_src) for b in batches)
            if shard.n_edges + adds > shard.e_max:
                raise MemoryError(
                    f"shard {shard_id}: epoch {epoch} adds {adds} edges to "
                    f"{shard.n_edges}/{shard.e_max}; seal aborted, epoch "
                    "left pending")
            for batch in batches:
                shard.apply(batch)
            # WAL append only after the whole epoch applied: a failed
            # seal leaves no record (the epoch re-seals; a half-applied
            # epoch cannot exist — see the capacity pre-check above).
            # Re-encoding the merged batches reproduces exactly what
            # decode_payloads will regroup on replay, whichever ingest
            # path the rows originally rode. Every seal writes a record —
            # empty epochs included — so the durable frontier's
            # completeness scan is well defined. wal_shards is shard-owned
            # state like ``shards``: only this shard's seal touches its
            # writer.
            w = self.wal_shards[shard_id]
            if w is not None and not self._wal_replaying:
                if not batches:
                    rows = _EMPTY_ROWS
                elif len(batches) == 1:       # steady state: one batch/epoch
                    rows = encode_payload_rows(batches[0])
                else:
                    rows = np.concatenate(
                        [encode_payload_rows(b) for b in batches])
                w.append(epoch, rows)
            self.shard_apply_seconds[shard_id] += time.perf_counter() - t0
        return on_seal

    # -- ingestion ---------------------------------------------------------
    def ingest(self, batch: MutationBatch) -> int:
        """No-wait dispatch of one mutation batch; returns the number of
        mutations dispatched now (the rest park until shards catch up).

        Multiple batches per epoch are fine, but an epoch is closed for
        ingestion once ANY shard has sealed it — a slice delivered to a
        sealed local snapshot could never be applied, so that is an error
        here rather than silent loss.

        Raises:
            ValueError: non-increasing version, already-sealed epoch, or a
                malformed batch (rejected before any version bookkeeping,
                so the corrected batch can retry at the same version).
        """
        v = batch.version.pack()
        if v <= self._last_version:
            raise ValueError("mutation batches must have increasing versions")
        sealed = max(n.local_frontier for n in self.nodes)
        if batch.version.epoch <= sealed:
            raise ValueError(
                f"epoch {batch.version.epoch} is already sealed on some "
                f"shard (max local frontier {sealed}); ingest batches "
                "before sealing their epoch")
        if (self.plan is not None and self.n_shards == 1
                and self.nodes[0].local_frontier >= batch.version.epoch - 1):
            # single-shard passthrough: the plan routes every key to shard
            # 0, so the batch rides to the node AS ITSELF — no payload
            # encode, no routing pass, no decode at seal (the batch is
            # applied as handed in; treat it as immutable once ingested).
            # An ineligible node (straggler restart) falls through to the
            # encoded path, whose parked slices know how to re-dispatch.
            if len(batch.vertex_types) != len(batch.add_vertices):
                # same malformed-batch guard encode_mutations applies,
                # still ahead of any version bookkeeping
                raise ValueError(
                    f"add_vertices ({len(batch.add_vertices)}) and "
                    f"vertex_types ({len(batch.vertex_types)}) disagree "
                    "in length")
            # overflow must raise BEFORE version bookkeeping (like the
            # other two paths) or the epoch wedges pending forever
            pack32_checked(batch.version)
            self._note_ingest(batch.version.epoch, v)
            n = batch.size
            if not n:
                return 0
            self.access_stats.record_mutations(np.asarray([n], np.float64))
            self.nodes[0].receive_batch(
                batch.version.epoch, np.broadcast_to(np.int64(0), (n,)),
                payload=batch)
            self.ingest_node.dispatched += n
            return n
        epoch = batch.version.epoch
        if (self.plan is not None
                and all(n.local_frontier >= epoch - 1 for n in self.nodes)):
            # steady-state fast path (every shard eligible — the no-wait
            # rule can't park anything): one vectorized routing pass over
            # the concatenated keys, then each shard receives a deferred
            # _ShardSlice; the per-shard row gathers happen inside the
            # shards' seals, i.e. on the parallel apply plane, leaving the
            # ingest thread with O(batch) hashing + bincount only.
            # pack32_checked mirrors the encoded path's overflow check
            # (encode first: raise before any version bookkeeping).
            if len(batch.vertex_types) != len(batch.add_vertices):
                raise ValueError(
                    f"add_vertices ({len(batch.add_vertices)}) and "
                    f"vertex_types ({len(batch.vertex_types)}) disagree "
                    "in length")
            pack32_checked(batch.version)
            self._note_ingest(batch.version.epoch, v)
            total = batch.size
            if not total:
                return 0
            n_typed, n_add = len(batch.add_vertices), len(batch.add_src)
            keys = np.concatenate([
                batch.add_vertices, batch.add_dst, batch.del_dst]) \
                .astype(np.int64, copy=False)
            node_ids = self.plan.assign(keys)
            self.access_stats.record_mutations(
                np.bincount(node_ids, minlength=self.n_shards))
            # one stable grouping sort (GIL-releasing); each shard gets its
            # ascending row positions, gathered at ITS seal — O(own rows)
            # per shard, O(batch log batch) here
            order = np.argsort(node_ids, kind="stable")
            sorted_nodes = node_ids[order]
            starts = np.flatnonzero(
                np.r_[True, sorted_nodes[1:] != sorted_nodes[:-1]])
            bounds = np.r_[starts, len(order)]
            for a, b in zip(bounds[:-1], bounds[1:], strict=True):
                self.nodes[int(sorted_nodes[a])].receive_batch(
                    epoch, np.broadcast_to(np.int64(0), (b - a,)),
                    payload=_ShardSlice(batch, order[a:b], n_typed, n_add))
            self.ingest_node.dispatched += total
            return total
        # encode first: if it raises (malformed batch), no version
        # bookkeeping has happened and the same version can be retried —
        # otherwise latest_sealed() could later name a version whose
        # mutations were never applied
        keys, epochs, payload = encode_mutations(batch)
        self._note_ingest(batch.version.epoch, v)
        if not keys.size:
            return 0
        if self.plan is not None:
            # route once here: the node ids both feed the access ledger and
            # override dispatch_batch's routing (same plan, same result)
            node_ids = self.plan.assign(keys)
            self.access_stats.record_mutations(
                np.bincount(node_ids, minlength=self.n_shards))
            return self.ingest_node.dispatch_batch(keys, epochs, payload,
                                                   node_ids=node_ids)
        return self.ingest_node.dispatch_batch(keys, epochs, payload)

    def _executor(self):
        if self._pool is None:
            from concurrent.futures import ThreadPoolExecutor
            self._pool = ThreadPoolExecutor(
                max_workers=self.parallel_apply,
                thread_name_prefix="shard-apply")
        return self._pool

    def shutdown(self) -> None:
        """Reap the parallel-apply thread pool (idempotent; the store
        stays usable — the pool is re-created on the next parallel seal)."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def seal_epoch(self, epoch: int) -> int:
        """Seal ``epoch`` on every shard (applying parked + pending slices)
        and advance the global frontier. Returns the new global frontier.

        Seals one epoch per shard per round with a blocked-batch retry
        between rounds: a slice parked because its shard lagged several
        epochs becomes dispatchable the moment the previous epoch seals,
        and must land before its own epoch seals.

        With ``parallel_apply > 1``, each round's per-shard seals — and
        therefore the shards' ``DynamicGraph.apply`` work — run
        concurrently on the persistent thread pool. Shard state is
        disjoint per thread (one node + one store + one telemetry slot
        each); the serial seams (blocked-batch retry between rounds,
        coordinator advance at the end) stay on the calling thread. Every
        shard of a round is awaited even when one fails, then the
        lowest-shard exception is re-raised: exactly like the serial
        plane, a failing shard's epoch stays pending and re-sealable (I6)
        while the global frontier — never advanced here on failure —
        keeps the epoch invisible to queries, so the epoch aborts
        atomically from the store's point of view.
        """
        while any(n.local_frontier < epoch for n in self.nodes):
            self.ingest_node.retry_blocked_batches()
            lagging = [n for n in self.nodes if n.local_frontier < epoch]
            if self.parallel_apply > 1 and len(lagging) > 1:
                futures = [self._executor().submit(
                    n.seal_epoch, n.local_frontier + 1) for n in lagging]
                errors = [f.exception() for f in futures]   # barrier
                for err in errors:
                    if err is not None:
                        raise err
            else:
                for node in lagging:
                    node.seal_epoch(node.local_frontier + 1)
        self.ingest_node.retry_blocked_batches()
        frontier = self.coordinator.advance()
        self._trim_ingest_log()
        return frontier

    def seal_shard(self, shard_id: int, epoch: int) -> int:
        """Seal one shard through ``epoch`` (straggler-paced sealing) and
        advance the global frontier. Returns the new global frontier."""
        node = self.nodes[shard_id]
        while node.local_frontier < epoch:
            self.ingest_node.retry_blocked_batches()
            node.seal_epoch(node.local_frontier + 1)
        self.ingest_node.retry_blocked_batches()
        frontier = self.coordinator.advance()
        self._trim_ingest_log()
        return frontier

    def apply(self, batch: MutationBatch) -> None:
        """Ingest + seal in one step (the DynamicGraph-compatible path)."""
        self.ingest(batch)
        self.seal_epoch(batch.version.epoch)

    # -- durability (graph/wal.py) -----------------------------------------
    def _note_ingest(self, epoch: int, packed: int) -> None:
        """Ingest-path version bookkeeping, shared by all three dispatch
        paths; with a WAL attached, also stages the version for its
        epoch's control-log commit record."""
        self._last_version = packed
        self._ingested_packed.append(packed)
        if self.wal is not None:
            self._epoch_versions.setdefault(epoch, []).append(packed)

    def _attach_wal(self, wal: GraphWal, *, checkpoint_keep: int,
                    fresh: bool) -> None:
        """Wire a WAL into the store: per-shard writers, the checkpoint
        manager, and the frontier subscription that writes commit
        records. ``fresh`` stores the construction parameters in the
        control log (recovery rebuilds the store from them); a recovered
        store reattaches with ``fresh=False``."""
        self.wal = wal
        if fresh:
            wal.write_meta({
                "n_base": self.plan.n_base, "n_max": self.n_max,
                "e_max": self.e_max,
                "churn_threshold": self.churn_threshold,
                "parallel_apply": self.parallel_apply,
                "fsync": wal.fsync, "fsync_every": wal.fsync_every,
                "checkpoint_every": self.checkpoint_every,
                "checkpoint_keep": int(checkpoint_keep)})
        self.wal_shards = [wal.shard_wal(i)
                          for i in range(len(self.shards))]
        self._ckpt = GraphCheckpointManager(wal.dir / "checkpoints",
                                            keep=checkpoint_keep)
        self.coordinator.subscribe(self._wal_on_frontier)

    def _wal_on_frontier(self, frontier: int) -> None:
        """Frontier subscriber: one control-log commit record per
        newly-sealed epoch (carrying its staged user-ingested versions),
        then a periodic checkpoint. Runs on the serial thread inside
        ``coordinator.advance`` — the shard records for these epochs were
        appended by the very seals that enabled the advance."""
        if self.wal is None or self._wal_replaying:
            return
        for e in range(self._wal_committed + 1, frontier + 1):
            self.wal.commit_epoch(e, self._epoch_versions.pop(e, []))
        self._wal_committed = frontier
        if (self.checkpoint_every > 0
                and frontier - self._last_ckpt_epoch
                >= self.checkpoint_every):
            self.checkpoint()

    def checkpoint(self) -> Optional[int]:
        """Durable snapshot of the whole store at the current global
        frontier; every shard's WAL rotates to a fresh segment and the
        segments the checkpoint covers are dropped. Returns the
        checkpointed epoch, or None when no consistent cut exists right
        now (nothing sealed yet, or a straggler-paced shard's local
        frontier is ahead of the global one — its post-frontier applies
        are not part of any globally-sealed snapshot).

        Raises ``ValueError`` without a WAL directory (the checkpoint
        ladder is part of the durability plane, not a standalone
        feature)."""
        if self._ckpt is None:
            raise ValueError("checkpointing needs a WAL directory "
                             "(construct with wal_dir=...)")
        f = self.coordinator.global_frontier
        if f < 0 or any(n.local_frontier != f for n in self.nodes):
            return None
        self._ckpt.save_graph(self, epoch=f)
        for w in self.wal_shards:
            if w is not None:
                w.rotate(f + 1)
                w.drop_segments_below(f + 1)
        self.wal.sync()
        self._last_ckpt_epoch = f
        return f

    def _replay_plan_event(self, ev: dict) -> None:
        """Re-execute one re-sharding cutover structurally during WAL
        replay: plan swap, shard allocation/retirement, ledger reset and
        telemetry — everything :meth:`split_shard`/:meth:`merge_shards`
        does EXCEPT dispatching migration rows, which already ride the
        shard WAL records of the activation epoch."""
        op, a, b = ev["op"], ev["a"], ev["b"]
        activation = ev["activation"]
        if op == "split":
            new_plan = self.plan.split(a, activation)
            target = new_plan.leaves[-1].shard
            if target != b or target != len(self.shards):
                raise ValueError(
                    f"plan replay allocated shard {target} but the "
                    f"control log names {b} with {len(self.shards)} "
                    "physical shards — control log and checkpoint "
                    "disagree")
            self.shards.append(DynamicGraph(self.n_max, self.e_max,
                                            self.churn_threshold))
            node = DataNode(target, on_seal=self._on_seal(target))
            node.local_frontier = activation - 1
            self.nodes.append(node)
            self.shard_apply_seconds.append(0.0)
            self.wal_shards.append(None)   # writers attach after replay
            src, tgt = a, b
        elif op == "merge":
            if self.plan.sibling_of(b) != a:
                raise ValueError(
                    f"control log merges shard {b} into {a} but its "
                    f"sibling under the replayed plan is "
                    f"{self.plan.sibling_of(b)}")
            new_plan = self.plan.merge(b, activation)
            self.retired.add(b)
            src, tgt = b, a
        else:
            raise ValueError(f"unknown plan event op {op!r}")
        self.plan = new_plan
        self.route = new_plan.assign
        self.ingest_node.route = new_plan.assign
        self.access_stats.reset(self.n_shards)
        self.migrations.append({
            "kind": op, "plan_id": new_plan.plan_id,
            "source": src, "target": tgt,
            "activation_epoch": activation,
            "migrated_edges": int(ev.get("migrated", 0))})

    def _restore_checkpoint(self, snap: dict) -> None:
        """Load a :meth:`GraphCheckpointManager.load_graph` snapshot into
        a freshly-constructed store: plan history, per-shard arrays (with
        live-index rebuild), access ledger, ingest log."""
        meta = snap["meta"]
        epoch = snap["epoch"]
        history = tuple(tuple(ev) for ev in meta["plan_history"])
        plan = RoutingPlan.replay(self.plan.n_base, history)
        for i in range(len(self.shards), plan.n_total):
            self.shards.append(DynamicGraph(self.n_max, self.e_max,
                                            self.churn_threshold))
            self.nodes.append(DataNode(i, on_seal=self._on_seal(i)))
            self.shard_apply_seconds.append(0.0)
            self.wal_shards.append(None)
        self.plan = plan
        self.route = plan.assign
        self.ingest_node.route = plan.assign
        self.retired = set(meta["retired"])
        self.migrations = list(meta["migrations"])
        for shard, arrays in zip(self.shards, snap["shards"],
                                 strict=True):
            k = len(arrays["src"])
            shard.src[:k] = arrays["src"]
            shard.dst[:k] = arrays["dst"]
            shard.created[:k] = arrays["created"]
            shard.deleted[:k] = arrays["deleted"]
            shard.n_edges = k
            shard.v_created[:] = arrays["v_created"]
            shard.v_type[:] = arrays["v_type"]
            shard.n_vertices = int((shard.v_created != MAXV).sum())
            last = int(arrays["last_version"])
            shard.versions = [Version.unpack(last)] if last >= 0 else []
            shard._log_floor = last
            shard._rebuild_index()
        for node in self.nodes:
            node.local_frontier = epoch
        # -> checkpoint epoch; ticks the ledger decay once, which the
        # restore below overwrites wholesale
        self.coordinator.advance()
        stats = meta["stats"]
        self.access_stats.reset(len(self.shards))
        self.access_stats.mutations[:] = stats["mutations"]
        self.access_stats.queries[:] = stats["queries"]
        self.access_stats.epochs_observed = stats["epochs_observed"]
        self.access_stats.vertex_heat[:] = snap["vertex_heat"]
        self._last_version = int(meta["last_version"])
        self._ingested_packed = [int(v) for v in meta["ingested_packed"]]
        self._last_ckpt_epoch = epoch

    @classmethod
    def recover(cls, wal_dir, *, planner: Optional[ShardPlanner] = None,
                parallel_apply: Optional[int] = None,
                fault_injector: Optional[FaultInjector] = None,
                checkpoint_every: Optional[int] = None,
                wal_fsync: Optional[str] = None,
                wal_fsync_every: Optional[int] = None
                ) -> "ShardedDynamicGraph":
        """Rebuild a store from its durability directory: the latest
        graph checkpoint plus the WAL tail, replayed through the ordinary
        receive/seal machinery — so the recovered store is byte-identical
        to the uncrashed oracle at every sealed epoch up to the durable
        frontier, across split and merge cutovers included (the control
        log replays the plan history; migration rows ride the shard
        records of their activation epoch like any other payload).

        The durable frontier is the newest epoch ``e`` such that every
        epoch through ``e`` has a control-log commit record AND an intact
        record on every shard required at it (batched fsync may lose an
        unsynced suffix of either — the minimum rule means that only
        shortens recovery, never corrupts it). Records beyond the durable
        frontier — committed-but-incomplete epochs, uncommitted plan
        events, torn tails — are truncated away so the driver re-ingests
        those epochs cleanly.

        Keyword overrides replace the persisted construction parameters
        (planner/fault_injector are process-local objects and never
        persist). Raises ``ValueError`` when the directory holds no WAL
        meta record; :class:`WalCorruptionError` on mid-segment
        corruption."""
        wal_dir = pathlib.Path(wal_dir)
        meta, events, commits = GraphWal.read_control(wal_dir)
        if meta is None:
            raise ValueError(
                f"no WAL meta record under {wal_dir}; nothing to recover")
        ckpt_keep = int(meta.get("checkpoint_keep", 2))
        ckpt = GraphCheckpointManager(wal_dir / "checkpoints",
                                      keep=ckpt_keep)
        snap = ckpt.load_graph()
        store = cls(
            int(meta["n_base"]), int(meta["n_max"]), int(meta["e_max"]),
            churn_threshold=meta["churn_threshold"],
            planner=planner,
            parallel_apply=(int(meta.get("parallel_apply", 0))
                            if parallel_apply is None else parallel_apply))
        store._wal_replaying = True
        c = -1
        if snap is not None:
            store._restore_checkpoint(snap)
            c = snap["epoch"]
        # cutovers not yet folded into the checkpoint's plan history (the
        # control log's plan events and the history grow in lockstep)
        tail_events = events[len(store.plan.history):]
        shard_records: dict[int, dict] = {}
        for d in sorted(wal_dir.glob("shard-*")):
            sid = int(d.name.split("-", 1)[1])
            shard_records[sid] = scan_shard_records(d)

        def shards_required(epoch: int) -> int:
            n = int(meta["n_base"])
            for ev in events:
                if ev["op"] == "split" and ev["activation"] <= epoch:
                    n += 1
            return n

        durable = c
        e = c + 1
        while e in commits and all(
                e in shard_records.get(sid, {})
                for sid in range(shards_required(e))):
            durable = e
            e += 1
        by_activation: dict[int, list[dict]] = {}
        for ev in tail_events:
            if ev["activation"] <= durable:
                by_activation.setdefault(ev["activation"], []).append(ev)
        for e in range(c + 1, durable + 1):
            for ev in by_activation.get(e, ()):
                store._replay_plan_event(ev)
            for sid in range(len(store.nodes)):
                rows = shard_records.get(sid, {}).get(e)
                node = store.nodes[sid]
                if rows is not None and len(rows[0]):
                    node.receive_batch(
                        e, np.broadcast_to(np.int64(0), (len(rows[0]),)),
                        payload=rows[0])
                node.seal_epoch(e)
            store.coordinator.advance()
        # ingest-log bookkeeping for the replayed tail, straight from the
        # commit records (checkpoint meta covered epochs <= c)
        packed_tail = [v for e2 in range(c + 1, durable + 1)
                       for v in commits.get(e2, [])]
        if packed_tail:
            store._ingested_packed.extend(packed_tail)
            store._last_version = packed_tail[-1]
        store._trim_ingest_log()
        store._wal_replaying = False
        # drop everything beyond the durable frontier BEFORE reattaching
        # append-mode writers: complete-but-uncommitted records (their
        # epochs get re-ingested and re-appended), uncommitted plan
        # events, and torn tails (a writer must reopen on a record
        # boundary)
        for d in wal_dir.glob("shard-*"):
            truncate_shard_after(d, durable)
        GraphWal.truncate_control_after(wal_dir, durable)
        store.checkpoint_every = (int(meta.get("checkpoint_every", 0))
                                  if checkpoint_every is None
                                  else int(checkpoint_every))
        store._attach_wal(
            GraphWal(wal_dir,
                     fsync=(meta.get("fsync", "batch")
                            if wal_fsync is None else wal_fsync),
                     fsync_every=(int(meta.get("fsync_every", 32))
                                  if wal_fsync_every is None
                                  else int(wal_fsync_every))),
            checkpoint_keep=ckpt_keep, fresh=False)
        store._wal_committed = durable
        store.fault_injector = fault_injector
        return store

    # -- re-sharding -------------------------------------------------------
    def record_query_touches(self, vertex_ids) -> None:
        """Feed query access patterns into the ledger: ``vertex_ids`` are
        the vertices a query window touched (sources/targets); they are
        binned to shards under the active plan. No-op under a custom
        route. Called by the serving layer inside its lock."""
        if self.plan is None:
            return
        ids = np.asarray(vertex_ids, np.int64)
        if not ids.size:
            return
        self.access_stats.record_queries(
            np.bincount(self.plan.assign(ids), minlength=self.n_shards))
        # per-vertex heat feeds hot-vertex mirror nomination (replica
        # plane); deliberately NOT fed from the ingest hot path — query
        # skew, not write skew, is what mirrors exploit
        self.access_stats.record_vertex_touches(ids)

    def is_quiescent(self) -> bool:
        """True when nothing is in flight: every local frontier equals the
        global frontier, the last ingested epoch is sealed, and no slice
        is parked OR pending on any node. This is the re-sharding cutover
        precondition — it guarantees every mutation of epochs < activation
        has been applied under the retiring plan, so swapping the route
        never re-routes an in-flight pre-cutover slice. (The pending-map
        check matters for back-to-back splits: a prior split's migration
        slices sit pending until their activation epoch seals, and a
        second split reading the source shard before then would
        re-migrate rows the first move already claimed.)"""
        f = self.coordinator.global_frontier
        return (not self.ingest_node.blocked
                and not self.ingest_node.blocked_batches
                and all(n.local_frontier == f for n in self.nodes)
                and Version.unpack(self._last_version).epoch <= f
                and not any(n.pending or n.pending_batches
                            or n.pending_payloads for n in self.nodes))

    def split_shard(self, hot_shard: int) -> dict:
        """Split ``hot_shard``'s key range: activate the successor plan at
        the next epoch and migrate the moving half-range.

        The migration rides as ordinary mutation payloads: for each live
        row whose key moves, a delete dispatched to the source shard and an
        add (in original creation order, preserving LIFO delete semantics)
        to the new shard, all at version ``(activation_epoch, 0)``. Both
        slices apply atomically when the activation epoch seals, so no
        query — always answered at a frontier-sealed snapshot — can
        observe a half-migrated graph. User batches may share the cutover
        version; ``decode_payloads`` merges them in arrival order.

        Returns a summary dict (plan id, source/target shards, activation
        epoch, migrated edge count), also appended to :attr:`migrations`.

        Raises:
            ValueError: custom-route store (no plan to split).
            RuntimeError: store not quiescent (see :meth:`is_quiescent`).
        """
        if self.plan is None:
            raise ValueError("re-sharding needs plan-based routing "
                             "(construct without a custom `route`)")
        if not self.is_quiescent():
            raise RuntimeError(
                "re-sharding requires a quiescent store: seal every "
                "ingested epoch on every shard first")
        if hot_shard in self.retired:
            raise ValueError(f"shard {hot_shard} is retired (merged away)")
        activation = self.coordinator.global_frontier + 1
        new_plan = self.plan.split(hot_shard, activation)
        # the new leaf's shard id, allocated from the plan's monotone
        # physical counter — NOT n_shards-1, which under-counts once a
        # merge has retired a leaf
        target = new_plan.leaves[-1].shard
        if target != len(self.shards):   # pragma: no cover - plan invariant
            raise AssertionError(
                f"plan allocated shard {target}, store has "
                f"{len(self.shards)} physical shards")
        shard = DynamicGraph(self.n_max, self.e_max, self.churn_threshold)
        node = DataNode(target, on_seal=self._on_seal(target))
        # the new shard joins AT the cutover boundary: marking every prior
        # epoch locally sealed is sound because the plan routed it nothing
        # before activation
        node.local_frontier = activation - 1
        self.shards.append(shard)
        self.nodes.append(node)      # shared list: coordinator+ingest see it
        self.shard_apply_seconds.append(0.0)
        self.wal_shards.append(
            self.wal.shard_wal(target) if self.wal is not None else None)
        migrated = self._dispatch_migration(hot_shard, target, new_plan,
                                            activation)
        self.plan = new_plan
        self.route = new_plan.assign
        self.ingest_node.route = new_plan.assign
        self.access_stats.reset(self.n_shards)
        summary = {"kind": "split", "plan_id": new_plan.plan_id,
                   "source": hot_shard, "target": target,
                   "activation_epoch": activation,
                   "migrated_edges": migrated}
        self.migrations.append(summary)
        if self.wal is not None:
            self.wal.record_plan_event("split", hot_shard, target,
                                       activation, migrated)
        return summary

    def merge_shards(self, removed_shard: int) -> dict:
        """Coarsen a split back: fold ``removed_shard``'s half-range into
        its split sibling (the leaf differing only in the newest path
        bit), the inverse of :meth:`split_shard`.

        Same cutover discipline as a split — quiescent store, successor
        plan activating at the next epoch, the retiring shard's live rows
        riding the ordinary ingest path as (delete @ source, add @
        survivor) payload rows at version ``(activation, 0)``, applied
        atomically when that epoch seals. Under the merged plan EVERY
        live key of the removed leaf routes to the survivor, so the
        migration drains the shard completely; it is then retired in
        place (see :attr:`retired`) — pre-cutover snapshots keep
        resolving from its tombstoned rows, post-cutover it seals empty
        epochs. Views are byte-identical across the cutover at every
        sealed version (the merge-coherence tests assert this).

        Returns a summary dict (also appended to :attr:`migrations`).

        Raises:
            ValueError: custom-route store, retired/unknown shard, or a
                shard whose leaf has no split sibling (depth-0 base
                leaves never merge).
            RuntimeError: store not quiescent.
        """
        if self.plan is None:
            raise ValueError("re-sharding needs plan-based routing "
                             "(construct without a custom `route`)")
        if removed_shard in self.retired:
            raise ValueError(f"shard {removed_shard} is already retired")
        if not self.is_quiescent():
            raise RuntimeError(
                "re-sharding requires a quiescent store: seal every "
                "ingested epoch on every shard first")
        survivor = self.plan.sibling_of(removed_shard)
        if survivor is None:
            raise ValueError(
                f"shard {removed_shard} has no split sibling to merge "
                "into (only split halves can coarsen back)")
        activation = self.coordinator.global_frontier + 1
        new_plan = self.plan.merge(removed_shard, activation)
        migrated = self._dispatch_migration(removed_shard, survivor,
                                            new_plan, activation)
        self.plan = new_plan
        self.route = new_plan.assign
        self.ingest_node.route = new_plan.assign
        self.retired.add(removed_shard)
        self.access_stats.reset(self.n_shards)
        summary = {"kind": "merge", "plan_id": new_plan.plan_id,
                   "source": removed_shard, "target": survivor,
                   "activation_epoch": activation,
                   "migrated_edges": migrated}
        self.migrations.append(summary)
        if self.wal is not None:
            # history-tuple order: (survivor, removed)
            self.wal.record_plan_event("merge", survivor, removed_shard,
                                       activation, migrated)
        return summary

    def _dispatch_migration(self, source: int, target: int,
                            new_plan: RoutingPlan, epoch: int) -> int:
        """Dispatch the moving half-range as payload rows at the cutover
        version. Quiescence makes 'live now' == 'live at the cutover
        snapshot', and makes both dispatch targets eligible (no parking)."""
        shard = self.shards[source]
        e = shard.n_edges
        live = np.flatnonzero(shard.deleted[:e] == MAXV)
        if not live.size:
            return 0
        route_keys = shard.dst[live].astype(np.int64)
        rows = live[new_plan.assign(route_keys) != source]
        n = rows.size
        if not n:
            return 0
        v = pack32_checked(Version(epoch, 0))
        payload = np.empty((2 * n, 4), np.int32)
        payload[:, 3] = v
        payload[:n, 0] = K_DEL            # source loses the moving rows...
        payload[n:, 0] = K_ADD            # ...target gains them, same order
        payload[:n, 1] = payload[n:, 1] = shard.src[rows]
        payload[:n, 2] = payload[n:, 2] = shard.dst[rows]
        keys = np.concatenate([shard.dst[rows], shard.dst[rows]]) \
            .astype(np.int64)
        node_ids = np.concatenate([np.full(n, source, np.int64),
                                   np.full(n, target, np.int64)])
        sent = self.ingest_node.dispatch_batch(
            keys, np.full(2 * n, epoch, np.int64), payload,
            node_ids=node_ids)
        if sent != 2 * n:                  # pragma: no cover - guarded above
            raise AssertionError("migration slice parked despite quiescence")
        return n

    def maybe_reshard(self) -> Optional[dict]:
        """Planner tick: consult the :class:`ShardPlanner` on the current
        access ledger and execute the proposed split — or, failing that,
        the proposed cold-sibling merge — if any.

        Safe to call every epoch — returns None (without touching the
        store) when there is no planner, the store is not quiescent, or
        the planner declines both ways. Returns the
        :meth:`split_shard` / :meth:`merge_shards` summary with the
        planner's ``reason`` attached. Retired shards are masked out of
        both decisions (their permanently-zero loads would deflate the
        mean every live shard is compared against)."""
        if self.planner is None or self.plan is None:
            return None
        if not self.is_quiescent():
            return None
        loads = self.access_stats.loads()
        live = np.ones(self.n_shards, bool)
        if self.retired:
            live[list(self.retired)] = False
        decision = self.planner.propose(
            loads, epochs_observed=self.access_stats.epochs_observed,
            live=live)
        if decision is not None:
            summary = self.split_shard(decision.shard)
            summary["reason"] = decision.reason
            return summary
        merge = self.planner.propose_merge(
            loads, epochs_observed=self.access_stats.epochs_observed,
            pairs=self.plan.mergeable_pairs(), live=live)
        if merge is None:
            return None
        summary = self.merge_shards(merge.removed)
        summary["reason"] = merge.reason
        return summary

    def plan_floor(self) -> int:
        """Packed version below which cached artifacts (stitched views,
        per-shard views, PageRank ranks) were built under a retired
        routing plan: ``(activation_epoch, 0)`` of the active plan, or 0
        if no split has happened (nothing is retired). The GC ladders use
        this to drop retired-plan entries outright instead of aging them
        out."""
        if self.plan is None or self.plan.plan_id == 0:
            return 0
        return Version(self.plan.activation_epoch, 0).pack()

    # -- snapshots ---------------------------------------------------------
    def latest_sealed(self) -> Optional[Version]:
        """Newest frontier-sealed snapshot version — the only snapshot an
        online query may be answered against (never a partially-sealed
        epoch). Returns the newest ingested version whose epoch every shard
        has sealed; ``Version(frontier, 0)`` if the sealed epochs carried no
        batches (a sealed empty snapshot is queryable); ``None`` before the
        first global seal. (A re-sharding migration is not an ingested
        version: it changes row placement, never snapshot content.)

        Pure read: no writes, so the serving tier's read plane may call it
        without the write lock. The ingest-log trim that used to piggyback
        on this lookup runs at seal time (:meth:`_trim_ingest_log`)."""
        frontier = self.coordinator.global_frontier
        if frontier < 0:
            return None
        log = self._ingested_packed
        for i in range(len(log) - 1, -1, -1):
            v = Version.unpack(log[i])
            if v.epoch <= frontier:
                return v
        return Version(frontier, 0)

    def _trim_ingest_log(self) -> None:
        """Drop ingest-log entries older than the newest sealed one. The
        frontier is monotone, so those entries can never be
        ``latest_sealed()``'s answer again — trimming at every seal keeps
        the log bounded by the unsealed backlog, not the stream length.
        Runs on the write plane (seal paths) only, which is what lets
        :meth:`latest_sealed` itself be a pure lock-free read."""
        frontier = self.coordinator.global_frontier
        log = self._ingested_packed
        for i in range(len(log) - 1, -1, -1):
            if Version.unpack(log[i]).epoch <= frontier:
                if i > 0:
                    del log[:i]
                return

    def on_frontier_advance(self, fn: Callable[[int], None]) -> None:
        """Subscribe ``fn(new_frontier)`` to global-seal notifications —
        fires whenever an epoch becomes sealed on every shard (i.e. a newer
        consistent snapshot became queryable)."""
        self.coordinator.subscribe(fn)

    def _gate(self, version: Version) -> None:
        if version.epoch > self.coordinator.global_frontier:
            raise ValueError(
                f"epoch {version.epoch} is not globally sealed (frontier "
                f"{self.coordinator.global_frontier}); snapshots become "
                "queryable once every shard seals them")

    def shard_views(self, version: Version,
                    use_kernel: bool = False) -> list[JoinView]:
        """Per-shard join views for a sealed snapshot — pre-sharded input
        for ``partition.partition_graph_sharded`` (no re-bucketing).
        Raises ``ValueError`` if ``version`` is not globally sealed."""
        self._gate(version)
        return [s.join_view(version, use_kernel=use_kernel)
                for s in self.shards]

    def join_view(self, version: Version,
                  use_kernel: bool = False) -> JoinView:
        """The stitched global CSR for a sealed snapshot (cached).
        Byte-identical to the single store's view at the same version —
        including versions older than a re-sharding cutover, which resolve
        from the pre-migration rows. Raises ``ValueError`` if ``version``
        is not globally sealed."""
        key = version.pack()
        if key in self._views:
            return self._views[key]
        view = stitch_join_views(version,
                                 self.shard_views(version,
                                                  use_kernel=use_kernel))
        self._views[key] = view
        return view

    def build_replica_plan(self, version: Version, hot_ids,
                           use_kernel: bool = False) -> ReplicaPlan:
        """Materialize the replica plane for one sealed snapshot: mirror
        the complete live out-adjacency of ``hot_ids`` (gathered from the
        stitched global view, so mirror rows are byte-for-byte snapshot
        rows in canonical order) and build the per-shard ``src_presence``
        locality index from the per-shard views.

        Called by the serving layer at the publish-at-seal boundary —
        rebuilding from ``version``'s own views at every publish IS the
        coherence protocol (invariant I10): a mirror can never be staler
        than the snapshot it is consulted for, because it is derived from
        it. Raises ``ValueError`` if ``version`` is not globally sealed."""
        self._gate(version)
        views = self.shard_views(version, use_kernel=use_kernel)
        n = self.n_max
        mirrored = np.zeros(n, bool)
        ids = np.asarray(hot_ids, np.int64).reshape(-1)
        mirrored[ids[(ids >= 0) & (ids < n)]] = True
        g = self.join_view(version, use_kernel=use_kernel)
        sel = mirrored[g.np_src]
        presence = np.zeros((len(views), n), bool)
        for j, v in enumerate(views):
            presence[j, v.np_src] = True
        pid = self.plan.plan_id if self.plan is not None else -1
        return ReplicaPlan(pid, version, mirrored,
                           g.np_src[sel], g.np_dst[sel], presence)

    def gc_views(self, keep_latest: int = 4) -> int:
        """Ladder-GC every shard's view cache plus the stitched cache,
        and drop entries keyed by retired routing plans.

        After a split, retired entries are dropped instead of aging
        through the ladder: the stitched cache drops everything below the
        active plan's activation (:meth:`plan_floor`), and each shard
        involved in a migration drops its views from before *its own* most
        recent migration (those still carry — or are missing — the moved
        rows; views from between someone else's later split and now are
        untouched, so an old split never wipes another shard's warm
        ladder). In both cases entries only drop once a post-cutover
        entry exists, so the serving snapshot is never evicted from under
        the server. Returns the number dropped."""
        dropped = prune_retired(self._views, self.plan_floor())
        shard_floor: dict[int, int] = {}
        for m in self.migrations:
            fl = Version(m["activation_epoch"], 0).pack()
            for i in (m["source"], m["target"]):
                shard_floor[i] = max(shard_floor.get(i, 0), fl)
        dropped += sum(
            s.gc_views(keep_latest, retire_below=shard_floor.get(i, 0))
            for i, s in enumerate(self.shards))
        return dropped + prune_views(self._views, keep_latest)

    # -- merged vertex/edge state -----------------------------------------
    @property
    def n_edges(self) -> int:
        """Edge rows appended across all shards — the capacity measure,
        not the live-edge count. A re-sharding migration re-appends the
        moving rows on the target shard (and tombstones them on the
        source), so after a split this exceeds the single store's row
        count even though every snapshot's live edges are identical."""
        return sum(s.n_edges for s in self.shards)

    @property
    def v_created(self) -> np.ndarray:
        """Global creation stamps: a vertex exists from the earliest version
        any shard created it (explicit add on its home shard, or endpoint
        auto-creation wherever its edges landed)."""
        out = self.shards[0].v_created.copy()
        for s in self.shards[1:]:
            np.minimum(out, s.v_created, out=out)
        return out

    @property
    def v_type(self) -> np.ndarray:
        """Global vertex types, matching the single store's
        first-creation-wins semantics: the type recorded by whichever
        shard(s) created the vertex at its earliest creation version.

        At that version at most one shard received the *typed* add (routing
        sends a vertex id to exactly one shard per plan); any other shard
        tied at the same version auto-created the vertex untyped (0), so
        the elementwise max over tied shards recovers the typed value —
        with no dependence on the CURRENT route, which re-sharding may
        have changed since the vertex was created."""
        created = self.v_created
        out = np.zeros(self.n_max, np.int32)
        for s in self.shards:
            mine = s.v_created == created
            np.maximum(out, np.where(mine, s.v_type, 0), out=out)
        return out

    @property
    def n_vertices(self) -> int:
        """Vertices created on any shard so far."""
        return int((self.v_created != MAXV).sum())

    def num_vertices(self, version: Optional[Version] = None) -> int:
        """Vertices existing at ``version`` (or now, when None)."""
        if version is None:
            return self.n_vertices
        return int((self.v_created <= pack32_clamped(version)).sum())

    @property
    def view_delta_patches(self) -> int:
        return sum(s.view_delta_patches for s in self.shards)

    @property
    def view_full_builds(self) -> int:
        return sum(s.view_full_builds for s in self.shards)

    def shard_edge_counts(self) -> list[int]:
        """Per-shard live-edge counts (the placement the plan produced)."""
        return [s.n_edges for s in self.shards]
