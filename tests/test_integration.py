"""End-to-end integration: the paper's online/offline loop at LM scale —
offline trainer writes versioned snapshots, online server reads the newest
one without blocking; elastic restart continues training losslessly."""
import jax
import numpy as np
import pytest

from repro.configs import all_configs, reduced
from repro.launch.serve import Server, _opt_like
from repro.launch.train import run
from repro.models import transformer as tf
from repro.train.checkpoint import CheckpointManager


def test_train_snapshot_then_serve(tmp_path):
    cfg = reduced(all_configs()["qwen2.5-14b"], num_layers=2)
    losses, state = run(cfg, steps=12, batch=4, seq=32,
                        ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100)
    srv = Server.from_checkpoint(cfg, str(tmp_path))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out = srv.generate(prompts, 4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def _tiny_cfg():
    return reduced(all_configs()["qwen2.5-14b"], num_layers=1, d_model=32,
                   vocab_size=64, head_dim=8, d_ff=64, loss_chunk=32)


def _params_like(cfg):
    return jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                        tf.param_shapes(cfg))


def test_from_checkpoint_params_only_fallback(tmp_path):
    """A params-only checkpoint (no optimizer leaves) is a legitimate
    STRUCTURE mismatch: from_checkpoint falls back to the narrower shape."""
    cfg = _tiny_cfg()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    CheckpointManager(tmp_path).save({"params": params}, epoch=0, step=1)
    srv = Server.from_checkpoint(cfg, str(tmp_path))
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(srv.params)[0]),
                                  np.asarray(jax.tree.leaves(params)[0]))


def test_from_checkpoint_surfaces_corruption(tmp_path):
    """Regression: a corrupt checkpoint must raise its REAL error, not be
    swallowed by the structure-shape retry. Here the optimizer subtree is
    corrupted (pickled object array): the old bare-except fallback would
    silently serve params and mask the corruption."""
    cfg = _tiny_cfg()
    params_like = _params_like(cfg)
    mgr = CheckpointManager(tmp_path)
    mgr.save({"params": params_like, **_opt_like(params_like)},
             epoch=0, step=1)
    fname = mgr.index.get("ckpt")
    data = dict(np.load(tmp_path / fname))
    corrupt_key = next(k for k in data if k.startswith("opt/"))
    data[corrupt_key] = np.array([object()], dtype=object)   # needs pickle
    np.savez(tmp_path / fname, **data)
    with pytest.raises(ValueError, match="allow_pickle|Object arrays"):
        Server.from_checkpoint(cfg, str(tmp_path))


def test_failure_plus_serve_consistency(tmp_path):
    """A crash mid-training does not corrupt the snapshot the server sees."""
    cfg = reduced(all_configs()["recurrentgemma-2b"], num_layers=3)
    losses, state = run(cfg, steps=14, batch=2, seq=24,
                        ckpt_dir=str(tmp_path), ckpt_every=4, fail_at=9,
                        log_every=100)
    assert int(state["step"]) == 14          # recovered and completed
    srv = Server.from_checkpoint(cfg, str(tmp_path))
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (1, 6)).astype(np.int32)
    out = srv.generate(prompts, 3)
    assert np.isfinite(out).all()
