"""Elastic scaling + gradient compression example.

Trains, checkpoints a versioned snapshot, then 'loses' half the cluster:
restores snapshot(v) and reshards the state onto a smaller mesh (here CPU
meshes; the same code path drives the 256->512 chip pod growth). Also shows
the int8 error-feedback compression path.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

import jax

from repro.configs import all_configs, reduced
from repro.launch.steps import make_train_step
from repro.launch.train import run
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import elastic_restart


def main():
    cfg = reduced(all_configs()["qwen2.5-14b"], num_layers=2)
    with tempfile.TemporaryDirectory() as d:
        print("phase 1: train 25 steps with int8 grad compression")
        losses, state = run(cfg, steps=25, batch=8, seq=32, ckpt_dir=d,
                            ckpt_every=10, compress=True, log_every=10)

        print("phase 2: elastic restart on a new mesh from snapshot(v)")
        mgr = CheckpointManager(d)
        new_mesh = jax.make_mesh((1, 1), ("data", "model"))
        state2 = elastic_restart(cfg, mgr, state, new_mesh)
        assert 0 < int(state2["step"]) <= 25
        mesh_shape = dict(
            zip(new_mesh.axis_names, new_mesh.devices.shape, strict=True))
        print(f"  restored at step {int(state2['step'])}, resharded to "
              f"mesh {mesh_shape}")

        print("phase 3: resume training on the new mesh")
        step_fn = jax.jit(make_train_step(cfg))
        from repro.train.data import TokenPipeline
        pipe = TokenPipeline(cfg.vocab_size, 8, 32, seed=0)
        i = int(state2["step"])
        for j in range(i, i + 5):
            state2, metrics = step_fn(state2, pipe.batch_view(j).value())
        print(f"  resumed {i} -> {int(state2['step'])}, "
              f"loss={float(metrics['loss']):.4f}")
        print("OK")


if __name__ == "__main__":
    main()
