"""Versioned data sets and snapshots — paper §2.3.1 (Fig 3).

Every data item carries versions ``(epoch, version)``; a mutation creates a
new version. A snapshot is resolved with the paper's rule::

    snapshot(v) = { d(i_v) },   i_v = max { v' <= v }

Two implementations share the rule:

* :class:`VersionedStore` — host-side multi-version KV store (control plane:
  checkpoints, schemas, replica directory entries).
* :func:`resolve_versions` / :class:`VersionedArray` — JAX data plane: a
  vectorized ``searchsorted`` resolves whole columns of versioned items at
  once (used by the dynamic graph store for snapshot masks).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Any, Iterable, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True, order=True)
class Version:
    """Paper Fig 3(a): epoch identifier + version number within the epoch."""
    epoch: int
    number: int

    def pack(self) -> int:
        return (self.epoch << 32) | self.number

    @staticmethod
    def unpack(packed: int) -> "Version":
        return Version(packed >> 32, packed & 0xFFFFFFFF)


ZERO = Version(0, 0)

# Data-plane packing: int32-safe (x64 is disabled in JAX by default).
# Host-side control plane uses the full 64-bit pack(); the graph store keeps
# its created/deleted/v_created stamp arrays in THIS packing natively so the
# kernel path never re-packs on the host. int32 max is reserved as the
# 'never' sentinel, so the largest valid stamp is int32 max - 1.
PACK_BITS = 20
EPOCH_LIMIT = 1 << (31 - PACK_BITS)      # epochs representable in int32
NUMBER_LIMIT = 1 << PACK_BITS            # version numbers per epoch
PACK32_NEVER = np.iinfo(np.int32).max    # 'never created/deleted' sentinel


def pack32(v: Version) -> int:
    assert v.epoch < EPOCH_LIMIT and v.number < NUMBER_LIMIT, v
    return (v.epoch << PACK_BITS) | v.number


def pack32_checked(v: Version) -> int:
    """int32 data-plane packing of a *stamp* about to be stored.

    Raises ``ValueError`` (not an assert — overflow would silently corrupt
    every later snapshot mask) when the version exceeds the packing, or
    collides with the reserved ``PACK32_NEVER`` sentinel. The graph store
    calls this once per ``apply`` — the single overflow check of the
    int32-native stamp plane.
    """
    packed = (v.epoch << PACK_BITS) | v.number
    if (v.epoch >= EPOCH_LIMIT or v.number >= NUMBER_LIMIT
            or packed >= PACK32_NEVER):
        raise ValueError(
            "version stamp exceeds int32 data-plane packing "
            f"(epoch < {EPOCH_LIMIT}, number < {NUMBER_LIMIT}, "
            f"int32 max reserved): {v}")
    return packed


def unpack32(packed: int) -> Version:
    """Inverse of :func:`pack32` (valid for checked stamps, which never
    collide with the sentinel)."""
    return Version(packed >> PACK_BITS, packed & (NUMBER_LIMIT - 1))


def pack32_clamped(v: Version) -> int:
    """int32 packing of a *query* version, clamped into the packable range.

    Stored stamps are range-checked at apply time, but a query may name any
    version (e.g. a far-future snapshot). Clamping each field to its limit
    preserves the ordering against every valid stamp: an in-range epoch
    with an overflowing number clamps to that epoch's last slot (sees all
    of the epoch, none of the next); an overflowing epoch clamps to the
    largest valid stamp (sees everything, never the sentinel).
    """
    packed = (min(v.epoch, EPOCH_LIMIT - 1) << PACK_BITS) \
        | min(v.number, NUMBER_LIMIT - 1)
    return min(packed, PACK32_NEVER - 1)


class VersionedStore:
    """Multi-version key-value items (paper Fig 3(b))."""

    def __init__(self):
        # key -> (sorted list of packed versions, list of values)
        self._items: dict[Any, tuple[list[int], list[Any]]] = {}

    def put(self, key, version: Version, value) -> None:
        vs, vals = self._items.setdefault(key, ([], []))
        packed = version.pack()
        idx = bisect.bisect_left(vs, packed)
        if idx < len(vs) and vs[idx] == packed:
            raise ValueError(f"version {version} of {key!r} already written "
                             "(versions are immutable)")
        vs.insert(idx, packed)
        vals.insert(idx, value)

    def get(self, key, version: Optional[Version] = None):
        """Paper's snapshot rule: value at max version <= requested."""
        if key not in self._items:
            raise KeyError(key)
        vs, vals = self._items[key]
        if version is None:
            return vals[-1]
        idx = bisect.bisect_right(vs, version.pack()) - 1
        if idx < 0:
            raise KeyError(f"{key!r} has no version <= {version}")
        return vals[idx]

    def versions(self, key) -> list[Version]:
        return [Version.unpack(p) for p in self._items.get(key, ([], []))[0]]

    def keys(self) -> Iterable:
        return self._items.keys()

    def snapshot(self, version: Version) -> dict:
        """Materialize {key: d(i_v)} for all keys with a version <= v."""
        out = {}
        for key in self._items:
            try:
                out[key] = self.get(key, version)
            except KeyError:
                pass
        return out

    def gc_below(self, version: Version) -> int:
        """Collect obsolete versions: keep, per key, only the newest version
        <= v (still addressable by snapshot(v)) plus everything > v.
        Returns number of dropped versions (paper §2.2 'obsolete replicas')."""
        dropped = 0
        packed = version.pack()
        for key, (vs, vals) in self._items.items():
            idx = bisect.bisect_right(vs, packed) - 1
            if idx > 0:
                del vs[:idx]
                del vals[:idx]
                dropped += idx
        return dropped


def resolve_versions(item_versions, query_version):
    """Vectorized snapshot rule over a column of packed versions.

    item_versions: (N, K) packed versions per item, sorted ascending along K,
    padded with ``jnp.iinfo(int64).max`` for unused slots.
    Returns (N,) index i_v into K of max version <= query, or -1 if none.
    """
    item_versions = jnp.asarray(item_versions)
    q = jnp.asarray(query_version, item_versions.dtype)
    # searchsorted per row: count of versions <= q, minus one
    return jnp.sum(item_versions <= q, axis=-1) - 1


class VersionedArray:
    """A fixed-capacity multi-version array column (JAX data plane).

    values: (N, K) — K version slots per item; versions: (N, K) packed,
    ascending, MAX-padded. Snapshot read = one vectorized resolve + gather.
    """

    MAXV = np.iinfo(np.int32).max

    def __init__(self, n_items: int, capacity: int, dtype=jnp.float32):
        self.values = jnp.zeros((n_items, capacity), dtype)
        self.versions = jnp.full((n_items, capacity), self.MAXV, jnp.int32)
        self.fill = jnp.zeros((n_items,), jnp.int32)

    def write(self, item_ids, version: Version, new_values):
        """Append a new version for the given items (one mutation batch)."""
        item_ids = jnp.asarray(item_ids)
        slots = self.fill[item_ids]
        self.values = self.values.at[item_ids, slots].set(new_values)
        self.versions = self.versions.at[item_ids, slots].set(pack32(version))
        self.fill = self.fill.at[item_ids].add(1)
        return self

    def read_snapshot(self, version: Version, default=0):
        idx = resolve_versions(self.versions, pack32(version))
        safe = jnp.maximum(idx, 0)
        vals = jnp.take_along_axis(self.values, safe[:, None], axis=1)[:, 0]
        return jnp.where(idx >= 0, vals, default)
