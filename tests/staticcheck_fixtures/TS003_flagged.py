"""TS003 fixture: Python iteration over a traced value inside jit."""
import jax


@jax.jit
def accumulate(xs):
    total = 0.0
    for row in xs:               # TS003: unrolls per traced element
        total = total + row.sum()
    return total
