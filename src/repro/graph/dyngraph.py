"""Versioned dynamic graph store — the JAX data plane of the paper's data
model.

JAX needs static shapes, so the graph is a capacity-bounded *multi-version*
edge/vertex store: a mutation never overwrites — an edge add writes a row
stamped ``created=v``; an edge delete stamps ``deleted=v``. A snapshot is a
*mask* (``created <= v < deleted``), which is exactly the paper's Fig 3(b)
multi-version item semantics (every version stays addressable), vectorized.

Ingestion (``apply``) is fully vectorized and indexed:

* vertex adds, edge-row appends, and endpoint auto-creation are batched
  NumPy ops — O(batch) with no per-element Python work on arrays;
* edge deletes resolve through a ``(src, dst) -> latest live row``
  :class:`LiveEdgeIndex` — a NumPy open-addressing hash table (int64 key
  slots, int32 row slots, linear probing, batched probe rounds) backed by
  a per-row ``prev-live`` chain (a LIFO stack per key). Both the insert
  and the pop side are whole-batch array ops with **no per-row Python
  loop**, so a threaded caller (the sharded store's parallel apply plane)
  spends the batch inside NumPy kernels that release the GIL instead of
  serialising on a Python dict.

Version stamps (``created`` / ``deleted`` / ``v_created``) are stored
natively in the int32 data-plane packing (``versioned.PACK_BITS``; int32
max is the 'never' sentinel), checked once for overflow at ``apply`` time
(``pack32_checked``). The 64-bit ``Version.pack()`` survives only at the
API boundary (view-cache keys, the batch log, sharded payload rows), so
``snapshot_mask(use_kernel=True)`` hands the stamp arrays straight to the
Pallas kernel — no 64→32-bit host conversion on the hot path.

The per-snapshot CSR ("join view", §2.3.3.2) is built once per queried
version and cached — it is what makes the join-group-by operator a segment
reduction. Views are maintained **delta-first**: when a view for an earlier
version is cached, the CSR for the requested version is patched from the
mutation delta (sorted-merge row insert/remove + incremental degree
updates) in O(m + |delta| log |delta|) instead of the full O(E + m log m)
mask-and-re-sort rebuild; past a churn threshold (delta larger than
``churn_threshold`` · m) it falls back to the full rebuild. Rows are kept
in canonical ``(dst, src)`` order so the delta patch and the full rebuild
produce byte-identical CSRs.

``apply`` also evicts cached views with version >= the incoming batch (a
snapshot cached for a not-yet-applied future version would silently go
stale otherwise).

On TPU the snapshot-mask resolution can route through the Pallas
``snapshot_resolve`` kernel (``use_kernel=True``): liveness is a 2-slot
multi-version resolve per edge ([created, deleted] -> [1, 0]).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.versioned import (PACK32_NEVER, Version, pack32_checked,
                                  pack32_clamped)

# 'never created / never deleted' stamp sentinel. Stamps are int32
# data-plane packed natively (versioned.PACK_BITS); int32 max is reserved.
MAXV = PACK32_NEVER

# Delta-patching a cached view wins while the delta is small relative to the
# live edge count; past this fraction a full mask-and-sort rebuild is cheaper.
DEFAULT_CHURN_THRESHOLD = 0.25


def splitmix64(x: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer (vectorized). Shared by :class:`LiveEdgeIndex`
    (slot hashing) and the sharded store's ``RoutingPlan`` (split-bit
    refinement hash): one well-mixed integer hash, two consumers."""
    x = np.asarray(x)
    # int64 input (the common case: edge keys, routing keys) reinterprets
    # bit-for-bit instead of paying a widening copy
    x = x.view(np.uint64) if x.dtype == np.int64 else x.astype(np.uint64)
    x = (x + np.uint64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


class LiveEdgeIndex:
    """Vectorized ``(src, dst) key -> newest live row`` map.

    Open-addressing hash table over parallel NumPy arrays — int64 key
    slots (-1 = empty), int32 row slots (-1 = key present but no live row)
    — with linear probing. Lookups and insert-or-update both run in
    *batched probe rounds*: every still-unresolved key advances one slot
    per round, so the Python-level cost is O(max probe length) loop
    iterations of whole-array work, not O(batch) per-row dict operations.
    Within an insert round, several distinct keys may claim the same empty
    slot; a scatter race arbitrates (duplicate-index scatter keeps the
    last write — whichever key remains in the slot won) and the losers
    keep probing past the now-occupied slot, which is ordinary
    linear-probing semantics.

    Emptied keys (every duplicate popped) keep their slot with row -1
    rather than tombstoning — lookups return -1 either way — and are
    dropped wholesale on the next growth rehash, which bounds table
    occupancy by the live key count, not the all-time key count.
    """

    EMPTY = -1

    def __init__(self, capacity: int = 1024):
        cap = 1 << max(3, int(capacity - 1).bit_length())
        self._keys = np.full(cap, self.EMPTY, np.int64)
        self._rows = np.full(cap, -1, np.int32)
        self._used = 0          # occupied slots, live or emptied

    @property
    def capacity(self) -> int:
        return len(self._keys)

    def _first_slots(self, keys: np.ndarray) -> np.ndarray:
        return (splitmix64(keys)
                & np.uint64(len(self._keys) - 1)).astype(np.int64)

    def slots_of(self, keys: np.ndarray) -> np.ndarray:
        """Table slot per key (-1 when absent), batched — one probe pass.

        The delete path uses this to read AND later write the same keys'
        rows (:meth:`rows_at` / :meth:`set_rows`) with a single probing
        pass instead of a lookup pass plus a store pass. Returned slots
        are invalidated by any subsequent insert (growth rehash).
        """
        keys = np.asarray(keys, np.int64)
        out = np.full(len(keys), -1, np.int64)
        if not len(keys) or not self._used:
            return out
        mask = len(self._keys) - 1
        slot = self._first_slots(keys)
        pending = np.arange(len(keys))
        while pending.size:
            s = slot[pending]
            tk = self._keys[s]
            hit = tk == keys[pending]
            out[pending[hit]] = s[hit]
            pending = pending[~(hit | (tk == self.EMPTY))]
            slot[pending] = (slot[pending] + 1) & mask
        return out

    def rows_at(self, slots: np.ndarray) -> np.ndarray:
        """Rows stored at ``slots_of`` results (-1 rides through for
        absent keys)."""
        out = np.full(len(slots), -1, np.int64)
        found = slots >= 0
        out[found] = self._rows[slots[found]]
        return out

    def set_rows(self, slots: np.ndarray, rows: np.ndarray) -> None:
        """Overwrite the rows at valid (>= 0) slots in place (-1 row =
        mark emptied). No probing, no inserts — slot-stable."""
        self._rows[slots] = rows

    def lookup(self, keys: np.ndarray) -> np.ndarray:
        """Newest live row per key (-1 when absent or emptied), batched."""
        return self.rows_at(self.slots_of(keys))

    def push(self, keys: np.ndarray, rows: np.ndarray) -> np.ndarray:
        """Insert-or-update UNIQUE ``key -> row`` and return each key's
        *previous* row (-1 when absent or emptied) — a fused
        lookup + store in one probe pass. The add path chains the batch's
        oldest duplicate to the returned previous top while the newest
        duplicate becomes the stored row."""
        keys = np.asarray(keys, np.int64)
        old = np.full(len(keys), -1, np.int64)
        if not len(keys):
            return old
        self._maybe_grow(len(keys))
        rows32 = np.asarray(rows, np.int32)
        mask = len(self._keys) - 1
        slot = self._first_slots(keys)
        pending = np.arange(len(keys))
        while pending.size:
            s = slot[pending]
            tk = self._keys[s]
            hit = tk == keys[pending]
            if hit.any():
                hs, hp = s[hit], pending[hit]
                old[hp] = self._rows[hs]
                self._rows[hs] = rows32[hp]
            resolved = hit
            empty = tk == self.EMPTY
            if empty.any():
                pos = np.flatnonzero(empty)
                se, cand = s[pos], pending[pos]
                self._keys[se] = keys[cand]          # scatter race: the key
                won = self._keys[se] == keys[cand]   # left standing won
                if won.any():
                    self._rows[se[won]] = rows32[cand[won]]
                    self._used += int(won.sum())
                    resolved = resolved.copy()
                    resolved[pos[won]] = True
            pending = pending[~resolved]
            slot[pending] = (slot[pending] + 1) & mask
        return old

    def store(self, keys: np.ndarray, rows: np.ndarray) -> None:
        """Insert-or-update ``key -> row`` for UNIQUE keys, batched.

        ``row`` -1 marks an existing key's stack as emptied (the pop side
        never needs to insert: it only updates keys it just looked up).
        """
        keys = np.asarray(keys, np.int64)
        if not len(keys):
            return
        self._maybe_grow(len(keys))
        rows32 = np.asarray(rows, np.int32)
        mask = len(self._keys) - 1
        slot = self._first_slots(keys)
        pending = np.arange(len(keys))
        while pending.size:
            s = slot[pending]
            tk = self._keys[s]
            hit = tk == keys[pending]
            self._rows[s[hit]] = rows32[pending[hit]]
            resolved = hit
            empty = tk == self.EMPTY
            if empty.any():
                pos = np.flatnonzero(empty)
                se, cand = s[pos], pending[pos]
                self._keys[se] = keys[cand]          # scatter race: the key
                won = self._keys[se] == keys[cand]   # left standing won
                if won.any():
                    self._rows[se[won]] = rows32[cand[won]]
                    self._used += int(won.sum())
                    resolved = resolved.copy()
                    resolved[pos[won]] = True
            pending = pending[~resolved]
            slot[pending] = (slot[pending] + 1) & mask

    def _maybe_grow(self, incoming: int) -> None:
        # keep load factor <= 2/3 so probe chains stay short
        if (self._used + incoming) * 3 <= len(self._keys) * 2:
            return
        live = self._rows != -1            # emptied keys are dropped here
        lk, lr = self._keys[live], self._rows[live]
        need = len(lk) + incoming
        cap = len(self._keys)
        while cap * 2 < need * 3:
            cap <<= 1
        self._keys = np.full(cap, self.EMPTY, np.int64)
        self._rows = np.full(cap, -1, np.int32)
        self._used = 0
        if len(lk):
            self.store(lk, lr)


@dataclasses.dataclass
class MutationBatch:
    """One epoch's worth of mutations (vectorized)."""
    version: Version
    add_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    add_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    del_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    del_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    add_vertices: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    vertex_types: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))

    def __post_init__(self):
        # every consumer (vectorized store, loop oracle, sharded encoder)
        # pairs add_vertices with vertex_types elementwise; a silent
        # truncation to the shorter of the two would drop vertex adds on
        # one path but not another, so the mismatch is resolved here once:
        # missing types default to 0 (untyped), surplus types are an error
        nv, nt = len(self.add_vertices), len(self.vertex_types)
        if nt > nv:
            raise ValueError(
                f"vertex_types has {nt} entries for {nv} add_vertices; "
                "a type without a vertex is meaningless")
        if nt < nv:
            self.vertex_types = np.concatenate(
                [np.asarray(self.vertex_types, np.int32),
                 np.zeros(nv - nt, np.int32)])

    @property
    def size(self) -> int:
        return (len(self.add_src) + len(self.del_src) + len(self.add_vertices))


@dataclasses.dataclass
class _BatchDelta:
    """Per-batch ingestion record: which store rows the batch touched.
    Lets ``join_view`` enumerate a version delta in O(|delta|)."""
    version: int                # packed
    row_start: int              # appended rows: [row_start, row_end)
    row_end: int
    del_rows: np.ndarray        # rows tombstoned by this batch


@dataclasses.dataclass
class JoinView:
    """CSR of one snapshot: dst-grouped in-edges (the join view).

    Rows are in canonical (dst, src) order. The trailing ``np_*`` fields are
    host-side state for O(delta) incremental maintenance.
    """
    version: Version
    n: int
    offsets: jnp.ndarray       # (n+1,)
    src: jnp.ndarray           # (m,) source vertex per in-edge
    dst: jnp.ndarray           # (m,)
    out_degree: jnp.ndarray    # (n,)
    in_degree: jnp.ndarray     # (n,)
    np_keys: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)    # (m,) int64 (dst<<32)|src, ascending
    np_src: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)
    np_dst: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)
    np_in_deg: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)    # (n,) int64
    np_out_deg: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)    # (n,) int64

    @property
    def m(self) -> int:
        return int(self.src.shape[0])


def _edge_keys(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    return (dst.astype(np.int64) << 32) | src.astype(np.int64)


def prune_views(views: dict, budget: int) -> int:
    """Drop cached views down to the :func:`ladder_keep` retention set,
    in place. Shared by the single store and the sharded stitched cache so
    the retention policy cannot diverge. Returns the number dropped."""
    if len(views) <= budget:
        return 0
    keep = set(ladder_keep(sorted(views, reverse=True), budget))
    drop = [k for k in views if k not in keep]
    for k in drop:
        del views[k]
    return len(drop)


def prune_retired(views: dict, floor: int) -> int:
    """Drop cached entries with version key < ``floor`` — but only once an
    entry at or above the floor exists, so the newest pre-floor entry keeps
    serving (and warm-starting) until the successor it waits on is cached.

    The sharded store uses this after a re-sharding migration: entries
    below the active routing plan's activation version were built under a
    retired plan and will never be served again once the first post-cutover
    snapshot exists. Returns the number dropped.
    """
    if floor <= 0 or not any(k >= floor for k in views):
        return 0
    drop = [k for k in views if k < floor]
    for k in drop:
        del views[k]
    return len(drop)


def build_join_view(version: Version, n: int, keys, src_s, dst_s,
                    in_deg, out_deg) -> JoinView:
    """Assemble a JoinView from canonical (dst, src)-ordered rows + degree
    arrays. Shared by the single store, the delta patcher, and the sharded
    stitcher so all three produce byte-identical CSRs."""
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum(in_deg, out=offsets[1:])
    return JoinView(version, n, jnp.asarray(offsets),
                    jnp.asarray(src_s), jnp.asarray(dst_s),
                    jnp.asarray(out_deg.astype(np.float32)),
                    jnp.asarray(in_deg.astype(np.float32)),
                    np_keys=keys, np_src=src_s, np_dst=dst_s,
                    np_in_deg=np.asarray(in_deg, np.int64),
                    np_out_deg=np.asarray(out_deg, np.int64))


def ladder_keep(keys_desc: list[int], budget: int) -> list[int]:
    """Pick which cached view versions to retain under a budget: a
    version-spaced ladder rather than the newest K.

    With delta maintenance the best rebuild base is the *nearest older*
    view, so newest-K retention leaves every pre-window version with no
    nearby base (ROADMAP: churn-adaptive view GC). Retention is an
    exponential histogram over distance-from-newest: bucket j spans
    distances [d·2^j, d·2^(j+1)) where d is the gap to the second-newest
    view, and the nearest view per bucket is kept, for at most
    ``budget - 1`` buckets. Any version inside the span then has a
    retained base within ~2x its distance from the frontier, and —
    crucially for repeated GC under a live stream — views beyond the last
    rung are dropped no matter what, so the retained set (and the
    ingestion delta log floored at its minimum) tracks the frontier
    instead of pinning the oldest view forever. ``budget`` is a cap (a
    bucket can swallow several views, so fewer may be retained).

    ``keys_desc`` must be sorted descending; returns the retained subset
    (descending). The two newest entries are always kept, so budget 2
    degenerates to newest-2 exactly.
    """
    n = len(keys_desc)
    if budget <= 0 or n == 0:
        return []
    if budget >= n:
        return list(keys_desc)
    newest = keys_desc[0]
    d_min = max(newest - keys_desc[1], 1)
    keep = [newest]
    last_bucket = -1
    for k in keys_desc[1:]:
        bucket = ((newest - k) // d_min).bit_length() - 1
        if bucket > budget - 2:
            break                      # beyond the last rung: drop the tail
        if bucket > last_bucket and len(keep) < budget:
            keep.append(k)
            last_bucket = bucket
    return keep


class DynamicGraph:
    """Capacity-bounded versioned edge store + vertex table."""

    def __init__(self, n_max: int, e_max: int,
                 churn_threshold: float = DEFAULT_CHURN_THRESHOLD):
        self.n_max = n_max
        self.e_max = e_max
        self.churn_threshold = churn_threshold
        self.src = np.zeros(e_max, np.int32)
        self.dst = np.zeros(e_max, np.int32)
        # version stamps live in the int32 data-plane packing natively
        # (MAXV = int32 max = 'never'); overflow is checked once per apply
        self.created = np.full(e_max, MAXV, np.int32)
        self.deleted = np.full(e_max, MAXV, np.int32)
        self.n_edges = 0
        self.v_created = np.full(n_max, MAXV, np.int32)
        self.v_type = np.zeros(n_max, np.int32)
        self.n_vertices = 0
        self.versions: list[Version] = []
        self._views: dict[int, JoinView] = {}
        # (src, dst) -> latest live row; _prev_live chains to the previous
        # live row with the same key (LIFO, matching "delete the newest
        # live duplicate" semantics). Pre-sized for e_max distinct keys at
        # <= 2/3 load so the steady-state stream never pays a rehash.
        self._index = LiveEdgeIndex(capacity=(e_max * 3 + 1) // 2)
        self._prev_live = np.full(e_max, -1, np.int64)
        self._batch_log: list[_BatchDelta] = []
        # records with version <= _log_floor have been trimmed (gc_views);
        # delta patching is only valid from bases at or above the floor
        self._log_floor = -1
        # telemetry for the delta-view path (benchmarks read these)
        self.view_full_builds = 0
        self.view_delta_patches = 0

    # -- ingestion ---------------------------------------------------------
    def apply(self, batch: MutationBatch) -> None:
        v = batch.version.pack()
        if self.versions and v <= self.versions[-1].pack():
            raise ValueError("mutation batches must have increasing versions")
        # the single overflow check of the int32-native stamp plane; raises
        # (like the capacity check below) before any state mutates
        v32 = pack32_checked(batch.version)
        if self.n_edges + len(batch.add_src) > self.e_max:
            # checked before any state mutates so a failed apply is a no-op
            raise MemoryError("edge capacity exceeded")
        # a view cached for a future version is invalidated by this batch
        stale = [k for k in self._views if k >= v]
        for k in stale:
            del self._views[k]
        # vertex adds (typed): first occurrence per id wins within a batch
        # (lengths are normalized by MutationBatch.__post_init__)
        if len(batch.add_vertices):
            vids, first = np.unique(batch.add_vertices, return_index=True)
            new = self.v_created[vids] == MAXV
            vids, first = vids[new], first[new]
            self.v_created[vids] = v32
            self.v_type[vids] = batch.vertex_types[first]
            self.n_vertices += len(vids)
        # edge adds: append rows
        k = len(batch.add_src)
        row_start = self.n_edges
        if k:
            sl = slice(self.n_edges, self.n_edges + k)
            self.src[sl] = batch.add_src
            self.dst[sl] = batch.add_dst
            self.created[sl] = v32
            self.deleted[sl] = MAXV
            # auto-create endpoint vertices (untyped). Large batches use a
            # boolean scatter over the vertex table (O(n_max), but plain
            # ufunc/scatter passes); small batches on a large store keep
            # the O(k log k) unique+gather so a serving-tail delta never
            # pays a full vertex-table scan
            if 4 * k >= self.n_max:
                touched = np.zeros(self.n_max, bool)
                touched[batch.add_src] = True
                touched[batch.add_dst] = True
                touched &= self.v_created == MAXV
                self.v_created[touched] = v32
                self.n_vertices += int(np.count_nonzero(touched))
            else:
                ends = np.unique(np.concatenate([batch.add_src,
                                                 batch.add_dst]))
                new = ends[self.v_created[ends] == MAXV]
                self.v_created[new] = v32
                self.n_vertices += len(new)
            # push the new rows onto their keys' live stacks, whole-batch:
            # a stable key sort groups duplicates in arrival order, so each
            # duplicate chains to its predecessor in the run; one fused
            # probe pass (push) then swaps each key's previous top out —
            # run heads chain to it — and its run tail (newest dup) in
            rows = np.arange(row_start, row_start + k, dtype=np.int64)
            keys = _edge_keys(batch.add_src, batch.add_dst)
            order = np.argsort(keys, kind="stable")
            sk, sr = keys[order], rows[order]
            head = np.r_[True, sk[1:] != sk[:-1]]
            dup = np.flatnonzero(~head)
            self._prev_live[sr[dup]] = sr[dup - 1]
            tail = np.r_[head[1:], True]
            self._prev_live[sr[head]] = self._index.push(sk[head], sr[tail])
            self.n_edges += k
        # edge deletes: pop the newest live row matching (src, dst) —
        # batched. Duplicated delete keys pop successive stack entries:
        # round t tombstones the t-th duplicate of every key that still
        # has a live row, walking the prev-live chains one hop per round
        # (rounds = max per-key duplication, typically 1).
        del_rows = np.zeros(0, np.int64)
        if len(batch.del_src):
            dkeys = _edge_keys(batch.del_src, batch.del_dst)
            order = np.argsort(dkeys, kind="stable")
            sk = dkeys[order]
            head = np.flatnonzero(np.r_[True, sk[1:] != sk[:-1]])
            uk = sk[head]
            counts = np.diff(np.r_[head, len(sk)])
            # one probe pass resolves each key's slot; the new tops are
            # written straight back to those slots (no inserts happen in
            # between, so the slots stay valid)
            slots = self._index.slots_of(uk)
            top = self._index.rows_at(slots)
            popped = top >= 0          # keys with no live row: ignore (seed)
            cur = top
            parts = []
            t = 0
            while True:
                act = (cur >= 0) & (counts > t)
                if not act.any():
                    break
                rows_t = cur[act]
                self.deleted[rows_t] = v32
                parts.append(rows_t)
                cur[act] = self._prev_live[rows_t]
                t += 1
            if parts:
                del_rows = np.concatenate(parts)
            if popped.any():
                self._index.set_rows(slots[popped], cur[popped])
        self._batch_log.append(_BatchDelta(
            v, row_start, self.n_edges, del_rows))
        self.versions.append(batch.version)

    def _rebuild_index(self) -> None:
        """Rebuild the live-edge hash index and prev-live chains from the
        stamp arrays — the crash-recovery path after a checkpoint restores
        ``src``/``dst``/``created``/``deleted`` wholesale.

        Correctness: pushes happen in row order and a delete always pops
        the newest live duplicate, so a key's live stack is at every
        moment an ascending run of row ids — the live rows in ascending
        order ARE the stack bottom-to-top. Re-pushing them with the
        apply path's stable-sort chaining therefore reproduces the index
        state the uncrashed store would hold (dead rows' stale chain
        entries are unobservable: only live rows are ever walked).
        """
        self._index = LiveEdgeIndex(capacity=(self.e_max * 3 + 1) // 2)
        self._prev_live = np.full(self.e_max, -1, np.int64)
        e = self.n_edges
        live = np.flatnonzero(self.deleted[:e] == MAXV)
        if not live.size:
            return
        keys = _edge_keys(self.src[live], self.dst[live])
        order = np.argsort(keys, kind="stable")
        sk, sr = keys[order], live[order]
        head = np.r_[True, sk[1:] != sk[:-1]]
        dup = np.flatnonzero(~head)
        self._prev_live[sr[dup]] = sr[dup - 1]
        tail = np.r_[head[1:], True]
        self._prev_live[sr[head]] = self._index.push(sk[head], sr[tail])

    # -- snapshots -----------------------------------------------------------
    def snapshot_mask(self, version: Version,
                      use_kernel: bool = False) -> np.ndarray:
        """created <= v < deleted — the paper's snapshot rule on edges.

        ``use_kernel`` routes the resolve through the Pallas
        ``snapshot_resolve`` kernel (liveness as a 2-slot multi-version
        resolve); the NumPy path is the portable host fallback. Stamps are
        int32 data-plane packed natively, so the kernel consumes the
        stored arrays directly — no 64→32-bit host conversion here.
        """
        v32 = pack32_clamped(version)
        e = self.n_edges
        if use_kernel:
            from repro.kernels import ops
            mask = ops.liveness_mask(self.created[:e], self.deleted[:e], v32)
            return np.asarray(mask)
        return (self.created[:e] <= v32) & (v32 < self.deleted[:e])

    def num_vertices(self, version: Optional[Version] = None) -> int:
        if version is None:
            return self.n_vertices
        return int((self.v_created <= pack32_clamped(version)).sum())

    def join_view(self, version: Version,
                  use_kernel: bool = False) -> JoinView:
        """Return (and cache) the dst-grouped CSR for a snapshot.

        Prefers patching the newest cached view at an earlier version with
        the mutation delta; falls back to a full rebuild when no usable base
        exists or the delta exceeds the churn threshold.
        """
        key = version.pack()
        if key in self._views:
            return self._views[key]
        view = self._delta_patch(key, version)
        if view is None:
            view = self._full_rebuild(version, use_kernel=use_kernel)
            self.view_full_builds += 1
        else:
            self.view_delta_patches += 1
        self._views[key] = view
        return view

    def _full_rebuild(self, version: Version,
                      use_kernel: bool = False) -> JoinView:
        mask = self.snapshot_mask(version, use_kernel=use_kernel)
        src = self.src[:self.n_edges][mask]
        dst = self.dst[:self.n_edges][mask]
        keys = _edge_keys(src, dst)
        order = np.argsort(keys, kind="stable")
        return self._make_view(version, keys[order], src[order], dst[order],
                               np.bincount(dst, minlength=self.n_max),
                               np.bincount(src, minlength=self.n_max))

    def _make_view(self, version: Version, keys, src_s, dst_s,
                   in_deg, out_deg) -> JoinView:
        return build_join_view(version, self.n_max, keys, src_s, dst_s,
                               in_deg, out_deg)

    def _delta_patch(self, key: int, version: Version) -> Optional[JoinView]:
        """Patch the newest cached view with version < key, or None if no
        base is usable / the churn threshold is exceeded."""
        bases = [k for k in self._views if self._log_floor <= k < key
                 and self._views[k].np_keys is not None]
        if not bases:
            return None
        base_key = max(bases)
        base = self._views[base_key]
        # edge delta between base_key and key: the log is version-sorted,
        # so the record range is found by bisection — O(|delta| + log B)
        lo = bisect.bisect_right(self._batch_log, base_key,
                                 key=lambda r: r.version)
        hi = bisect.bisect_right(self._batch_log, key,
                                 key=lambda r: r.version)
        add_rows: list[np.ndarray] = []
        del_rows: list[np.ndarray] = []
        for rec in self._batch_log[lo:hi]:
            add_rows.append(np.arange(rec.row_start, rec.row_end, dtype=np.int64))
            del_rows.append(rec.del_rows)
        adds = (np.concatenate(add_rows) if add_rows
                else np.zeros(0, np.int64))
        dels = (np.concatenate(del_rows) if del_rows
                else np.zeros(0, np.int64))
        # rows added in the delta count only if still live at `key`; rows
        # deleted in the delta count only if present in the base (a row both
        # added and deleted inside the delta cancels out of both sets).
        # Stamp arrays are int32-packed, so the 64-bit log/cache keys are
        # re-expressed in stamp packing for the comparisons.
        adds = adds[self.deleted[adds] > pack32_clamped(version)]
        dels = dels[self.created[dels]
                    <= pack32_clamped(Version.unpack(base_key))]
        churn = len(adds) + len(dels)
        if churn > self.churn_threshold * max(base.m, 1):
            return None
        if churn == 0:
            return self._make_view(version, base.np_keys, base.np_src,
                                   base.np_dst, base.np_in_deg.copy(),
                                   base.np_out_deg.copy())
        keys, src_s, dst_s = base.np_keys, base.np_src, base.np_dst
        in_deg = base.np_in_deg.copy()
        out_deg = base.np_out_deg.copy()
        if len(dels):
            dkeys = np.sort(_edge_keys(self.src[dels], self.dst[dels]))
            # multiset removal: j-th duplicate of a key removes the j-th of
            # its contiguous run in the (sorted) base rows
            left = np.searchsorted(keys, dkeys, side="left")
            occ = np.arange(len(dkeys)) - np.searchsorted(dkeys, dkeys,
                                                          side="left")
            keep = np.ones(len(keys), bool)
            keep[left + occ] = False
            keys, src_s, dst_s = keys[keep], src_s[keep], dst_s[keep]
            np.subtract.at(in_deg, self.dst[dels], 1)
            np.subtract.at(out_deg, self.src[dels], 1)
        if len(adds):
            asrc, adst = self.src[adds], self.dst[adds]
            akeys = _edge_keys(asrc, adst)
            order = np.argsort(akeys, kind="stable")
            akeys, asrc, adst = akeys[order], asrc[order], adst[order]
            pos = np.searchsorted(keys, akeys, side="left")
            keys = np.insert(keys, pos, akeys)
            src_s = np.insert(src_s, pos, asrc)
            dst_s = np.insert(dst_s, pos, adst)
            np.add.at(in_deg, adst, 1)
            np.add.at(out_deg, asrc, 1)
        return self._make_view(version, keys, src_s, dst_s, in_deg, out_deg)

    def gc_views(self, keep_latest: int = 4, *, retire_below: int = 0) -> int:
        """Collect obsolete join views (paper §2.2 obsolete-replica GC).

        Retention is churn-adaptive: instead of the newest ``keep_latest``
        views, a version-spaced *ladder* (:func:`ladder_keep`) is kept, so a
        request for any past version finds a delta-patch base within ~2x its
        distance from the frontier under the same budget.

        ``retire_below`` additionally drops every cached view below that
        packed version once a newer one is cached (:func:`prune_retired`) —
        the sharded store passes a re-sharding migration's activation
        version here so a shard involved in a split does not pin pre-split
        views (built under a retired routing plan) in its ladder.

        Also trims the ingestion delta log: records at or below the oldest
        retained view's version can never contribute to a future delta
        patch from a retained base, so the log stays bounded by the churn
        since the oldest view instead of growing with the whole stream.
        The trim runs even when no view is dropped (with no cached views
        at all, everything up to the newest applied version is trimmed —
        any later-cached old view is then below the floor and rebuilds
        from scratch, never from missing records).

        The log floor additionally tracks ``retire_below`` *whether or not*
        :func:`prune_retired` fired: records strictly below the retired
        floor only patch retired-plan targets, and keeping them pinned the
        log to the oldest retired view whenever no post-cutover view was
        cached yet (e.g. a serving path that stalls right after a
        re-sharding split) — the one place view pruning and ``_log_floor``
        bookkeeping could disagree. Still-cached retired views remain
        addressable; they just full-rebuild instead of serving as delta
        bases.
        """
        dropped = prune_retired(self._views, retire_below)
        dropped += prune_views(self._views, keep_latest)
        if self._views:
            floor = min(self._views)
        elif self.versions:
            floor = self.versions[-1].pack()
        else:
            floor = self._log_floor
        # retire_below drops entries < floor, the log trim drops records
        # <= floor: records AT the retired floor (the cutover batch) stay
        floor = max(floor, retire_below - 1)
        self._batch_log = [r for r in self._batch_log if r.version > floor]
        self._log_floor = max(self._log_floor, floor)
        return dropped


# ----------------------------------------------------------- synthetic data
def _churn_batches(rng, n_epochs: int, sample_adds, *, delete_frac: float,
                   readd_frac: float) -> list[MutationBatch]:
    """Shared epoch loop for the synthetic stream generators: per-epoch
    ``(src, dst)`` adds from ``sample_adds(rng)``, live-set bookkeeping,
    ``delete_frac`` uniform deletes and ``readd_frac`` re-adds of
    previously deleted edges. One implementation of the delete/re-add
    bookkeeping keeps the uniform and skewed generators in lockstep."""
    live: list[tuple[int, int]] = []
    dead: list[tuple[int, int]] = []
    batches = []
    for e in range(n_epochs):
        src, dst = sample_adds(rng)
        adds_s, adds_d = list(src), list(dst)
        if readd_frac and dead:
            k = int(len(dead) * readd_frac)
            for i in rng.choice(len(dead), size=k, replace=False):
                s, d = dead[i]
                adds_s.append(s)
                adds_d.append(d)
        n_del = int(len(live) * delete_frac)
        if n_del:
            idx = rng.choice(len(live), size=n_del, replace=False)
            sel = set(idx.tolist())
            dels = [live[i] for i in idx]
            live = [x for i, x in enumerate(live) if i not in sel]
            dead.extend(dels)
            del_s = np.array([x[0] for x in dels], np.int32)
            del_d = np.array([x[1] for x in dels], np.int32)
        else:
            del_s = del_d = np.zeros(0, np.int32)
        live.extend(zip(adds_s, adds_d, strict=True))
        batches.append(MutationBatch(
            Version(e, 0),
            add_src=np.array(adds_s, np.int32),
            add_dst=np.array(adds_d, np.int32),
            del_src=del_s, del_dst=del_d))
    return batches


def synthesize_churn_stream(n_vertices: int, n_epochs: int,
                            adds_per_epoch: int, *, seed: int = 0,
                            delete_frac: float = 0.0,
                            readd_frac: float = 0.0) -> list[MutationBatch]:
    """Uniform-random mutation batches with controllable churn: each epoch
    deletes ``delete_frac`` of the live edges and re-adds ``readd_frac`` of
    the previously deleted ones. Shared by the equivalence tests and the
    ingestion benchmark so both exercise identical stream semantics."""

    def sample_adds(rng):
        src = rng.integers(0, n_vertices, adds_per_epoch).astype(np.int32)
        dst = rng.integers(0, n_vertices, adds_per_epoch).astype(np.int32)
        return src, dst

    return _churn_batches(np.random.default_rng(seed), n_epochs, sample_adds,
                          delete_frac=delete_frac, readd_frac=readd_frac)


def synthesize_skewed_stream(n_vertices: int, n_epochs: int,
                             adds_per_epoch: int, *, seed: int = 0,
                             zipf_a: float = 1.2,
                             delete_frac: float = 0.0) -> list[MutationBatch]:
    """Zipf-skewed mutation batches: destination vertices are drawn from a
    Zipf(``zipf_a``) rank distribution mapped through a random permutation
    of the vertex ids, so a handful of (randomly placed) vertices receive
    most of the edges — the hot-shard regime the access-pattern-adaptive
    re-sharding planner exists for. Sources are uniform. ``delete_frac``
    deletes that fraction of the live edges each epoch (uniformly, so
    deletes of hot-destination edges exercise post-migration delete
    routing). Shared by the ``resharding`` benchmark axis, the demo, and
    the split-equivalence tests."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_vertices)

    def sample_adds(rng):
        ranks = rng.zipf(zipf_a, adds_per_epoch)
        dst = perm[(ranks - 1) % n_vertices].astype(np.int32)
        src = rng.integers(0, n_vertices, adds_per_epoch).astype(np.int32)
        return src, dst

    return _churn_batches(rng, n_epochs, sample_adds,
                          delete_frac=delete_frac, readd_frac=0.0)


def synthesize_stream(n_vertices: int, n_epochs: int, adds_per_epoch: int,
                      *, seed: int = 0, delete_frac: float = 0.05,
                      n_types: int = 3) -> tuple[DynamicGraph, list[MutationBatch]]:
    """Preferential-attachment mutation stream (citation-graph-like: papers
    cite earlier papers; new vertex types appear in later epochs — the
    paper's Fig 1 evolution). Vertices grown in each epoch arrive as typed
    ``add_vertices`` with the epoch's type."""
    rng = np.random.default_rng(seed)
    e_max = n_epochs * adds_per_epoch * 2 + 16
    g = DynamicGraph(n_vertices, e_max)
    batches = []
    deg = np.ones(n_vertices, np.float64)
    grown = 8
    live: list[tuple[int, int]] = []
    for epoch in range(n_epochs):
        prev_grown = grown
        grown = min(n_vertices, grown + max(1, n_vertices // (n_epochs + 1)))
        p = deg[:grown] / deg[:grown].sum()
        dsts = rng.choice(grown, size=adds_per_epoch, p=p).astype(np.int32)
        srcs = rng.integers(0, grown, size=adds_per_epoch).astype(np.int32)
        keep = srcs != dsts
        srcs, dsts = srcs[keep], dsts[keep]
        deg_update = np.bincount(dsts, minlength=n_vertices)
        deg += deg_update
        n_del = int(len(live) * delete_frac)
        if n_del:
            idx = rng.choice(len(live), size=n_del, replace=False)
            dels = [live[i] for i in idx]
            live = [e for i, e in enumerate(live) if i not in set(idx)]
            del_src = np.array([d[0] for d in dels], np.int32)
            del_dst = np.array([d[1] for d in dels], np.int32)
        else:
            del_src = del_dst = np.zeros(0, np.int32)
        live.extend(zip(srcs.tolist(), dsts.tolist(), strict=True))
        # vertex type evolution: later epochs introduce new types; this
        # epoch's newly grown vertices carry the epoch's type (Fig 1)
        vtype = np.minimum(epoch * n_types // max(n_epochs, 1), n_types - 1)
        new_vertices = np.arange(0 if epoch == 0 else prev_grown, grown,
                                 dtype=np.int32)
        batch = MutationBatch(
            version=Version(epoch, 0),
            add_src=srcs, add_dst=dsts,
            del_src=del_src, del_dst=del_dst,
            add_vertices=new_vertices,
            vertex_types=np.full(len(new_vertices), vtype, np.int32))
        g.apply(batch)
        batches.append(batch)
    return g, batches
