"""Pallas TPU kernel: blocked online-softmax attention (GQA + causal +
sliding-window/local masking).

Grid = (B, Hq, S // QB). Each instance owns one q block (QB, hd) and loops
over kv chunks with ``fori_loop``, keeping the running max / denominator /
accumulator in VMEM f32. Causal block-skipping is real here (the loop bound
depends on the q-block index — the 2x FLOPs the portable jnp path wastes on
masked upper-triangle chunks is *not* spent), and window masking also lower-
bounds the loop so local attention is O(S*W).

GQA is free: the kv BlockSpec index_map divides the q-head index by the
group size, so kv blocks are fetched once per kv head.

VMEM per instance: q (QB, hd) + k,v (S, hd) bf16 + acc (QB, hd) f32.
At S=32k, hd=128, bf16: k+v = 16 MB — within a v5e core's VMEM for one
resident (1,1,S,hd) block; longer S must tile kv through HBM (the wrapper
asserts the budget instead of silently thrashing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
DEFAULT_Q_BLOCK = 128
DEFAULT_KV_BLOCK = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, *, q_block, kv_block, causal,
            window, scale, seq_len):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale              # (QB, hd)
    kv_hi = seq_len // kv_block
    if causal:
        kv_hi = jnp.minimum(kv_hi, (qi + 1) * q_block // kv_block
                            + (1 if q_block % kv_block else 0))
    kv_lo = 0
    if window is not None:
        kv_lo = jnp.maximum(0, (qi * q_block - window) // kv_block)

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.ds(j * kv_block, kv_block)].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(j * kv_block, kv_block)].astype(jnp.float32)
        s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32)
        pos_q = qi * q_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 0)
        pos_k = j * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 1)
        mask = jnp.ones((q_block, kv_block), bool)
        if causal:
            mask &= pos_k <= pos_q
        if window is not None:
            mask &= (pos_q - pos_k) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((q_block,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_block,), jnp.float32)
    a0 = jnp.zeros((q_block, q.shape[1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(kv_lo, kv_hi, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "q_block", "kv_block",
                                    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    q_block: int = DEFAULT_Q_BLOCK,
                    kv_block: int = DEFAULT_KV_BLOCK,
                    interpret: bool = False):
    """q: (B,Hq,S,hd); k,v: (B,Hkv,S,hd), Hq % Hkv == 0. Returns (B,Hq,S,hd).
    S must be a multiple of the block sizes (the model pads)."""
    B, Hq, S, hd = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    qb = min(q_block, S)
    kb = min(kv_block, S)
    assert S % qb == 0 and S % kb == 0, (S, qb, kb)
    # VMEM budget check: resident k+v blocks must fit (~half a v5e core VMEM)
    assert 2 * S * hd * 2 <= 96 * 1024 * 1024, "kv too large for VMEM residency"
    scale = hd ** -0.5
    grid = (B, Hq, S // qb)
    return pl.pallas_call(
        functools.partial(_kernel, q_block=qb, kv_block=kb, causal=causal,
                          window=window, scale=scale, seq_len=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qb, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h, i: (b, h // g, 0, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h, i: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, hd), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
