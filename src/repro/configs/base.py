"""Architecture config system.

Every assigned architecture is described by one :class:`ModelConfig`. A config
is *declarative*: it fixes the block pattern (the repeating unit that is scanned
over), the mixer kinds, FFN kind, and attention details. The same
``models/transformer.py`` code path instantiates all ten architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

MixerKind = Literal["attn", "swa", "local", "global", "rglru", "mlstm", "slstm"]
FFNKind = Literal["swiglu", "geglu", "gelu_mlp", "moe", "none"]
NormKind = Literal["rms", "ln"]
EmbedMode = Literal["tokens", "frames"]

ATTN_KINDS = ("attn", "swa", "local", "global")
RECURRENT_KINDS = ("rglru", "mlstm", "slstm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # Block pattern: repeating unit of mixer kinds; num_layers = k*len(pattern)+r.
    pattern: Sequence[MixerKind] = ("attn",)
    ffn: FFNKind = "swiglu"
    norm: NormKind = "rms"
    # attention details
    qkv_bias: bool = False
    mlp_bias: bool = False
    rope: bool = True
    rope_theta: float = 10_000.0
    local_window: int = 1024          # for "local" mixers
    swa_window: int = 4096            # for "swa" mixers
    qk_norm: bool = False
    sandwich_norm: bool = False       # post-block norms (gemma3)
    logit_softcap: float = 0.0        # final-logit softcapping (gemma family)
    attn_softcap: float = 0.0
    # MoE
    n_experts: int = 0
    top_k: int = 2
    d_ff_expert: int = 0
    moe_impl: Literal["dense", "dropping"] = "dense"
    capacity_factor: float = 1.25
    expert_sharding: Literal["tensor", "expert"] = "tensor"
    # recurrent blocks
    lru_width: int = 0                # rglru inner width (0 -> d_model)
    conv_width: int = 4
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    # embeddings
    embed_mode: EmbedMode = "tokens"
    pos_emb: Literal["rope", "sinusoidal", "none"] = "rope"
    tie_embeddings: bool = False
    scale_embeddings: bool = False    # multiply embeddings by sqrt(d_model)
    # numerics
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"
    # §Perf knobs (beyond-paper optimizations; defaults = paper-faithful
    # baseline)
    reduce_dtype: str = "float32"     # dtype of TP partial-sum all-reduces
    bwd_dtype: str = "float32"        # cotangent dtype through dense layers
    mlstm_chunk: int = 0              # 0 = plain scan; >0 = chunk size
    mlstm_impl: str = "scan"          # scan | chunkwise (parallel intra-chunk)
    moe_groups: int = 0               # >1: shard-local MoE dispatch groups
    microbatches: int = 1             # gradient-accumulation splits per step
    # long-context capability: does the arch admit a 500k decode cell?
    subquadratic: bool = False
    # attention kv-chunk size for the jnp flash path
    kv_chunk: int = 1024
    # remat policy for the scanned block: none | dots | full
    remat: str = "full"
    # loss vocab chunking (tokens per chunk in the chunked CE)
    loss_chunk: int = 2048

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def num_units(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def tail_pattern(self) -> Sequence[MixerKind]:
        r = self.num_layers % len(self.pattern)
        return tuple(self.pattern[:r])

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs accounting)."""
        d, v = self.d_model, self.vocab_size
        total = 0
        if self.embed_mode == "tokens":
            total += v * d
        total += d * v  # lm head
        for kind in list(self.pattern) * self.num_units + list(self.tail_pattern):
            total += self._block_params(kind)
        total += d  # final norm
        return total

    def _block_params(self, kind: str) -> int:
        d = self.d_model
        hd = self.resolved_head_dim
        n = 0
        if kind in ATTN_KINDS:
            n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                n += self.q_dim + 2 * self.kv_dim
            n += d  # pre-norm
            if self.sandwich_norm:
                n += d
            if self.qk_norm:
                n += 2 * hd
        elif kind == "rglru":
            w = self.lru_width or d
            n += 2 * d * w + w * d + self.conv_width * w + 4 * w + d
        elif kind == "mlstm":
            dp = int(self.mlstm_proj_factor * d)
            h = self.n_heads
            # up proj (x + ogate branches), down proj, conv, per-head block-diag
            # qkv, i/f gate projections (dp -> h scalars each), pre-norm.
            n += d * 2 * dp + dp * d + self.conv_width * dp
            n += 3 * h * (dp // h) ** 2 + 2 * dp * h + d
        elif kind == "slstm":
            h = self.n_heads
            hd_s = d // h
            # input projections for 4 gates, per-head recurrent matrices for
            # 4 gates, biases, pre-norm, gated ffn (proj_factor).
            n += 4 * d * d + 4 * h * hd_s * hd_s + 8 * d + d
            dff_s = int(self.slstm_proj_factor * d)
            n += 2 * d * dff_s + dff_s * d
        # FFN
        if kind in ATTN_KINDS or kind == "rglru":
            if self.ffn in ("swiglu", "geglu"):
                n += 3 * d * self.d_ff + d
            elif self.ffn == "gelu_mlp":
                n += 2 * d * self.d_ff + d
                if self.mlp_bias:
                    n += self.d_ff + d
            elif self.ffn == "moe":
                ffe = self.d_ff_expert or self.d_ff
                n += d * self.n_experts + self.n_experts * 3 * d * ffe + d
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k experts only)."""
        if self.ffn != "moe":
            return self.param_count()
        ffe = self.d_ff_expert or self.d_ff
        per_layer_moe = self.n_experts * 3 * self.d_model * ffe
        active_moe = self.top_k * 3 * self.d_model * ffe
        n_moe_layers = sum(
            1 for k in (list(self.pattern) * self.num_units + list(self.tail_pattern))
            if k in ATTN_KINDS
        )
        return self.param_count() - n_moe_layers * (per_layer_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell from the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    # import for registration side effects
    from repro.configs import (  # noqa: F401
        xlstm_1_3b, qwen1_5_110b, qwen2_5_14b, starcoder2_7b, gemma3_27b,
        musicgen_medium, internvl2_76b, mixtral_8x22b, phi3_5_moe, recurrentgemma_2b,
    )


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    pat = cfg.pattern
    base = {
        "num_layers": max(2, len(pat)),
        "d_model": 64,
        "n_heads": max(2, min(4, cfg.n_heads)),
        "n_kv_heads": max(1, min(2, cfg.n_kv_heads)),
        "head_dim": 16,
        "d_ff": 128 if cfg.d_ff else 0,
        "vocab_size": 256,
        "n_experts": min(4, cfg.n_experts) if cfg.n_experts else 0,
        "d_ff_expert": 64 if cfg.d_ff_expert else 0,
        "lru_width": 64 if cfg.lru_width else 0,
        "local_window": 8,
        "swa_window": 8,
        "kv_chunk": 16,
        "loss_chunk": 64,
        "name": cfg.name + "-smoke",
    }
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
