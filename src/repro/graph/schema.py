"""Evolving heterogeneous schemas — paper §2.1 (Figs 1-2).

Vertices/edges are *abstract entities*; applications attach versioned schemas.
A schema declaration is template-like: ``node Author<version V=V2> :
Author<V1> { String contact; }``. New versions inherit fields from older
versions; link types connect (node type, version) pairs. A graph with no
schema attached is an *abstract graph*; attaching one makes it *schematized*.

The registry supports the paper's two usage patterns:
  * different computation per schema version (``fields_of`` is version-exact);
  * one computation across a *set* of versions (``versions_of`` + the
    version-compatible ``validate``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class FieldDecl:
    name: str
    type: str   # "String" | "Int" | "Float" | "Bool" — declarative only


@dataclasses.dataclass(frozen=True)
class NodeSchema:
    type_name: str
    version: int
    fields: tuple[FieldDecl, ...]
    parent_version: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class LinkSchema:
    src_type: str
    # None = any version (paper: Author<V2> -> School<Version V>)
    src_version: Optional[int]
    dst_type: str
    dst_version: Optional[int]


_PY_TYPES = {"String": str, "Int": int, "Float": float, "Bool": bool}


class SchemaRegistry:
    """Versioned node/link schema declarations with inheritance."""

    def __init__(self):
        self._nodes: dict[tuple[str, int], NodeSchema] = {}
        self._links: list[LinkSchema] = []
        self._type_ids: dict[tuple[str, int], int] = {}

    # -- declaration ---------------------------------------------------------
    def declare_node(self, type_name: str, version: int,
                     fields: dict[str, str],
                     inherits: Optional[int] = None) -> NodeSchema:
        if (type_name, version) in self._nodes:
            raise ValueError(f"{type_name}<{version}> already declared "
                             "(schema versions are immutable)")
        if inherits is not None and (type_name, inherits) not in self._nodes:
            raise ValueError(f"{type_name}<{inherits}> not declared")
        decl = tuple(FieldDecl(n, t) for n, t in fields.items())
        schema = NodeSchema(type_name, version, decl, inherits)
        self._nodes[(type_name, version)] = schema
        self._type_ids[(type_name, version)] = len(self._type_ids)
        return schema

    def declare_link(self, src_type: str, dst_type: str,
                     src_version: Optional[int] = None,
                     dst_version: Optional[int] = None) -> LinkSchema:
        for t, v in ((src_type, src_version), (dst_type, dst_version)):
            if v is not None and (t, v) not in self._nodes:
                raise ValueError(f"{t}<{v}> not declared")
            if v is None and not any(k[0] == t for k in self._nodes):
                raise ValueError(f"node type {t} not declared")
        link = LinkSchema(src_type, src_version, dst_type, dst_version)
        self._links.append(link)
        return link

    # -- queries ---------------------------------------------------------
    def versions_of(self, type_name: str) -> list[int]:
        return sorted(v for t, v in self._nodes if t == type_name)

    def fields_of(self, type_name: str, version: int) -> dict[str, str]:
        """Fields including everything inherited from ancestor versions."""
        key = (type_name, version)
        if key not in self._nodes:
            raise KeyError(f"{type_name}<{version}>")
        out: dict[str, str] = {}
        chain = []
        cur: Optional[int] = version
        while cur is not None:
            schema = self._nodes[(type_name, cur)]
            chain.append(schema)
            cur = schema.parent_version
        for schema in reversed(chain):
            for f in schema.fields:
                out[f.name] = f.type
        return out

    def type_id(self, type_name: str, version: int) -> int:
        """Dense integer id for use in the JAX data plane's type columns."""
        return self._type_ids[(type_name, version)]

    def validate(self, type_name: str, version: int, props: dict) -> bool:
        fields = self.fields_of(type_name, version)
        for name, value in props.items():
            if name not in fields:
                return False
            if not isinstance(value, _PY_TYPES[fields[name]]):
                return False
        return True

    def link_allowed(self, src: tuple[str, int], dst: tuple[str, int]) -> bool:
        for l in self._links:
            if l.src_type != src[0] or l.dst_type != dst[0]:
                continue
            if l.src_version is not None and l.src_version != src[1]:
                continue
            if l.dst_version is not None and l.dst_version != dst[1]:
                continue
            return True
        return False


def citation_schema() -> SchemaRegistry:
    """The paper's running example (Fig 1-2): author/paper graph evolving to
    add contact info and school nodes."""
    reg = SchemaRegistry()
    reg.declare_node("Author", 1, {"name": "String"})
    reg.declare_node("Paper", 1, {"title": "String"})
    reg.declare_link("Author", "Paper")
    # evolution: Author V2 inherits V1, School appears
    reg.declare_node("Author", 2, {"contact": "String"}, inherits=1)
    reg.declare_node("School", 1, {"name": "String"})
    reg.declare_link("Author", "School", src_version=2)
    return reg
