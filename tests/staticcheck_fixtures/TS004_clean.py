"""TS004 clean twin: widths routed through the pow2 discipline."""


def pad_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def pad_plan(sources, pad=True):
    raw = len(sources)
    width = pad_pow2(raw) if pad else raw    # call / bare alias: fine
    return width


def pad_block(n):
    base_width = pad_pow2(n)
    width = min(base_width, 4096)        # min over pow2 terms: fine
    cap_width = 1 << 12                  # shift literal: fine
    return width, cap_width
