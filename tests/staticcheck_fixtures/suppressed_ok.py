"""Suppression fixture: every violation here carries a disable comment,
so reprolint must report nothing for this file."""
import threading


def epoch_of(packed: int) -> int:
    return packed >> 32    # reprolint: disable=SH003 — measured, documented


class WindowQueue:
    def __init__(self):
        self._lock = threading.Lock()
        self.pending = []

    def add(self, item):
        with self._lock:
            self.pending.append(item)

    def peek_len(self):
        # racy-but-monotone diagnostic read, deliberately lock-free
        return len(self.pending)    # reprolint: disable=RL001
