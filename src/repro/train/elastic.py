"""Elastic scaling: reshard a training state onto a different mesh.

The versioned checkpoint + deterministic data views make elasticity a pure
data-management operation (the paper's thesis): resolve ``snapshot(v)``,
re-derive PartitionSpecs for the new mesh from the same logical rules, and
``device_put`` each leaf to its new sharding. Batch indices continue from
the restored step, so no sample is lost or repeated.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding

from repro.launch import sharding as shd


def plan_resharding(cfg, params_like, old_mesh, new_mesh, *,
                    multi_pod_new=False):
    """Validate + build the new sharding tree. Raises with a clear message
    if a tensor can't shard on the new mesh (falls back to replication per
    the replica-coherence fallback in ShardingRules.spec)."""
    mapping = shd.baseline_mapping(multi_pod_new,
                                   expert_sharding=cfg.expert_sharding)
    rules = shd.ShardingRules(new_mesh, mapping)
    specs = shd.param_specs(params_like, rules)
    return jax.tree.map(lambda s: NamedSharding(new_mesh, s), specs)


def reshard(tree, shardings):
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


def elastic_restart(cfg, ckpt_manager, state_like, new_mesh, *,
                    version=None, multi_pod_new=False):
    """snapshot(v) -> reshard -> resume. Returns the resharded state."""
    state = ckpt_manager.restore(state_like, version)
    shardings = plan_resharding(cfg, state["params"], None, new_mesh,
                                multi_pod_new=multi_pod_new)
    full = {
        "params": shardings,
        "opt": {"m": shardings, "v": shardings,
                "count": NamedSharding(new_mesh, jax.sharding.PartitionSpec())},
        "step": NamedSharding(new_mesh, jax.sharding.PartitionSpec()),
    }
    return reshard(state, full)
