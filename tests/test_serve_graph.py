"""GraphQueryServer: online serving on live sharded snapshots.

The server must (a) answer strictly against the newest frontier-sealed
snapshot — never a partially-sealed epoch, (b) produce results
byte-identical to one-shot queries on the single store at the same
version, (c) collapse same-kind query windows into one vectorized call,
(d) warm-start PageRank incrementally per epoch and keep its caches
bounded under the ladder GC, and (e) keep serving while ingestion streams
on a background thread.
"""
import threading

import numpy as np
import pytest

from repro.core.versioned import Version
from repro.graph import compute as gc
from repro.graph.dyngraph import (DynamicGraph, MutationBatch,
                                  synthesize_churn_stream)
from repro.graph.query import (DegreeTopK, KHop, PageRankQuery, Reachability,
                               SnapshotQueryEngine)
from repro.graph.sharded import ShardedDynamicGraph
from repro.launch.serve_graph import GraphQueryServer


def _setup(n=64, epochs=5, adds=60, n_shards=3, seed=13, **server_kw):
    batches = synthesize_churn_stream(n, epochs, adds, seed=seed,
                                      delete_frac=0.2)
    e_max = sum(len(b.add_src) for b in batches) + 16
    sg = ShardedDynamicGraph(n_shards, n, e_max)
    g = DynamicGraph(n, e_max)
    server = GraphQueryServer(sg, **server_kw)
    return server, g, batches


def test_flush_before_any_seal_raises():
    server, _, batches = _setup()
    server.submit(KHop(0, 2))
    with pytest.raises(RuntimeError, match="no globally sealed"):
        server.flush()
    # the window survives the failed flush and answers after the seal
    server.step(batches[0])
    [res] = server.flush()
    assert res.version == batches[0].version


def test_results_byte_identical_to_single_store():
    server, g, batches = _setup(tol=1e-8, max_iter=300)
    for b in batches:
        g.apply(b)
        server.step(b)
        for q in (KHop(1, 2), KHop(5, 2), Reachability(0, 63, max_hops=6),
                  DegreeTopK(5), PageRankQuery()):
            server.submit(q)
        results = server.flush()
        assert all(r.version == b.version for r in results)
        view = g.join_view(b.version)
        for r in results:
            if isinstance(r.query, KHop):
                exp = np.asarray(gc.k_hop(view, np.array([r.query.source]),
                                          r.query.k))
                np.testing.assert_array_equal(r.value, exp)
            elif isinstance(r.query, Reachability):
                assert r.value == gc.reachability(view, r.query.src,
                                                  r.query.dst,
                                                  r.query.max_hops)
            elif isinstance(r.query, DegreeTopK):
                ids, degs = r.value
                exp_deg, exp_ids = np.asarray(view.in_degree), None
                np.testing.assert_array_equal(degs, exp_deg[ids])
                assert (np.diff(degs) <= 0).all()


def test_pagerank_warm_chain_matches_incremental_timeline():
    """The server's per-epoch PageRank equals the single store's
    incremental (warm-started) timeline bit for bit — the online/offline
    shared-data goal."""
    server, g, batches = _setup(prewarm_pagerank=True, tol=1e-8,
                                max_iter=300)
    served = []
    for b in batches:
        g.apply(b)
        server.step(b)
        served.append(server.query(PageRankQuery()).value)
    versions = [b.version for b in batches]
    timeline = gc.pagerank_timeline(g, versions, incremental=True, tol=1e-8,
                                    max_iter=300)
    for got, exp in zip(served, timeline, strict=True):
        np.testing.assert_array_equal(got, np.asarray(exp.ranks))
    # every epoch after the first warm-started; queries all hit the cache
    assert server.engine.rank_cold_starts == 1
    assert server.engine.rank_warm_starts == len(batches) - 1
    assert server.engine.rank_cache_hits == len(batches)


def test_window_batches_same_kind_into_one_vectorized_call():
    server, _, batches = _setup()
    for b in batches[:2]:
        server.step(b)
    for src in (0, 5, 9, 11, 17):
        server.submit(KHop(src, 2))           # same k: ONE batched call
    for src in (1, 2, 3):
        server.submit(Reachability(src, 40))  # same bound: ONE frontier
    server.submit(DegreeTopK(4))
    server.submit(DegreeTopK(4))              # deduped group
    results = server.flush()
    assert len(results) == 10
    calls = server.engine.vectorized_calls
    assert calls["k_hop"] == 1
    assert calls["reachability"] == 1
    assert calls["degree_topk"] == 1
    # different k -> separate traces/groups, still one call per group
    server.submit(KHop(0, 1))
    server.submit(KHop(4, 2))
    server.flush()
    assert server.engine.vectorized_calls["k_hop"] == 3


def test_serves_newest_sealed_never_partial_epoch():
    """While a straggler shard lags, the server keeps answering at the last
    globally-sealed version; once the straggler seals, the next flush moves
    to the new snapshot."""
    server, g, batches = _setup(n_shards=2)
    sg = server.graph
    for b in batches[:-1]:
        g.apply(b)
        server.step(b)
    last = batches[-1]
    sg.ingest(last)
    sg.seal_shard(1, last.version.epoch)       # shard 0 straggles
    res = server.query(KHop(3, 2))
    assert res.version == batches[-2].version  # not the partial epoch
    view = g.join_view(batches[-2].version)
    np.testing.assert_array_equal(
        res.value, np.asarray(gc.k_hop(view, np.array([3]), 2)))
    sg.seal_shard(0, last.version.epoch)       # straggler catches up
    g.apply(last)
    res2 = server.query(KHop(3, 2))
    assert res2.version == last.version
    np.testing.assert_array_equal(
        res2.value,
        np.asarray(gc.k_hop(g.join_view(last.version), np.array([3]), 2)))


def test_caches_stay_bounded_under_churn():
    n, epochs = 48, 12
    batches = synthesize_churn_stream(n, epochs, 40, seed=3,
                                      delete_frac=0.2)
    e_max = sum(len(b.add_src) for b in batches) + 16
    sg = ShardedDynamicGraph(2, n, e_max)
    server = GraphQueryServer(sg, view_keep=4, rank_keep=3,
                              prewarm_pagerank=True)
    for b in batches:
        server.step(b)
        server.query(PageRankQuery())
    assert len(sg._views) <= 4
    for shard in sg.shards:
        assert len(shard._views) <= 4
    assert len(server.engine.cached_rank_versions) <= 3
    # the newest version is always retained (it is the serving snapshot)
    assert max(server.engine.cached_rank_versions) == \
        batches[-1].version.pack()
    assert max(sg._views) == batches[-1].version.pack()


def test_background_ingest_serves_while_streaming():
    server, g, batches = _setup(epochs=8, adds=40)
    for b in batches:
        g.apply(b)
    t = server.start_background_ingest(iter(batches), delay_s=0.002)
    seen = []
    while t.is_alive():
        try:
            res = server.query(KHop(2, 2))
        except RuntimeError:       # nothing sealed yet
            continue
        seen.append(res)
    t.join()
    # every answer was consistent with the single store at ITS version
    assert seen, "no query completed while the stream was live"
    for r in seen:
        view = g.join_view(r.version)
        np.testing.assert_array_equal(
            r.value, np.asarray(gc.k_hop(view, np.array([2]), 2)))
    # after the stream drains, the server serves the final snapshot
    final = server.query(KHop(2, 2))
    assert final.version == batches[-1].version


def test_query_returns_its_own_result_with_pending_window():
    """query() flushes the whole window but must return the result of the
    query it just submitted — not whatever was first in the queue."""
    server, _, batches = _setup()
    server.step(batches[0])
    server.submit(DegreeTopK(2))              # someone else's pending query
    r = server.query(KHop(0, 1))
    assert isinstance(r.query, KHop) and r.query.source == 0
    assert server.served == 2                 # both were answered


def test_engine_rejects_unknown_query_type():
    engine = SnapshotQueryEngine()
    g = DynamicGraph(8, 16)
    g.apply(MutationBatch(Version(0, 0),
                          add_src=np.array([0], np.int32),
                          add_dst=np.array([1], np.int32)))
    with pytest.raises(TypeError, match="unknown query"):
        engine.execute(g.join_view(Version(0, 0)), ["not-a-query"])


def test_failed_window_is_requeued_not_lost():
    """One bad query must not silently discard the whole window: the
    window is restored for a retry after the error surfaces."""
    server, _, batches = _setup()
    server.step(batches[0])
    server.submit(KHop(0, 2))
    server.submit("not-a-query")              # shim skips admission checks
    with pytest.raises(TypeError, match="unknown query"):
        server.flush()
    assert len(server._pending_cheap) == 2    # nothing lost
    server._pending_cheap = [e for e in server._pending_cheap
                             if not isinstance(e.request.query, str)]
    [res] = server.flush()                    # innocent query still answers
    assert isinstance(res.query, KHop)


def test_degree_topk_k_larger_than_n_returns_all():
    server, _, batches = _setup(n=64)
    server.step(batches[0])
    ids, degs = server.query(DegreeTopK(1000)).value
    assert len(ids) == 64
    assert (np.diff(degs) <= 0).all()


def test_ingested_version_log_stays_bounded():
    """latest_sealed() trims versions older than the newest sealed one, so
    a long-lived stream does not pin one entry per epoch forever."""
    server, _, batches = _setup(epochs=8)
    for b in batches:
        server.step(b)
        server.graph.latest_sealed()
    assert len(server.graph._ingested_packed) == 1
    assert server.graph.latest_sealed() == batches[-1].version


# -- lock-discipline regressions (reprolint RL001 fixes) ------------------
def test_requeue_on_unsealed_keeps_racing_submissions():
    """flush() used to swap _pending outside the lock and restore it
    wholesale on the no-snapshot path, clobbering queries submitted in
    between. Interleave deterministically: submit from inside the
    flush's own latest_sealed call (the lock is re-entrant, so this is
    exactly a submitter that won the race). Pin the server to the
    serialized discipline so the window pins via graph.latest_sealed —
    the pipelined path reads the published pointer under the same lock
    as the queue swap, which forecloses this race by construction."""
    server, _, batches = _setup(pipeline_reads=False)
    server.submit(KHop(0, 1))
    real = server.graph.latest_sealed

    def racing_latest_sealed():
        server.submit(KHop(1, 1))       # a submitter racing the flush
        return real()

    server.graph.latest_sealed = racing_latest_sealed
    with pytest.raises(RuntimeError, match="no globally sealed"):
        server.flush()
    server.graph.latest_sealed = real
    server.step(batches[0])
    assert len(server.flush()) == 2     # neither query was lost


def test_concurrent_submitters_and_flusher_lose_no_queries():
    """submit()/flush() raced on _pending and the served/latency
    counters: with concurrent submitters, a swap could drop whole
    windows. 4 submitters x 50 queries against a live flusher must
    serve exactly 200."""
    server, _, batches = _setup(epochs=3)
    server.step(batches[0])
    errors = []
    stop = threading.Event()

    def flusher():
        try:
            while not stop.is_set():
                server.flush()
        except BaseException as e:      # pragma: no cover
            errors.append(e)

    def submitter():
        try:
            for _ in range(50):
                server.submit(KHop(0, 1))
        except BaseException as e:      # pragma: no cover
            errors.append(e)

    ft = threading.Thread(target=flusher)
    subs = [threading.Thread(target=submitter) for _ in range(4)]
    ft.start()
    for t in subs:
        t.start()
    for t in subs:
        t.join()
    stop.set()
    ft.join()
    server.flush()                      # drain whatever the flusher missed
    assert not errors
    assert server.stats().served == 200


def test_stats_consistent_during_background_ingest():
    """stats() used to read served/latencies_s/reshard_events outside
    the lock while the background ingest thread mutates them (the
    ISSUE's 'unguarded read of server state on the background-ingest
    path'). Hammer stats() against a live stream: it must never throw
    and served must be monotone."""
    server, _, batches = _setup(epochs=6)
    server.step(batches[0])
    t = server.start_background_ingest(iter(batches[1:]), delay_s=0.001)
    last = -1
    while t.is_alive():
        server.submit(KHop(0, 2))
        server.flush()
        s = server.stats()
        assert s.served >= last
        last = s.served
    t.join()
    assert server.stats().served >= last
