"""End-to-end integration: the paper's online/offline loop at LM scale —
offline trainer writes versioned snapshots, online server reads the newest
one without blocking; elastic restart continues training losslessly."""
import numpy as np

from repro.configs import all_configs, reduced
from repro.launch.serve import Server
from repro.launch.train import run


def test_train_snapshot_then_serve(tmp_path):
    cfg = reduced(all_configs()["qwen2.5-14b"], num_layers=2)
    losses, state = run(cfg, steps=12, batch=4, seq=32,
                        ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100)
    srv = Server.from_checkpoint(cfg, str(tmp_path))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out = srv.generate(prompts, 4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_failure_plus_serve_consistency(tmp_path):
    """A crash mid-training does not corrupt the snapshot the server sees."""
    cfg = reduced(all_configs()["recurrentgemma-2b"], num_layers=3)
    losses, state = run(cfg, steps=14, batch=2, seq=24,
                        ckpt_dir=str(tmp_path), ckpt_every=4, fail_at=9,
                        log_every=100)
    assert int(state["step"]) == 14          # recovered and completed
    srv = Server.from_checkpoint(cfg, str(tmp_path))
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (1, 6)).astype(np.int32)
    out = srv.generate(prompts, 3)
    assert np.isfinite(out).all()
