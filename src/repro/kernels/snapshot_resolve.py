"""Pallas TPU kernel: vectorized snapshot resolution (paper §2.3.1).

``snapshot(v) = d(i_v), i_v = max{v' <= v}`` over a multi-version column
store: items (N, K) with K version slots (ascending, MAX-padded). The scan
over candidate versions is a VPU-parallel masked max across the K lanes —
one HBM pass over the version matrix, fused value gather.

Blocking: grid over item blocks; each instance holds an (NB, K) version tile
and the matching (NB, K) value tile in VMEM, emits (NB,) resolved values.
K is small (version fan-out per item), so tiles are tiny; the kernel is
HBM-bandwidth-bound and reads each element exactly once — roofline-optimal.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ITEM_BLOCK = 1024


def _kernel(q_ref, ver_ref, val_ref, out_ref, idx_ref):
    q = q_ref[0]
    vers = ver_ref[...]                       # (NB, K) int32
    vals = val_ref[...]                       # (NB, K)
    ok = vers <= q
    # index of the newest eligible version; -1 if none
    k = jax.lax.broadcasted_iota(jnp.int32, vers.shape, 1)
    best = jnp.max(jnp.where(ok, k, -1), axis=1)             # (NB,)
    safe = jnp.maximum(best, 0)
    gathered = jnp.take_along_axis(vals, safe[:, None], axis=1)[:, 0]
    out_ref[...] = jnp.where(best >= 0, gathered, jnp.zeros_like(gathered))
    idx_ref[...] = best


@functools.partial(jax.jit, static_argnames=("item_block", "interpret"))
def snapshot_resolve(versions, values, query_version, *,
                     item_block: int = DEFAULT_ITEM_BLOCK,
                     interpret: bool = False):
    """versions: (N, K) int32 ascending (pad = int32 max); values: (N, K);
    query_version: scalar int32. Returns (resolved (N,), index (N,) with -1
    for items having no version <= query)."""
    N, K = versions.shape
    if N == 0:
        return (jnp.zeros((0,), values.dtype), jnp.zeros((0,), jnp.int32))
    nb = min(item_block, N)
    pad = (-N) % nb
    if pad:
        maxv = jnp.iinfo(jnp.int32).max
        versions = jnp.pad(versions, ((0, pad), (0, 0)), constant_values=maxv)
        values = jnp.pad(values, ((0, pad), (0, 0)))
    Np = versions.shape[0]
    q = jnp.asarray(query_version, jnp.int32).reshape(1)
    out, idx = pl.pallas_call(
        _kernel,
        grid=(Np // nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((nb, K), lambda i: (i, 0)),
            pl.BlockSpec((nb, K), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((nb,), lambda i: (i,)),
            pl.BlockSpec((nb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), values.dtype),
            jax.ShapeDtypeStruct((Np,), jnp.int32),
        ],
        interpret=interpret,
    )(q, versions, values)
    return out[:N], idx[:N]


@functools.partial(jax.jit, static_argnames=("item_block", "interpret"))
def liveness_mask(created, deleted, query_version, *,
                  item_block: int = DEFAULT_ITEM_BLOCK,
                  interpret: bool = False):
    """Edge liveness (``created <= q < deleted``) as a 2-slot multi-version
    resolve: versions (N, 2) = [created, deleted], values [1, 0]. The newest
    eligible slot at q is 'created' exactly when the edge is live, so the
    resolved value IS the mask. Same single-HBM-pass roofline as
    :func:`snapshot_resolve`; the snapshot-mask hot path of the dynamic
    graph store routes here on TPU.

    created/deleted: (N,) int32 data-plane-packed version stamps (ascending
    per row: deleted is MAX-padded until tombstoned). The dynamic graph
    store keeps its stamp arrays in this packing natively, so they arrive
    here as-is — no 64→32-bit host repack on the query path. Returns
    (N,) bool.
    """
    versions = jnp.stack([jnp.asarray(created, jnp.int32),
                          jnp.asarray(deleted, jnp.int32)], axis=1)
    values = jnp.broadcast_to(jnp.asarray([1.0, 0.0], jnp.float32),
                              versions.shape)
    out, _ = snapshot_resolve(versions, values, query_version,
                              item_block=item_block, interpret=interpret)
    return out > 0.5
