"""Graph query server: online queries on live sharded snapshots.

The paper's central claim is ONE evolving graph serving both offline
analytics and low-latency online queries. This is the online half wired
end to end: a :class:`GraphQueryServer` owns a ``ShardedDynamicGraph``,
keeps ingesting a mutation stream (cooperatively via :meth:`step`, or on a
background thread via :meth:`start_background_ingest`), and answers
batched queries strictly against the **newest frontier-sealed snapshot**
(``latest_sealed()`` — the global-frontier rule; a partially-sealed epoch
is never served). Query windows are answered by the
``graph.query.SnapshotQueryEngine``: same-kind queries collapse into one
vectorized jitted call, PageRank is cached per snapshot version and
warm-started incrementally from the previous epoch's ranks, and both the
rank cache and the view caches are GC'd with the version-spaced
``ladder_keep`` retention so server memory stays bounded under churn.

This is layer 5 (the top) of the pipeline mapped in
``docs/ARCHITECTURE.md``, and the serving loop is also where dynamic
re-sharding closes its feedback loop: flushed windows feed query touches
into the store's access ledger, and :meth:`GraphQueryServer.step` runs
the planner tick at its entry — the between-epochs quiescent point, so a
fired split's migration applies inside the incoming batch's seal.

Usage (synthetic ingest-while-query loop, CPU):
    PYTHONPATH=src python -m repro.launch.serve_graph --vertices 2000 \
        --epochs 8 --queries-per-epoch 16
"""
from __future__ import annotations

import argparse
import collections
import threading
import time
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core.versioned import Version
from repro.graph.dyngraph import MutationBatch, synthesize_churn_stream
from repro.graph.query import (DegreeTopK, KHop, PageRankQuery, Query,
                               QueryResult, Reachability, SnapshotQueryEngine,
                               query_touch_vertices)
from repro.graph.sharded import ShardedDynamicGraph


class GraphQueryServer:
    """Serves online graph queries while mutations stream into the shards.

    ``view_keep`` / ``rank_keep`` bound the stitched-view and PageRank
    caches (ladder retention); ``gc_every`` runs that GC every N sealed
    epochs so a long-lived server tracks the frontier instead of pinning
    every epoch it ever served. ``prewarm_pagerank`` computes ranks eagerly
    after every :meth:`step` (warm-started from the previous epoch,
    outside the server lock so queries are never stalled behind it),
    keeping the warm chain unbroken even when PageRank queries are sparse.

    The server is also the access-pattern feed for dynamic re-sharding
    (``docs/ARCHITECTURE.md``): every flushed window's touch vertices are
    binned into the graph's ``AccessStats`` ledger, and — when the graph
    was constructed with a ``ShardPlanner`` and ``auto_reshard`` is left
    on — :meth:`step` runs the planner tick at its ENTRY, the
    between-epochs point where the store is guaranteed quiescent; a fired
    split's migration then applies inside the incoming batch's seal, so a
    stream that simply stops never strands a migration. Splits are
    appended to :attr:`reshard_events` as they fire; after a cutover the
    GC pass drops cache entries keyed by the retired routing plan
    (``plan_floor``) instead of aging them through the ladder.

    Thread-safety: one re-entrant lock serializes every touch of mutable
    graph/engine state (ingest, seal, re-shard, cache GC, stats); query
    execution runs on immutable stitched views outside the lock, so
    ingestion never waits on query compute.
    """

    def __init__(self, graph: ShardedDynamicGraph, *,
                 view_keep: int = 8, rank_keep: int = 4, gc_every: int = 1,
                 prewarm_pagerank: bool = False, auto_reshard: bool = True,
                 **pagerank_kw):
        self.graph = graph
        self.engine = SnapshotQueryEngine(**pagerank_kw)
        self.view_keep = view_keep
        self.rank_keep = rank_keep
        self.gc_every = max(1, gc_every)
        self.prewarm_pagerank = prewarm_pagerank
        self.auto_reshard = auto_reshard
        self.reshard_events: list[dict] = []
        # one lock serializes every touch of the mutable graph state; query
        # execution on an (immutable) stitched view runs outside it
        self._lock = threading.RLock()
        self._pending: list[tuple[Query, float]] = []
        self._seals = 0
        # bounded: stats() percentiles are over the most recent window, and
        # a long-lived server does not accumulate per-query floats forever
        self.latencies_s: collections.deque[float] = \
            collections.deque(maxlen=8192)
        self.served = 0
        self.ingest_thread: Optional[threading.Thread] = None
        graph.on_frontier_advance(self._on_seal)

    # -- ingestion side ----------------------------------------------------
    def _on_seal(self, frontier: int) -> None:
        # fires inside seal_epoch/seal_shard; re-entrant lock covers the
        # case of a caller sealing the graph directly, outside step()
        with self._lock:
            self._seals += 1
            if self._seals % self.gc_every == 0:
                self.graph.gc_views(self.view_keep)
                self.engine.gc(self.rank_keep,
                               retire_below=self.graph.plan_floor())

    def _maybe_prewarm(self) -> None:
        if not self.prewarm_pagerank:
            return
        with self._lock:
            v = self.graph.latest_sealed()
            if v is None:
                return
            view = self.graph.join_view(v)   # O(delta) stitch under lock
        # the PageRank iteration — the heaviest compute here — runs outside
        # the server lock (the engine's own cache lock suffices), so the
        # query side is never stalled behind a prewarm
        self.engine.pagerank(view)
        # the prewarm inserted the newest view/ranks AFTER the seal-time GC
        # pass; re-prune so the cache bounds hold after every step (the
        # ladder always retains the newest entry, so nothing useful drops)
        with self._lock:
            self.graph.gc_views(self.view_keep)
            floor = self.graph.plan_floor()
        self.engine.gc(self.rank_keep, retire_below=floor)

    def step(self, batch: MutationBatch) -> None:
        """Ingest one mutation batch and seal its epoch on every shard —
        the cooperative serving loop's ingestion tick. With
        ``prewarm_pagerank`` the epoch's ranks are warmed here, after the
        seal releases the lock.

        With ``auto_reshard`` (and a planner on the graph) this is also
        the planner tick. It runs at step ENTRY — between epochs the
        store is quiescent, the only state a re-sharding cutover may
        activate from — so a split's migration always applies inside THIS
        batch's seal (the cutover epoch is the one about to be ingested),
        and a stream that simply stops can never strand a dispatched
        migration in a never-sealed epoch. Splits are recorded in
        :attr:`reshard_events`."""
        with self._lock:
            if self.auto_reshard:
                event = self.graph.maybe_reshard()
                if event is not None:
                    self.reshard_events.append(event)
            self.graph.ingest(batch)
            self.graph.seal_epoch(batch.version.epoch)
        self._maybe_prewarm()

    def start_background_ingest(self, stream: Iterable[MutationBatch], *,
                                delay_s: float = 0.0) -> threading.Thread:
        """Drive :meth:`step` over ``stream`` on a daemon thread — queries
        keep flowing on the caller's thread while epochs seal behind the
        lock. Returns the (started) thread; join it to wait for the stream
        to drain."""

        def pump():
            for batch in stream:
                self.step(batch)
                if delay_s:
                    time.sleep(delay_s)

        t = threading.Thread(target=pump, daemon=True,
                             name="graph-ingest")
        self.ingest_thread = t
        t.start()
        return t

    # -- query side --------------------------------------------------------
    def latest_version(self) -> Optional[Version]:
        with self._lock:
            return self.graph.latest_sealed()

    def submit(self, query: Query) -> None:
        """Enqueue a query into the current window (answered at the next
        :meth:`flush`, all same-kind queries in one vectorized call).
        Thread-safe: submitters may race each other and the flusher."""
        with self._lock:
            self._pending.append((query, time.perf_counter()))

    def flush(self) -> list[QueryResult]:
        """Answer every pending query against the newest frontier-sealed
        snapshot. Raises if nothing is globally sealed yet."""
        with self._lock:
            pending, self._pending = self._pending, []
            if not pending:
                return []
            v = self.graph.latest_sealed()
            if v is None:
                # re-queue AHEAD of anything submitted since the swap so
                # window order is preserved (nothing was answered yet)
                self._pending = pending + self._pending
                raise RuntimeError(
                    "no globally sealed snapshot yet — seal an epoch on "
                    "every shard before querying")
            view = self.graph.join_view(v)
        # the stitched view is immutable once built: execute outside the
        # lock so ingestion never waits on query compute. A failing window
        # (e.g. one malformed query) is re-queued, not silently discarded.
        try:
            values = self.engine.execute(view, [q for q, _ in pending])
        except BaseException:
            with self._lock:
                self._pending = pending + self._pending
            raise
        done = time.perf_counter()
        results = [QueryResult(q, val, v, done - t0)
                   for (q, t0), val in zip(pending, values, strict=True)]
        with self._lock:
            # access-pattern feed: bin this window's touch vertices into
            # the re-sharding planner's ledger (no-op on custom routes) —
            # only AFTER the window succeeded, so a failing window
            # re-queued above cannot double-count touches on every retry
            self.graph.record_query_touches(
                query_touch_vertices([q for q, _ in pending]))
            self.latencies_s.extend(r.latency_s for r in results)
            self.served += len(results)
        return results

    def query(self, q: Query) -> QueryResult:
        """Submit + flush a single query (convenience / point lookups).
        Flushes the whole pending window and returns THIS query's result
        (it is the last submitted, so the last in the window)."""
        self.submit(q)
        return self.flush()[-1]

    # -- telemetry ---------------------------------------------------------
    def stats(self) -> dict:
        """Serving snapshot: latency percentiles over the recent window,
        cache sizes, vectorized-call and PageRank warm-start counters,
        plus re-sharding state (shard count, active plan id, splits so
        far). Thread-safe."""
        with self._lock:
            lat = np.asarray(self.latencies_s)
            served = self.served
            reshard_events = list(self.reshard_events)
            frontier = self.graph.coordinator.global_frontier
            cached_views = len(self.graph._views)
            n_shards = self.graph.n_shards
            plan = self.graph.plan
        return {
            "served": served,
            "n_shards": n_shards,
            "routing_plan_id": plan.plan_id if plan is not None else None,
            "reshard_events": reshard_events,
            "query_p50_s": float(np.percentile(lat, 50)) if lat.size else 0.0,
            "query_p95_s": float(np.percentile(lat, 95)) if lat.size else 0.0,
            "global_frontier": frontier,
            "cached_stitched_views": cached_views,
            "cached_rank_versions": len(self.engine.cached_rank_versions),
            "vectorized_calls": dict(self.engine.vectorized_calls),
            "rank_cache_hits": self.engine.rank_cache_hits,
            "rank_warm_starts": self.engine.rank_warm_starts,
            "rank_cold_starts": self.engine.rank_cold_starts,
        }


def _demo_queries(rng: np.random.Generator, n: int,
                  count: int) -> Sequence[Query]:
    qs: list[Query] = []
    for _ in range(count):
        roll = rng.random()
        if roll < 0.5:
            qs.append(KHop(int(rng.integers(0, n)), k=2))
        elif roll < 0.8:
            qs.append(Reachability(int(rng.integers(0, n)),
                                   int(rng.integers(0, n)), max_hops=8))
        elif roll < 0.95:
            qs.append(DegreeTopK(8))
        else:
            qs.append(PageRankQuery(top_k=8))
    return qs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=2_000)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--adds-per-epoch", type=int, default=1_000)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--queries-per-epoch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    batches = synthesize_churn_stream(args.vertices, args.epochs,
                                      args.adds_per_epoch, seed=args.seed,
                                      delete_frac=0.2)
    e_max = sum(len(b.add_src) for b in batches) + 16
    sg = ShardedDynamicGraph(args.shards, args.vertices, e_max)
    server = GraphQueryServer(sg, prewarm_pagerank=True, tol=1e-6,
                              max_iter=200)
    rng = np.random.default_rng(args.seed + 1)
    t0 = time.perf_counter()
    for batch in batches:
        server.step(batch)                      # ingestion tick
        for q in _demo_queries(rng, args.vertices,
                               args.queries_per_epoch):
            server.submit(q)
        results = server.flush()                # one vectorized window
        v = results[0].version if results else None
        print(f"epoch {batch.version.epoch}: answered {len(results)} "
              f"queries @ snapshot {v}")
    wall = time.perf_counter() - t0
    s = server.stats()
    print(f"\nserved {s['served']} queries over {args.epochs} epochs "
          f"in {wall:.2f}s")
    print(f"  p50={s['query_p50_s']*1e3:.2f}ms p95={s['query_p95_s']*1e3:.2f}ms")
    print(f"  vectorized calls: {s['vectorized_calls']} "
          f"(vs {s['served']} queries)")
    print(f"  pagerank warm starts: {s['rank_warm_starts']}, "
          f"cold: {s['rank_cold_starts']}, cache hits: {s['rank_cache_hits']}")
    print(f"  bounded caches: {s['cached_stitched_views']} views, "
          f"{s['cached_rank_versions']} rank versions")


if __name__ == "__main__":
    main()
