"""Mixtral-8x22B [arXiv:2401.04088]: 56L, d_model=6144, 48 heads GQA kv=8,
8 experts top-2 with d_ff=16384 each, vocab 32768, SWA window 4096, SwiGLU
experts, RMSNorm, RoPE. SWA => sub-quadratic => runs long_500k."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    pattern=("swa",),
    ffn="moe",
    norm="rms",
    rope=True,
    rope_theta=1_000_000.0,
    swa_window=4096,
    n_experts=8,
    top_k=2,
    d_ff_expert=16384,
    expert_sharding="tensor",   # 8 experts % 16 != 0 -> TP inside experts
    subquadratic=True,
))
