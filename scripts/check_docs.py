"""Docs reference checker: every code reference in the docs must resolve.

Scans the inline-code spans (single-backtick; fenced blocks are skipped —
they hold ASCII diagrams and shell transcripts) of ``docs/ARCHITECTURE.md``
and ``examples/README.md`` and verifies three kinds of token, word by
word:

1. **Paths** — tokens matching ``*.py|md|yml|yaml|json|toml`` must exist
   relative to the repo root, under ``src/repro/`` (so ``graph/sharded.py``
   resolves), or under ``examples/``.
2. **Dotted repro symbols** — ``repro.mod[.sub][.Symbol]`` must import,
   with any trailing attribute resolving via ``getattr``.
3. **Class attributes** — ``ClassName.attr`` where ``ClassName`` is
   exported by one of the graph/core/launch modules must have that
   attribute; an unknown ``ClassName`` is an error (docs should reference
   checkable names).

Anything else (inline math, shell flags, plain identifiers) is ignored.
Exit status 1 with a listing if any reference is dangling.

    PYTHONPATH=src python scripts/check_docs.py
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOCS = ["docs/ARCHITECTURE.md", "examples/README.md"]
PATH_DIRS = [".", "src/repro", "examples"]
REGISTRY_MODULES = [
    "repro.graph.dyngraph", "repro.graph.sharded", "repro.graph.query",
    "repro.graph.compute", "repro.graph.reference", "repro.graph.partition",
    "repro.core.snapshotter", "repro.core.replica", "repro.core.versioned",
    "repro.core.clock", "repro.core.views", "repro.launch.serve_graph",
    "repro.launch.rpc", "repro.graph.wal",
]

PATH_RE = re.compile(r"^[\w./-]+\.(py|md|yml|yaml|json|toml)$")
REPRO_RE = re.compile(r"^repro(\.\w+)+$")
CLASS_ATTR_RE = re.compile(r"^([A-Z]\w+)\.(\w+)$")
MODULE_ATTR_RE = re.compile(r"^([a-z_]\w*)\.(\w+)$")


def inline_spans(text: str) -> list[str]:
    """Single-backtick spans outside fenced code blocks."""
    no_fences = re.sub(r"```.*?```", "", text, flags=re.S)
    return re.findall(r"`([^`\n]+)`", no_fences)


def build_registry() -> tuple[dict, dict]:
    classes: dict[str, object] = {}
    modules: dict[str, object] = {}
    for name in REGISTRY_MODULES:
        mod = importlib.import_module(name)
        modules[name.rsplit(".", 1)[-1]] = mod
        for attr in dir(mod):
            obj = getattr(mod, attr)
            if isinstance(obj, type):
                classes.setdefault(attr, obj)
    return classes, modules


def check_token(token: str, classes: dict, modules: dict) -> str | None:
    """Return an error string for a dangling reference, None otherwise."""
    token = token.rstrip(".,;:")
    if PATH_RE.match(token):
        if any((ROOT / d / token).exists() for d in PATH_DIRS):
            return None
        return f"path not found: {token}"
    if REPRO_RE.match(token):
        parts = token.split(".")
        for cut in range(len(parts), 1, -1):
            try:
                obj = importlib.import_module(".".join(parts[:cut]))
            except ImportError:
                continue
            for attr in parts[cut:]:
                if not hasattr(obj, attr):
                    return f"symbol not found: {token}"
                obj = getattr(obj, attr)
            return None
        return f"module not importable: {token}"
    m = CLASS_ATTR_RE.match(token)
    if m:
        cls_name, attr = m.groups()
        cls = classes.get(cls_name)
        if cls is None:
            return f"unknown class in reference: {token}"
        if not hasattr(cls, attr):
            return f"class attribute not found: {token}"
        return None
    m = MODULE_ATTR_RE.match(token)
    if m and m.group(1) in modules:
        if not hasattr(modules[m.group(1)], m.group(2)):
            return f"module attribute not found: {token}"
    return None


def main() -> int:
    classes, modules = build_registry()
    errors: list[str] = []
    for doc in DOCS:
        path = ROOT / doc
        if not path.exists():
            errors.append(f"{doc}: file missing")
            continue
        for span in inline_spans(path.read_text()):
            for word in span.split():
                err = check_token(word, classes, modules)
                if err:
                    errors.append(f"{doc}: {err}")
    for e in errors:
        print(f"FAIL: {e}")
    if not errors:
        print(f"OK: all code references in {', '.join(DOCS)} resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
