"""Pallas TPU kernel: diagonal linear recurrence h_t = a_t * h_{t-1} + b_t
(RG-LRU / gated linear RNN inner loop).

Grid = (B, C // CB). Each instance owns a (S, CB) channel slab in VMEM and
walks time in *chunks*: within a chunk the recurrence is unrolled
sequentially over rows (vector ops across the CB lanes — the VPU's native
layout), and the chunk carry is a single (CB,) vector. The computation is
memory-bound (each element is touched once); keeping the full slab resident
makes it one HBM read + one write, which is the roofline optimum — a
log-depth scan would only add traffic.

VMEM per instance: a,b,(h) slabs (3 x S x CB x 4B): S=4096, CB=256 -> 12 MB.
Longer sequences are tiled over time by the wrapper (carry chaining).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHANNEL_BLOCK = 256
DEFAULT_TIME_CHUNK = 256
MAX_RESIDENT_S = 8192


def _kernel(a_ref, b_ref, h0_ref, o_ref, *, seq_len, time_chunk):
    carry = h0_ref[0]                                      # (CB,)
    n_chunks = seq_len // time_chunk

    def chunk(ci, carry):
        base = ci * time_chunk
        a = a_ref[0, pl.ds(base, time_chunk)]              # (TC, CB)
        b = b_ref[0, pl.ds(base, time_chunk)]
        out = jnp.zeros_like(a)

        def step(t, state):
            carry, out = state
            carry = a[t] * carry + b[t]
            return carry, out.at[t].set(carry)

        carry, out = jax.lax.fori_loop(0, time_chunk, step, (carry, out))
        o_ref[0, pl.ds(base, time_chunk)] = out
        return carry

    jax.lax.fori_loop(0, n_chunks, chunk, carry)


@functools.partial(jax.jit, static_argnames=("channel_block", "time_chunk",
                                             "interpret"))
def lru_scan(a, b, h0=None, *, channel_block: int = DEFAULT_CHANNEL_BLOCK,
             time_chunk: int = DEFAULT_TIME_CHUNK, interpret: bool = False):
    """a, b: (B, S, C) f32 -> h: (B, S, C) f32, h_0 = a_0*h0 + b_0."""
    B, S, C = a.shape
    cb = min(channel_block, C)
    tc = min(time_chunk, S)
    assert C % cb == 0, (C, cb)
    s_pad = (-S) % tc
    if s_pad:
        # pad with identity steps (a=1, b=0) at the END; slice off after
        a = jnp.pad(a, ((0, 0), (0, s_pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, s_pad), (0, 0)))
    S_p = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, C), jnp.float32)
    if S_p > MAX_RESIDENT_S:
        # time-tile through the wrapper with carry chaining
        outs = []
        carry = h0
        for lo in range(0, S_p, MAX_RESIDENT_S):
            seg = slice(lo, lo + MAX_RESIDENT_S)
            h = lru_scan(a[:, seg], b[:, seg], carry,
                         channel_block=cb, time_chunk=tc, interpret=interpret)
            carry = h[:, -1]
            outs.append(h)
        return jnp.concatenate(outs, axis=1)[:, :S]
    grid = (B, C // cb)
    out = pl.pallas_call(
        functools.partial(_kernel, seq_len=S_p, time_chunk=tc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S_p, cb), lambda bi, ci: (bi, 0, ci)),
            pl.BlockSpec((1, S_p, cb), lambda bi, ci: (bi, 0, ci)),
            pl.BlockSpec((1, cb), lambda bi, ci: (bi, ci)),
        ],
        out_specs=pl.BlockSpec((1, S_p, cb), lambda bi, ci: (bi, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((B, S_p, C), jnp.float32),
        interpret=interpret,
    )(a, b, h0)
    return out[:, :S]
