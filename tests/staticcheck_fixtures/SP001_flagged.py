"""SP001 fixture: seal-plane closures mutating non-shard-owned state."""
import time


class Sharded:
    def __init__(self, n_shards):
        self.shards = [object() for _ in range(n_shards)]
        self.shard_apply_seconds = [0.0] * n_shards
        self.migrations = []
        self.frontier = -1

    def _on_seal(self, shard_id):
        def on_seal(epoch, payloads):
            t0 = time.perf_counter()
            self.migrations.append(epoch)            # SP001: serial seam
            self.frontier = epoch                    # SP001: rebinds self attr
            self.shard_apply_seconds[0] += (         # SP001: not shard_id slot
                time.perf_counter() - t0)
        return on_seal
