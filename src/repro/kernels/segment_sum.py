"""Pallas TPU kernel: sorted segment-sum (the join-group-by hot spot).

TPU adaptation of the paper's join-group-by operator (DESIGN.md §2): instead
of a GPU warp-per-row scatter, the reduction is reformulated as an MXU
matmul: for each edge block, ``one_hot(segment_ids) @ values`` accumulates
into a VMEM-resident output column block. The one-hot compare runs on the
VPU; the (n x EB) @ (EB x FB) product runs on the MXU at full tilt, which
beats serialized scatters for the dense-ish degree distributions of real
graphs.

Blocking: grid = (F // FB, m // EB); the edge axis is the *inner* (fastest)
grid dim so the (n, FB) accumulator block stays resident in VMEM across the
whole edge sweep (Pallas keeps a block resident while its index_map output
is unchanged); it is zeroed at the first edge step and written back once.

VMEM budget per instance: (n, FB) f32 accumulator + (EB, FB) values +
(n, EB) one-hot — with n<=4096, FB=128, EB=512: ~2 MB + 0.25 MB + 4 MB,
comfortably inside a v5e core's VMEM. Larger n is tiled by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_EDGE_BLOCK = 512
DEFAULT_FEAT_BLOCK = 128


def _kernel(seg_ref, val_ref, out_ref, *, n: int, edge_block: int):
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    segs = seg_ref[...]                                   # (EB,)
    vals = val_ref[...].astype(jnp.float32)               # (EB, FB)
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, edge_block), 0)
    onehot = (rows == segs[None, :]).astype(jnp.float32)  # (n, EB)
    out_ref[...] += jax.lax.dot(onehot, vals,
                                preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "edge_block",
                                    "feat_block", "interpret"))
def segment_sum(values, segment_ids, num_segments: int, *,
                edge_block: int = DEFAULT_EDGE_BLOCK,
                feat_block: int = DEFAULT_FEAT_BLOCK,
                interpret: bool = False):
    """values: (m, F) sorted by segment; segment_ids: (m,) int32 ascending.
    Returns (num_segments, F) f32. Pads m/F internally."""
    m, F = values.shape
    if m == 0:
        return jnp.zeros((num_segments, F), jnp.float32)
    eb = min(edge_block, max(m, 8))
    fb = min(feat_block, F)
    m_pad = (-m) % eb
    f_pad = (-F) % fb
    if m_pad or f_pad:
        values = jnp.pad(values, ((0, m_pad), (0, f_pad)))
        # padded edges point at segment n (dropped after)
        segment_ids = jnp.pad(segment_ids, (0, m_pad),
                              constant_values=num_segments)
    n_out = num_segments + 1  # +1 row swallows padding
    grid = (values.shape[1] // fb, values.shape[0] // eb)
    out = pl.pallas_call(
        functools.partial(_kernel, n=n_out, edge_block=eb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb,), lambda f, e: (e,)),
            pl.BlockSpec((eb, fb), lambda f, e: (e, f)),
        ],
        out_specs=pl.BlockSpec((n_out, fb), lambda f, e: (0, f)),
        out_shape=jax.ShapeDtypeStruct((n_out, values.shape[1]), jnp.float32),
        interpret=interpret,
    )(segment_ids, values)
    return out[:num_segments, :F]
