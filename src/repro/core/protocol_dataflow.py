"""Protocol dataflow — paper §2.3.3.

A directed graph of *stateful* vertices. Computing starts at an **ingress**
vertex (encapsulates external input into messages per a protocol) and ends at
an **egress** vertex (decapsulates to an external consumer). Each internal
vertex has input queues and output queues plus two schedulers:

* the **input scheduler** picks which queued messages to process next
  (application-specific scheduling — e.g. a priority queue turns label-
  correcting SSSP into Dijkstra);
* the **output scheduler** reorders/coalesces outgoing messages
  (communication optimization — e.g. combining messages to the same target,
  Trinity-style hub buffering).

A **protocol** = (message format, vertex semantics). Different programming
models (Pregel, edge-centric, MapReduce, timely-style epochs) are different
protocols over the same runtime; they compose in one dataflow (paper Fig 6).
Control flow is data-dependent — the runtime loop below is only an executor;
no central scheduler is needed for correctness (paper's scale-out argument).

Event delivery uses Lamport clocks (``core.clock``): every vertex stamps
sends/receives, so delivery in stamp order preserves every causal relation.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Any, Callable, Iterable, Optional

from repro.core.clock import Event, EventLog, LamportClock, Stamp


# ------------------------------------------------------------------ protocol
@dataclasses.dataclass(frozen=True)
class Protocol:
    """Message format + vertex semantics contract."""
    name: str
    validate: Callable[[Any], bool] = lambda payload: True
    # application-defined causal relation for event delivery (optional)
    happens_before: Optional[Callable[[Event, Event], Optional[bool]]] = None


@dataclasses.dataclass(frozen=True)
class Message:
    stamp: Stamp
    epoch: int
    payload: Any


# ---------------------------------------------------------------- schedulers
class FIFOScheduler:
    """Default input scheduler: drain in arrival order."""

    def select(self, queue: deque, budget: int) -> list[Message]:
        out = []
        while queue and len(out) < budget:
            out.append(queue.popleft())
        return out


class PriorityScheduler:
    """Application-specific input scheduling (paper: Dijkstra via priority
    queue). ``key`` maps a payload to its priority (smaller = first)."""

    def __init__(self, key: Callable[[Any], float]):
        self.key = key
        self._heap: list[tuple[float, int, Message]] = []
        self._n = 0

    def select(self, queue: deque, budget: int) -> list[Message]:
        while queue:
            m = queue.popleft()
            heapq.heappush(self._heap, (self.key(m.payload), self._n, m))
            self._n += 1
        out = []
        while self._heap and len(out) < budget:
            out.append(heapq.heappop(self._heap)[2])
        return out


class IdentityOutput:
    def emit(self, msgs: list[tuple[str, Any]]) -> list[tuple[str, Any]]:
        return msgs


class CoalescingOutput:
    """Combine messages with the same coalescing key before sending
    (message-scheduling / communication optimization, §2.3.3.2)."""

    def __init__(self, key: Callable[[Any], Any], combine: Callable[[Any, Any], Any]):
        self.key = key
        self.combine = combine

    def emit(self, msgs: list[tuple[str, Any]]) -> list[tuple[str, Any]]:
        merged: dict[tuple[str, Any], Any] = {}
        order: list[tuple[str, Any]] = []
        for port, payload in msgs:
            k = (port, self.key(payload))
            if k in merged:
                merged[k] = self.combine(merged[k], payload)
            else:
                merged[k] = payload
                order.append(k)
        return [(port, merged[(port, k)]) for port, k in order]


# ------------------------------------------------------------------ vertices
class Vertex:
    """A stateful protocol-dataflow vertex.

    Subclasses (or the ``fn`` constructor arg) implement the protocol's
    semantics: ``fn(vertex, port, payloads) -> iterable of (out_port,
    payload)``. State lives on the instance (``self.state``).
    """

    def __init__(self, name: str, protocol: Protocol,
                 fn: Optional[Callable] = None, *, state: Any = None,
                 input_scheduler=None, output_scheduler=None,
                 budget: int = 1 << 30):
        self.name = name
        self.protocol = protocol
        self.fn = fn
        self.state = state
        self.inputs: dict[str, deque] = {}
        self.out_edges: dict[str, list[tuple["Vertex", str]]] = {}
        self.input_scheduler = input_scheduler or FIFOScheduler()
        self.output_scheduler = output_scheduler or IdentityOutput()
        self.budget = budget
        self.clock: Optional[LamportClock] = None   # set by Dataflow
        self.dataflow: Optional["Dataflow"] = None

    # -- wiring ------------------------------------------------------------
    def in_port(self, port: str) -> deque:
        return self.inputs.setdefault(port, deque())

    def connect(self, out_port: str, dst: "Vertex", dst_port: str = "in"):
        dst.in_port(dst_port)
        self.out_edges.setdefault(out_port, []).append((dst, dst_port))
        return dst

    # -- execution ---------------------------------------------------------
    def has_pending(self) -> bool:
        if any(q for q in self.inputs.values()):
            return True
        heap = getattr(self.input_scheduler, "_heap", None)
        return bool(heap)

    def on_receive(self, port: str, payloads: list[Any]) -> Iterable[tuple[str, Any]]:
        if self.fn is None:
            raise NotImplementedError(f"{self.name} has no semantics fn")
        return self.fn(self, port, payloads) or ()

    def deliver(self, port: str, msg: Message):
        self.clock.receive(msg.stamp)
        self.in_port(port).append(msg)

    def step(self) -> int:
        """Process up to ``budget`` messages; emit results. Returns number of
        messages processed."""
        processed = 0
        for port, queue in list(self.inputs.items()):
            batch = self.input_scheduler.select(queue, self.budget)
            if not batch:
                continue
            processed += len(batch)
            epoch = max(m.epoch for m in batch)
            outs = list(self.on_receive(port, [m.payload for m in batch]))
            self._emit(outs, epoch)
        return processed

    def _emit(self, outs: list[tuple[str, Any]], epoch: int):
        for out_port, payload in self.output_scheduler.emit(outs):
            if not self.protocol.validate(payload):
                raise ValueError(
                    f"{self.name}: payload violates protocol "
                    f"{self.protocol.name}: {payload!r}")
            for dst, dst_port in self.out_edges.get(out_port, ()):
                stamp = self.clock.send()
                self.dataflow.events.record(
                    Event(stamp, "send",
                          {"src": self.name, "dst": dst.name, "epoch": epoch}))
                dst.deliver(dst_port, Message(stamp, epoch, payload))

    def emit_event(self, kind: str, payload: Any = None):
        """User-defined events (paper: 'allows the user to define any kind
        of event')."""
        self.dataflow.events.record(Event(self.clock.tick(), kind, payload))


class Ingress(Vertex):
    """Receives input from an external source and encapsulates it into
    messages according to the protocol (``encode`` is the encapsulation)."""

    def __init__(self, name: str, protocol: Protocol,
                 encode: Optional[Callable[[Any], Any]] = None):
        super().__init__(name, protocol)
        self.encode = encode or (lambda payload: payload)

    def push(self, payloads: Iterable[Any], epoch: int = 0,
             out_port: str = "out"):
        outs = [(out_port, self.encode(p)) for p in payloads]
        self._emit(outs, epoch)


class Egress(Vertex):
    """Decapsulates messages and hands data to an external consumer."""

    def __init__(self, name: str, protocol: Protocol,
                 consumer: Callable[[Any], None]):
        super().__init__(name, protocol, fn=self._consume)
        self.consumer = consumer
        self.received: list[Any] = []

    def _consume(self, _self, port, payloads):
        for p in payloads:
            self.received.append(p)
            self.consumer(p)
        return ()


# ------------------------------------------------------------------ dataflow
class Dataflow:
    """The directed graph + executor + event log."""

    def __init__(self, name: str = "dataflow"):
        self.name = name
        self.vertices: list[Vertex] = []
        self.events = EventLog()
        self._next_id = 0

    def add(self, vertex: Vertex) -> Vertex:
        vertex.clock = LamportClock(self._next_id)
        vertex.dataflow = self
        self._next_id += 1
        self.vertices.append(vertex)
        if vertex.protocol.happens_before is not None:
            self.events.register_relation(vertex.protocol.happens_before)
        return vertex

    def run_until_quiescent(self, max_rounds: int = 10_000) -> int:
        """Data-dependent control flow: keep stepping vertices that have
        pending input. Returns number of rounds."""
        for round_no in range(max_rounds):
            work = 0
            for v in self.vertices:
                if v.has_pending():
                    work += v.step()
            if work == 0:
                return round_no
        raise RuntimeError(f"{self.name}: not quiescent after {max_rounds} rounds")

    def deliver_events(self) -> list[Event]:
        delivered = self.events.deliver()
        assert self.events.check_causal_consistency(delivered)
        return delivered
