"""Data pipeline: deterministic synthetic LM streams + lineage-tracked
batches (distributed views) + the graph-mutation adapter.

The Markov-chain token stream has real learnable structure (a random sparse
transition matrix), so the quickstart's loss visibly falls below the unigram
entropy floor — i.e. training is actually learning, not just driving the
bias terms.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.views import View


@dataclasses.dataclass
class MarkovLM:
    vocab_size: int
    branching: int = 8
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.next_tokens = rng.integers(
            0, self.vocab_size, size=(self.vocab_size, self.branching))

    def sample(self, rng, batch, seq):
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=batch)
        for t in range(seq):
            choice = rng.integers(0, self.branching, size=batch)
            toks[:, t + 1] = self.next_tokens[toks[:, t], choice]
        return toks


class TokenPipeline:
    """Deterministic, restartable pipeline: batch i is a pure function of
    (seed, i) — a distributed view whose lineage is just its index, so a
    failed/elastic-restarted worker regenerates any batch exactly."""

    def __init__(self, vocab_size, batch, seq, *, seed=0, frames_dim=None):
        self.lm = MarkovLM(vocab_size, seed=seed)
        self.batch, self.seq, self.seed = batch, seq, seed
        self.frames_dim = frames_dim

    def batch_view(self, index: int) -> View:
        def produce():
            rng = np.random.default_rng((self.seed, index))
            toks = self.lm.sample(rng, self.batch, self.seq)
            batch = {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
            if self.frames_dim:  # frames-mode archs: stub frontend embeddings
                emb_rng = np.random.default_rng((self.seed, index, 7))
                batch["inputs"] = emb_rng.standard_normal(
                    (self.batch, self.seq, self.frames_dim)).astype(np.float32)
            return batch
        return View.source(f"batch[{index}]", produce)

    def __iter__(self):
        i = 0
        while True:
            yield self.batch_view(i).value()
            i += 1


def unigram_entropy_floor(lm: MarkovLM) -> float:
    """Entropy of the stationary unigram distribution (nats) — the loss a
    context-blind model converges to; the Markov structure admits lower."""
    counts = np.bincount(lm.next_tokens.reshape(-1),
                         minlength=lm.vocab_size).astype(np.float64)
    p = counts / counts.sum()
    p = p[p > 0]
    return float(-(p * np.log(p)).sum())
