import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines, before any jax import: jax locks the device
# count on first init. The dry-run (and ONLY the dry-run) gets 512
# placeholder host devices so jax.make_mesh can build the production mesh.
os.environ.setdefault("REPRO_FORCE_BF16", "1")  # lower with TPU-real dtypes

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory/cost/collective statistics.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Every result is appended incrementally to results/dryrun/<arch>__<shape>__<mesh>.json
so a long --all run can be resumed/parallelized; existing cells are skipped
unless --force.
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, all_configs
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "e4m3": 1, "e5m2": 1,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+)?)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str):
    """Sum operand bytes of every collective op in the (post-SPMD) HLO."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # match '<shape> kind(' — the op use, not metadata mentions
            marker = f" {kind}("
            start_marker = f"{kind}-start("
            if marker not in stripped and start_marker not in stripped:
                continue
            # operands are inside the parens following the op name
            idx = stripped.find(marker)
            if idx < 0:
                idx = stripped.find(start_marker)
            paren = stripped.find("(", idx)
            operand_text = stripped[paren:]
            total = sum(_shape_bytes(d, s)
                        for d, s in _SHAPE_RE.findall(operand_text))
            stats[kind]["count"] += 1
            stats[kind]["bytes"] += total
            break
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


def shardings_for(cfg, shape_name, mesh, multi_pod):
    cell = SHAPES[shape_name]
    long_ctx = cell.name == "long_500k"
    mapping = shd.baseline_mapping(multi_pod, long_context=long_ctx,
                                   serve=cell.kind != "train",
                                   expert_sharding=cfg.expert_sharding)
    rules = shd.ShardingRules(mesh, mapping)

    def ns(spec):
        return NamedSharding(mesh, spec)
    ins = input_specs(cfg, shape_name)

    def batch_sharding(tree):
        def leaf(x):
            spec = rules.spec(("batch",) + (None,) * (len(x.shape) - 1), x.shape)
            return ns(spec)
        return jax.tree.map(leaf, tree)

    if cell.kind == "train":
        pspecs = shd.param_specs(ins["state"]["params"], rules)
        state_sh = {
            "params": jax.tree.map(ns, pspecs),
            "opt": {"m": jax.tree.map(ns, pspecs),
                    "v": jax.tree.map(ns, pspecs),
                    "count": ns(P())},
            "step": ns(P()),
        }
        args = (ins["state"], ins["batch"])
        in_sh = (state_sh, batch_sharding(ins["batch"]))
        return args, in_sh, rules
    pspecs = shd.param_specs(ins["params"], rules)
    params_sh = jax.tree.map(ns, pspecs)
    if cell.kind == "prefill":
        args = (ins["params"], ins["batch"])
        in_sh = (params_sh, batch_sharding(ins["batch"]))
        return args, in_sh, rules
    cache_sh = jax.tree.map(ns, shd.cache_specs(ins["cache"], rules))
    args = (ins["params"], ins["cache"], ins["inputs"], ins["pos"])
    in_sh = (params_sh, cache_sh,
             batch_sharding(ins["inputs"]), ns(P()))
    return args, in_sh, rules


def step_fn_for(cfg, shape_name):
    kind = SHAPES[shape_name].kind
    if kind == "train":
        return make_train_step(cfg)
    if kind == "prefill":
        return make_prefill_step(cfg)
    return make_decode_step(cfg)


def cell_applicable(cfg, shape_name) -> bool:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False
    return True


def parse_overrides(pairs):
    """--override key=value (int/float/str/bool inferred) for §Perf variants."""
    out = {}
    for pair in pairs or ():
        k, v = pair.split("=", 1)
        for cast in (int, float):
            try:
                out[k] = cast(v)
                break
            except ValueError:
                continue
        else:
            out[k] = {"true": True, "false": False}.get(v.lower(), v)
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, save_hlo=False,
             overrides=None, tag=""):
    import dataclasses
    cfg = all_configs()[arch]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn = step_fn_for(cfg, shape_name)
    args, in_sh, rules = shardings_for(cfg, shape_name, mesh, multi_pod)
    from repro.nn.layers import bf16_backward_scope
    with rules.active(), bf16_backward_scope(cfg.bwd_dtype == "bfloat16"):
        jitted = jax.jit(fn, in_shardings=in_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "transcendentals": cost.get("transcendentals"),
        },
        "collectives": coll,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if save_hlo:
        suffix = f"__{tag}" if tag else ""
        (RESULTS / f"{arch}__{shape_name}__{mesh_kind}{suffix}.hlo.txt"
         ).write_text(hlo)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--override", action="append", default=None,
                    help="cfg field override key=value (repeatable)")
    ap.add_argument("--tag", default="",
                    help="suffix for result files (perf variants)")
    args = ap.parse_args()
    overrides = parse_overrides(args.override)

    RESULTS.mkdir(parents=True, exist_ok=True)
    archs = sorted(all_configs()) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        cfg = all_configs()[arch]
        for shape_name in shapes:
            for mesh_kind in meshes:
                tag = f"__{args.tag}" if args.tag else ""
                out = RESULTS / f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
                if out.exists() and not args.force:
                    print(f"[skip] {out.name} exists")
                    continue
                if not cell_applicable(cfg, shape_name):
                    out.write_text(json.dumps({
                        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
                        "skipped": "long_500k needs sub-quadratic attention; "
                                   "this arch is pure full-attention "
                                   "(see DESIGN.md §Arch-applicability)"}))
                    print(f"[SKIP] {arch} x {shape_name} (full attention)")
                    continue
                print(f"[run ] {arch} x {shape_name} x {mesh_kind} "
                      f"{overrides or ''}...", flush=True)
                try:
                    res = run_cell(arch, shape_name, mesh_kind, args.save_hlo,
                                   overrides=overrides, tag=args.tag)
                    if args.tag:
                        res["tag"] = args.tag
                        res["overrides"] = overrides
                    out.write_text(json.dumps(res, indent=1))
                    print(f"[ ok ] {arch} x {shape_name} x {mesh_kind}: "
                          f"flops/dev={res['cost']['flops']:.3e} "
                          f"coll={res['collectives']['total_bytes']:.3e}B "
                          f"compile={res['compile_s']}s", flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append((arch, shape_name, mesh_kind, repr(e)))
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\nall requested dry-run cells OK")


if __name__ == "__main__":
    main()
