"""Self-tests for the reprolint static-analysis suite.

The contract under test, per the tentpole's acceptance criteria:

* every registered rule fires on its ``<RULE>_flagged.py`` fixture and
  is silent on the ``<RULE>_clean.py`` twin (clean twins must be clean
  under EVERY rule, not just their own — the fixture corpus doubles as
  the checkers' false-positive regression suite),
* suppression comments are honored,
* the repo tree itself is clean modulo the committed baseline (the gate
  CI runs), and the fixed true positives in ``serve_graph.py`` /
  ``query.py`` / ``sharded.py`` stay fixed,
* deliberately-introduced violations of each family fail the gate.
"""
import pathlib
import subprocess
import sys

import pytest

from repro.analysis import staticcheck
from repro.analysis.staticcheck import core as sc_core
from repro.analysis.staticcheck import lockcheck

ROOT = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = ROOT / "tests" / "staticcheck_fixtures"
RULES = sorted(staticcheck.RULES)


def run_on(path: pathlib.Path):
    return staticcheck.check_file(path, ROOT)


# ---------------------------------------------------------------- fixtures
@pytest.mark.parametrize("rule", RULES)
def test_every_rule_has_fixture_pair(rule):
    """Meta-test: the corpus carries a flagged/clean pair per rule."""
    assert (FIXTURES / f"{rule}_flagged.py").exists(), rule
    assert (FIXTURES / f"{rule}_clean.py").exists(), rule


@pytest.mark.parametrize("rule", RULES)
def test_rule_fires_on_flagged_fixture(rule):
    found = {f.rule for f in run_on(FIXTURES / f"{rule}_flagged.py")}
    assert rule in found, f"{rule} silent on its flagged fixture"


@pytest.mark.parametrize("rule", RULES)
def test_clean_fixture_is_clean_under_all_rules(rule):
    findings = run_on(FIXTURES / f"{rule}_clean.py")
    assert findings == [], [f.format() for f in findings]


def test_flagged_fixtures_report_expected_counts():
    """Spot-check finding counts so a checker that degenerates into
    flagging everything (or collapsing to one hit) is caught."""
    assert len([f for f in run_on(FIXTURES / "TS001_flagged.py")
                if f.rule == "TS001"]) == 3
    assert len([f for f in run_on(FIXTURES / "SP001_flagged.py")
                if f.rule == "SP001"]) == 3
    assert len([f for f in run_on(FIXTURES / "SH003_flagged.py")
                if f.rule == "SH003"]) == 2


# ------------------------------------------------------------ suppressions
def test_suppression_comments_are_honored():
    findings = run_on(FIXTURES / "suppressed_ok.py")
    assert findings == [], [f.format() for f in findings]


def test_suppression_is_rule_specific():
    src = (FIXTURES / "SH003_flagged.py").read_text()
    patched = src.replace(
        "return packed >> 32",
        "return packed >> 32    # reprolint: disable=RL001")
    findings = staticcheck.check_source(
        patched, "tests/staticcheck_fixtures/SH003_flagged.py")
    # suppressing the WRONG rule must not silence the finding
    assert any(f.rule == "SH003" and f.line == 5 for f in findings)


def test_disable_file_silences_the_whole_file():
    src = ("# reprolint: disable-file=SH003\n"
           + (FIXTURES / "SH003_flagged.py").read_text())
    assert staticcheck.check_source(
        src, "tests/staticcheck_fixtures/SH003_flagged.py") == []


# ------------------------------------------------------- repo-level gating
def test_repo_tree_is_clean_modulo_baseline():
    targets = [ROOT / t for t in ("src/repro", "scripts", "benchmarks",
                                  "examples") if (ROOT / t).exists()]
    findings = staticcheck.check_paths(
        targets, ROOT,
        exclude_parts=("tests", "staticcheck_fixtures", "__pycache__"))
    baseline = staticcheck.load_baseline(
        ROOT / "scripts" / "staticcheck_baseline.json")
    new, _ = staticcheck.gate(findings, baseline)
    assert new == [], [f.format() for f in new]


@pytest.mark.parametrize("target", [
    "src/repro/launch/serve_graph.py",    # unguarded server state (fixed)
    "src/repro/graph/query.py",           # unguarded telemetry (fixed)
    "src/repro/graph/sharded.py",         # raw >>32 unpacks (fixed)
])
def test_fixed_true_positives_stay_fixed(target):
    findings = run_on(ROOT / target)
    assert findings == [], [f.format() for f in findings]


def test_gate_exit_codes():
    script = ROOT / "scripts" / "run_staticcheck.py"
    clean = subprocess.run(
        [sys.executable, str(script), "--gate",
         str(ROOT / "src" / "repro" / "graph")],
        capture_output=True, text=True)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    dirty = subprocess.run(
        [sys.executable, str(script), "--gate",
         str(FIXTURES / "SH003_flagged.py")],
        capture_output=True, text=True)
    assert dirty.returncode == 1, dirty.stdout + dirty.stderr
    assert "SH003" in dirty.stdout


def test_baseline_absorbs_exact_count():
    findings = run_on(FIXTURES / "SH003_flagged.py")
    sh3 = [f for f in findings if f.rule == "SH003"]
    key = sc_core.baseline_key(sh3[0])
    new, _ = staticcheck.gate(sh3, {key: len(sh3)})
    assert new == []
    new, _ = staticcheck.gate(sh3, {key: len(sh3) - 1})
    assert len(new) == 1


# ------------------------------------------- deliberate violations fail CI
REGISTRY_VIOLATION = '''
import threading

class GraphQueryServer:
    def __init__(self, graph):
        self.graph = graph
        self._ingest_lock = threading.RLock()
        self._serve_lock = threading.Lock()
        self.served = 0

    def drain(self):
        self.graph.gc_views(4)        # registry-guarded, no lock
        self.served += 1
'''


def test_declarative_registry_guards_by_class_name():
    """The SPEC registry applies to any class with the registered name —
    inference finds no guarded writes here, so only the registry can
    produce these findings."""
    findings = staticcheck.check_source(
        REGISTRY_VIOLATION, "launch/serve_graph_variant.py")
    rl = [f for f in findings if f.rule == "RL001"]
    assert {("graph" in f.message or "served" in f.message)
            for f in rl} == {True}
    assert len(rl) == 2


def test_registry_matches_real_attribute_names():
    """Registry entries must reference attributes that still exist, so a
    rename in the server/engine cannot silently hollow out the rule."""
    import repro.graph.query as q
    import repro.launch.rpc as rpc
    import repro.launch.serve_graph as sg
    from repro.graph.sharded import ShardedDynamicGraph

    srv = sg.GraphQueryServer(ShardedDynamicGraph(2, 64, 256))
    for lock, attrs in lockcheck.SPEC["GraphQueryServer"].locks.items():
        assert hasattr(srv, lock), lock
        for attr in attrs:
            assert hasattr(srv, attr), attr
    front = rpc.GraphRPCServer(srv)
    for lock, attrs in lockcheck.SPEC["GraphRPCServer"].locks.items():
        assert hasattr(front, lock), lock
        for attr in attrs:
            assert hasattr(front, attr), attr
    eng = q.SnapshotQueryEngine()
    for attr in lockcheck.SPEC["SnapshotQueryEngine"].locks["_rank_lock"]:
        assert hasattr(eng, attr), attr


def test_registry_pins_fast_path_state():
    """The fast-path state (lane queues, result cache, prewarm mailbox)
    must be IN the registry — a refactor that drops it from the SPEC
    would silently stop enforcing its lock discipline even though the
    attribute checks above still pass."""
    from repro.analysis.staticcheck import sealcheck

    serve = lockcheck.SPEC["GraphQueryServer"].locks
    assert {"_pending_cheap", "_pending_expensive",
            "_lane_latencies"} <= serve["_serve_lock"]
    assert {"_prewarm_target", "prewarm_runs"} <= serve["_prewarm_lock"]
    rank = lockcheck.SPEC["SnapshotQueryEngine"].locks["_rank_lock"]
    assert {"_result_cache", "result_cache_hits", "result_cache_misses",
            "result_cache_evictions", "_warm_signatures"} <= rank
    # the prewarm worker is publish-path state: a seal-plane closure may
    # never spawn/feed it (it would race the coalescing mailbox)
    assert "_prewarm_thread" in sealcheck.SERIAL_SEAM
    assert "_prewarm_target" in sealcheck.SERIAL_SEAM


def test_registry_pins_durability_plane_state(tmp_path):
    """PR 10's durability plane must stay under reprolint's eye: the WAL
    writer lock and fault-injector lock relations reference live
    attributes, the degraded-mode backlog sits under the server's ingest
    lock, and the seal-plane rules know the store-level WAL + injector
    are serial seams while the per-shard writer list is shard-owned."""
    import repro.launch.serve_graph as sg
    from repro.analysis.staticcheck import sealcheck
    from repro.graph.sharded import ShardedDynamicGraph
    from repro.graph.wal import FaultInjector, GraphWal

    wal = GraphWal(tmp_path)
    try:
        for lock, attrs in lockcheck.SPEC["GraphWal"].locks.items():
            assert hasattr(wal, lock), lock
            for attr in attrs:
                assert hasattr(wal, attr), attr
    finally:
        wal.close()
    inj = FaultInjector()
    for lock, attrs in lockcheck.SPEC["FaultInjector"].locks.items():
        assert hasattr(inj, lock), lock
        for attr in attrs:
            assert hasattr(inj, attr), attr
    srv = sg.GraphQueryServer(ShardedDynamicGraph(2, 64, 256),
                              prewarm_traces=False)
    ingest = lockcheck.SPEC["GraphQueryServer"].locks["_ingest_lock"]
    assert {"_seal_backlog", "seal_failures"} <= ingest
    for attr in ("_seal_backlog", "seal_failures"):
        assert hasattr(srv, attr), attr
    # seal closures touch exactly their own WAL writer slot; everything
    # else in the durability plane belongs to the serial thread
    assert "wal_shards" in sealcheck.SHARD_OWNED
    assert {"wal", "fault_injector",
            "_seal_backlog"} <= sealcheck.SERIAL_SEAM


@pytest.mark.parametrize("family_fixture, rule", [
    ("RL001_flagged.py", "RL001"),
    ("TS001_flagged.py", "TS001"),
    ("SH001_flagged.py", "SH001"),
    ("SP001_flagged.py", "SP001"),
])
def test_each_family_fails_the_gate(family_fixture, rule):
    """One deliberate violation per family must gate non-zero."""
    script = ROOT / "scripts" / "run_staticcheck.py"
    proc = subprocess.run(
        [sys.executable, str(script), "--gate",
         str(FIXTURES / family_fixture)],
        capture_output=True, text=True)
    assert proc.returncode == 1
    assert rule in proc.stdout
