"""Graph query server: online queries on live sharded snapshots.

The paper's central claim is ONE evolving graph serving both offline
analytics and low-latency online queries. This is the online half wired
end to end: a :class:`GraphQueryServer` owns a ``ShardedDynamicGraph``,
keeps ingesting a mutation stream (cooperatively via :meth:`step`, or on a
background thread via :meth:`start_background_ingest`), and answers
typed :class:`~repro.graph.query.QueryRequest` envelopes strictly against
**frontier-sealed snapshots** (``latest_sealed()`` — the global-frontier
rule; a partially-sealed epoch is never served). Query windows are
answered by the ``graph.query.SnapshotQueryEngine``: same-kind queries —
across every submitting client, in-process or RPC — collapse into one
vectorized jitted call, PageRank is cached per snapshot version and
warm-started incrementally from the previous epoch's ranks, and both the
rank cache and the view caches are GC'd with the version-spaced
``ladder_keep`` retention so server memory stays bounded under churn.

**Epoch-pipelined reads (the seal-swap discipline).** Ingest and serving
no longer share one lock. The write plane (``_ingest_lock``) serializes
ingest/seal/re-shard/cache-GC; at every global seal the server stitches
the newly sealed epoch's view and *publishes* it — an atomic pointer swap
under the tiny read-plane lock (``_serve_lock``). Queries pin the
published immutable view and execute entirely outside the write plane, so
windows answer at sealed epoch *e* while epoch *e+1*'s shard applies run
(on the ``parallel_apply`` thread pool) — instead of queuing behind the
apply as they did when one RLock covered both planes. The only
lock-ordering rule is ``_ingest_lock`` → ``_serve_lock`` (publish);
nothing ever nests the other way (enforced by reprolint RL002).

The network front for this server lives in ``launch/rpc.py``
(length-prefixed wire codec, admission control, cross-client batching);
``python -m repro.launch.serve_graph --rpc-port 0`` starts it on a
synthetic stream.

This is layer 5 (the top) of the pipeline mapped in
``docs/ARCHITECTURE.md``, and the serving loop is also where dynamic
re-sharding closes its feedback loop: answered windows buffer their query
touches on the read plane, :meth:`GraphQueryServer.step` drains them into
the store's access ledger and runs the planner tick at its entry — the
between-epochs quiescent point, so a fired split's migration applies
inside the incoming batch's seal.

Usage (synthetic ingest-while-query loop, CPU):
    PYTHONPATH=src python -m repro.launch.serve_graph --vertices 2000 \
        --epochs 8 --queries-per-epoch 16
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import itertools
import threading
import time
from typing import Callable, Iterable, Mapping, Optional, Sequence

import numpy as np

from repro.core.replica import MirrorPlanner
from repro.core.versioned import Version
from repro.graph.dyngraph import (JoinView, MutationBatch, prune_retired,
                                  prune_views, synthesize_churn_stream)
from repro.graph.query import (ERR_BAD_PIN, ERR_BAD_QUERY, ERR_DEADLINE,
                               ERR_OVERLOADED,
                               DegreeTopK, KHop, PageRankQuery, Query,
                               QueryRequest, QueryResponse, QueryResult,
                               Reachability, RoutedSnapshot,
                               SnapshotQueryEngine, query_kind,
                               query_touch_vertices)
from repro.graph.sharded import ShardedDynamicGraph
from repro.graph.wal import ShardFaultError

QUERY_KINDS = ("k_hop", "reachability", "degree_topk", "pagerank")

# lane classification for the two-lane scheduler: cheap kinds answer in
# one bounded jitted sweep (or a cache hit); expensive kinds iterate to
# convergence (PageRank) or may walk the whole graph (cold unbounded
# reachability). An expensive-kind request whose answer is already
# memoized at its target version rides the cheap lane too — it is a dict
# lookup, and that is the whole point of the fast path.
LANES = ("cheap", "expensive")
CHEAP_KINDS = frozenset({"k_hop", "degree_topk"})


@dataclasses.dataclass(frozen=True)
class ServerStats:
    """Frozen serving snapshot with stable field names (the dict-shaped
    ``stats()`` of earlier revisions is gone — benchmarks, examples and
    the RPC ``stats`` op all read these fields).

    ``queue_depth`` is the pending requests at sampling time;
    ``shed_overload`` / ``shed_deadline`` count typed load-shed and
    expired-budget responses; ``per_kind_latency_s`` maps each query kind
    to its ``{"p50", "p95", "p99"}`` submit-to-answer quantiles over the
    recent window (absent kinds were never served).

    Replica-plane telemetry: ``mirror_hits`` / ``mirror_misses`` count
    frontier vertices resolved from mirrors vs shards across every routed
    window; ``fanout_hist`` maps shards-touched-per-routed-group (as a
    string key, for the JSON wire) to occurrence count, ``mean_fanout``
    its mean (`-1.0` before any routed window); ``mirrored_vertices`` is
    the serving snapshot's mirror set size; ``split_events`` /
    ``merge_events`` count completed re-sharding cutovers of each kind.

    Fast-path telemetry: ``queue_depth_by_lane`` / ``per_lane_latency_s``
    break the queue and the quantiles down by scheduler lane;
    ``result_cache_*`` mirror the engine's versioned result cache
    (hits/misses/evictions, live entries, hit rate over all lookups);
    ``prewarm_runs`` counts completed publish-time trace prewarms.

    Degraded-mode telemetry (invariant I11): ``degraded`` is True while
    a failed seal leaves epochs pending — the server keeps answering at
    the last *published* sealed snapshot, never a partial one;
    ``stale_epochs`` is how many ingested epochs the serving frontier
    lags; ``seal_failures`` counts failed seal attempts over the
    server's lifetime (it never resets on recovery)."""
    served: int
    windows: int
    queue_depth: int
    shed_overload: int
    shed_deadline: int
    serving_version: Optional[Version]
    global_frontier: int
    n_shards: int
    routing_plan_id: Optional[int]
    reshard_events: tuple
    query_p50_s: float
    query_p95_s: float
    query_p99_s: float
    per_kind_latency_s: Mapping[str, Mapping[str, float]]
    published_views: int
    cached_stitched_views: int
    cached_rank_versions: int
    vectorized_calls: Mapping[str, int]
    rank_cache_hits: int
    rank_warm_starts: int
    rank_cold_starts: int
    mirror_hits: int
    mirror_misses: int
    mirror_hit_rate: float
    routed_windows: int
    fanout_hist: Mapping[str, int]
    mean_fanout: float
    mirrored_vertices: int
    split_events: int
    merge_events: int
    queue_depth_by_lane: Mapping[str, int]
    per_lane_latency_s: Mapping[str, Mapping[str, float]]
    result_cache_hits: int
    result_cache_misses: int
    result_cache_hit_rate: float
    result_cache_entries: int
    result_cache_evictions: int
    prewarm_runs: int
    degraded: bool = False
    stale_epochs: int = 0
    seal_failures: int = 0


@dataclasses.dataclass
class _Entry:
    """One queued request on the read plane: the typed envelope, its
    submission timestamp (``perf_counter``), the absolute deadline derived
    from ``deadline_s`` (None = no budget), an optional completion
    callback — RPC handlers pass one so the scheduler can push the
    response back on the submitting connection; legacy ``submit()``
    entries have none and are returned by ``flush()`` — and the scheduler
    lane the request was classified into at submission."""
    request: QueryRequest
    enqueued_at: float
    deadline_at: Optional[float] = None
    on_done: Optional[Callable[[QueryResponse], None]] = None
    lane: str = "cheap"


def _quantiles(lat: np.ndarray) -> tuple[float, float, float]:
    if not lat.size:
        return 0.0, 0.0, 0.0
    p50, p95, p99 = (float(np.percentile(lat, q)) for q in (50, 95, 99))
    return p50, p95, p99


class GraphQueryServer:
    """Serves online graph queries while mutations stream into the shards.

    ``view_keep`` / ``rank_keep`` bound the stitched-view, published-view
    and PageRank caches (ladder retention); ``gc_every`` runs that GC
    every N sealed epochs so a long-lived server tracks the frontier
    instead of pinning every epoch it ever served. ``prewarm_pagerank``
    computes ranks eagerly after every :meth:`step` (warm-started from the
    previous epoch, outside the write lock so queries are never stalled
    behind it), keeping the warm chain unbroken even when PageRank queries
    are sparse.

    ``max_pending`` bounds the typed request queue — the admission-control
    half of the serving tier: :meth:`submit_request` load-sheds with an
    immediate ``ERR_OVERLOADED`` response instead of queueing without
    bound (the legacy ``submit()`` shim is exempt; in-process cooperative
    callers flush their own windows). ``pipeline_reads=False`` restores
    the pre-split discipline — every window pins its snapshot under the
    write lock and therefore queues behind in-flight applies — and exists
    so the serving benchmark can measure the seal-swap win against the
    real old behavior rather than a strawman.

    The server is also the access-pattern feed for dynamic re-sharding
    (``docs/ARCHITECTURE.md``): every answered window's touch vertices are
    buffered on the read plane, and :meth:`step` — the write plane's
    entry, where the store is guaranteed quiescent — drains them into the
    graph's ``AccessStats`` ledger and (when the graph was constructed
    with a ``ShardPlanner`` and ``auto_reshard`` is left on) runs the
    planner tick, so a fired split's migration applies inside the incoming
    batch's seal. Splits are appended to :attr:`reshard_events` as they
    fire; after a cutover the GC pass drops cache entries keyed by the
    retired routing plan (``plan_floor``) instead of aging them through
    the ladder.

    Thread-safety: ``_ingest_lock`` (re-entrant) serializes every touch of
    mutable graph/engine state (ingest, seal, re-shard, cache GC);
    ``_serve_lock`` guards only the read plane (pending queue, published
    snapshot pointer, serving counters). Query execution runs on published
    immutable views outside both locks, so ingestion never waits on query
    compute and queries never wait on applies.
    """

    def __init__(self, graph: ShardedDynamicGraph, *,
                 view_keep: int = 8, rank_keep: int = 4, gc_every: int = 1,
                 prewarm_pagerank: bool = False, auto_reshard: bool = True,
                 max_pending: int = 1024, pipeline_reads: bool = True,
                 replicate_hot: Optional[bool] = None, mirror_k: int = 64,
                 mirror_min_heat: float = 1.0,
                 two_lane: bool = True, expensive_budget: int = 16,
                 result_cache: bool = True,
                 result_cache_entries: int = 4096,
                 prewarm_traces: Optional[bool] = None,
                 max_touch_buffer: int = 65536,
                 **pagerank_kw):
        self.graph = graph
        self.engine = SnapshotQueryEngine(
            result_cache=result_cache,
            result_cache_entries=result_cache_entries, **pagerank_kw)
        self.view_keep = view_keep
        self.rank_keep = rank_keep
        self.gc_every = max(1, gc_every)
        self.prewarm_pagerank = prewarm_pagerank
        self.auto_reshard = auto_reshard
        self.max_pending = max_pending
        self.pipeline_reads = pipeline_reads
        # fast path knobs: two_lane splits the window queue by cost class
        # (the RPC tier runs one dispatcher per lane); expensive_budget
        # caps how many expensive entries one lane drain executes so a
        # PageRank convoy yields the engine back to the cheap lane.
        # prewarm_traces (default: on whenever reads are pipelined) warms
        # jit traces for the new serving snapshot off the publish path.
        self.two_lane = two_lane
        self.expensive_budget = max(1, expensive_budget)
        if prewarm_traces is None:
            prewarm_traces = pipeline_reads
        self.prewarm_traces = prewarm_traces
        self.max_touch_buffer = max_touch_buffer
        # replica plane: mirror the hottest vertices' adjacency at every
        # publish and route frontier queries replica-first. Defaults on
        # when the prerequisites hold — plan-based routing (the locality
        # index needs per-shard views keyed by the plan) and pipelined
        # reads (mirrors refresh at the publish boundary)
        if replicate_hot is None:
            replicate_hot = pipeline_reads and graph.plan is not None
        self.replicate_hot = replicate_hot
        self._mirror_planner = MirrorPlanner(mirror_k=mirror_k,
                                             min_heat=mirror_min_heat)
        self.reshard_events: list[dict] = []
        # degraded mode (invariant I11): epochs whose seal failed (they
        # stay pending on the store per I6 and re-seal later), plus a
        # lifetime failure counter — both under the write lock. The
        # read plane stamps responses from _degraded_hint, a lock-free
        # hint like _sealed_hint (at worst one window stamps stale).
        self._seal_backlog: list[int] = []
        self.seal_failures = 0
        self._degraded_hint = False
        # write plane: every touch of mutable graph/engine state
        self._ingest_lock = threading.RLock()
        # read plane: pending lane queues + published snapshot + counters
        self._serve_lock = threading.Lock()
        self._pending_cheap: list[_Entry] = []
        self._pending_expensive: list[_Entry] = []
        # (version, stitched view, replica routing context or None) — one
        # atomic pointer, so a window can never pair a view with another
        # version's mirrors (invariant I10)
        self._serving: Optional[
            tuple[Version, JoinView, Optional[RoutedSnapshot]]] = None
        # lock-free copy of the newest globally sealed version, refreshed
        # at every seal: the admission path's lane classifier reads it on
        # unpipelined servers so submission never touches the write lock
        # (an in-flight apply would stall the RPC reader otherwise)
        self._sealed_hint: Optional[Version] = None
        self._published: dict[int, JoinView] = {}
        # bounded ring of touch arrays (drop-oldest past max_touch_buffer
        # total ids): a serving-only server with no ingest tick to drain
        # it must not accumulate query touches forever
        self._touch_buffer: collections.deque[np.ndarray] = \
            collections.deque()
        self._touch_buffered = 0
        self._seals = 0
        self.windows = 0
        self.shed_overload = 0
        self.shed_deadline = 0
        # bounded: stats() percentiles are over the most recent window, and
        # a long-lived server does not accumulate per-query floats forever
        self.latencies_s: collections.deque[float] = \
            collections.deque(maxlen=8192)
        self._kind_latencies: dict[str, collections.deque] = {
            k: collections.deque(maxlen=2048) for k in QUERY_KINDS}
        self._lane_latencies: dict[str, collections.deque] = {
            lane: collections.deque(maxlen=4096) for lane in LANES}
        self.served = 0
        self._auto_ids = itertools.count(1)
        # dispatcher wake signals: work_available is the any-lane event
        # (legacy single-dispatcher waiters); work_cheap / work_expensive
        # wake the two-lane RPC dispatchers independently
        self.work_available = threading.Event()
        self.work_cheap = threading.Event()
        self.work_expensive = threading.Event()
        self.ingest_thread: Optional[threading.Thread] = None
        # publish-time trace prewarm: a single persistent daemon worker
        # coalesces to the newest published snapshot (_prewarm_target is a
        # one-slot mailbox under its own lock; the wake event is set by
        # _publish and cleared by the worker before reading the slot)
        self._prewarm_lock = threading.Lock()
        self._prewarm_target: Optional[
            tuple[Version, JoinView, Optional[RoutedSnapshot]]] = None
        self._prewarm_wake = threading.Event()
        self._prewarm_stop = threading.Event()
        self._prewarm_thread: Optional[threading.Thread] = None
        self.prewarm_runs = 0
        graph.on_frontier_advance(self._on_seal)

    # -- ingestion side ----------------------------------------------------
    def _on_seal(self, frontier: int) -> None:
        # fires inside seal_epoch/seal_shard; re-entrant lock covers the
        # case of a caller sealing the graph directly, outside step()
        with self._ingest_lock:
            self._seals += 1
            self._sealed_hint = self.graph.latest_sealed()
            # publish BEFORE the GC pass: the stitch inserts the new
            # version into the view cache, and pruning after keeps the
            # cache at its bound the moment the seal returns (the ladder
            # always retains the newest entry — the serving snapshot)
            if self.pipeline_reads:
                self._publish()
            if self._seals % self.gc_every == 0:
                self.graph.gc_views(self.view_keep)
                self.engine.gc(self.rank_keep,
                               retire_below=self.graph.plan_floor())

    def _publish(self) -> None:
        """Seal-swap: stitch the newest sealed epoch's view on the write
        plane and swap it into the read plane's published pointer. The
        stitch (O(delta), cached per version) is paid once per seal by the
        ingest side so no query ever stitches — or waits for the write
        lock — on its hot path."""
        with self._ingest_lock:
            v = self.graph.latest_sealed()
            if v is None:
                return
            view = self.graph.join_view(v)
            floor = self.graph.plan_floor()
            routed = None
            if self.replicate_hot:
                # mirror refresh rides the publish: nominate from the
                # ledger's vertex heat, rebuild the plan from THIS sealed
                # version's own views — a mirror is exactly as fresh as
                # the snapshot it serves, never staler (invariant I10)
                hot = self._mirror_planner.nominate(
                    self.graph.access_stats.vertex_heat)
                plan = self.graph.build_replica_plan(v, hot)
                routed = RoutedSnapshot(plan, self.graph.shard_views(v))
        with self._serve_lock:
            self._serving = (v, view, routed)
            self._published[v.pack()] = view
            # same ladder retention as the graph-side caches, and retired
            # routing plans drop outright — but never the serving entry
            prune_retired(self._published, floor)
            prune_views(self._published, self.view_keep)
        if self.prewarm_traces:
            # hand the new snapshot to the prewarm worker (coalescing
            # one-slot mailbox: a faster seal cadence overwrites the slot
            # and the worker only ever warms the newest target)
            with self._prewarm_lock:
                self._prewarm_target = (v, view, routed)
            self._prewarm_wake.set()
            self._ensure_prewarm_thread()

    def _ensure_prewarm_thread(self) -> None:
        if self._prewarm_thread is not None or self._prewarm_stop.is_set():
            return
        t = threading.Thread(target=self._prewarm_loop, daemon=True,
                             name="trace-prewarm")
        self._prewarm_thread = t
        t.start()

    def _prewarm_loop(self) -> None:
        """Publish-time trace prewarm worker: replays the engine's
        recorded warm signatures (pow2-bucketed jitted shapes, plus hot
        routed buckets when the snapshot ships a replica plan) against
        each newly published view, so the first query after a seal pays a
        dict lookup instead of a retrace. Best-effort by design — a
        prewarm failure must never take serving down with it."""
        while not self._prewarm_stop.is_set():
            self._prewarm_wake.wait()
            if self._prewarm_stop.is_set():
                return
            self._prewarm_wake.clear()
            with self._prewarm_lock:
                target, self._prewarm_target = self._prewarm_target, None
            if target is None:
                continue
            v, view, routed = target
            try:
                self.engine.warm_traces(view, routed)
            except Exception:
                continue
            with self._prewarm_lock:
                self.prewarm_runs += 1

    def stop_prewarm(self) -> None:
        """Stop the prewarm worker (idempotent; a later publish does NOT
        restart it). The worker is a daemon thread so calling this is
        optional hygiene — RPC ``stop()`` and tests use it for a clean
        teardown."""
        self._prewarm_stop.set()
        self._prewarm_wake.set()
        t = self._prewarm_thread
        if t is not None:
            t.join(timeout=5.0)

    def _drain_touches(self) -> None:
        """Move buffered query touches from the read plane into the
        graph's access ledger — called at step() entry, where the write
        lock is held and the store is quiescent."""
        with self._serve_lock:
            buffered = list(self._touch_buffer)
            self._touch_buffer.clear()
            self._touch_buffered = 0
        with self._ingest_lock:
            for ids in buffered:
                self.graph.record_query_touches(ids)

    def _maybe_prewarm(self) -> None:
        if not self.prewarm_pagerank:
            return
        with self._ingest_lock:
            v = self.graph.latest_sealed()
            if v is None:
                return
            view = self.graph.join_view(v)   # O(delta) stitch under lock
        # the PageRank iteration — the heaviest compute here — runs outside
        # the write lock (the engine's own cache lock suffices), so the
        # query side is never stalled behind a prewarm
        self.engine.pagerank(view)
        # the prewarm inserted the newest view/ranks AFTER the seal-time GC
        # pass; re-prune so the cache bounds hold after every step (the
        # ladder always retains the newest entry, so nothing useful drops)
        with self._ingest_lock:
            self.graph.gc_views(self.view_keep)
            floor = self.graph.plan_floor()
        self.engine.gc(self.rank_keep, retire_below=floor)

    def step(self, batch: MutationBatch) -> None:
        """Ingest one mutation batch and seal its epoch on every shard —
        the cooperative serving loop's ingestion tick. With
        ``prewarm_pagerank`` the epoch's ranks are warmed here, after the
        seal releases the lock.

        This is also where the read plane feeds back into the write plane:
        buffered query touches drain into the access ledger, and with
        ``auto_reshard`` (and a planner on the graph) the planner tick
        runs at step ENTRY — between epochs the store is quiescent, the
        only state a re-sharding cutover may activate from — so a split's
        migration always applies inside THIS batch's seal (the cutover
        epoch is the one about to be ingested), and a stream that simply
        stops can never strand a dispatched migration in a never-sealed
        epoch. Splits are recorded in :attr:`reshard_events`.

        A *failed* seal (an injected shard fault, or a capacity abort) is
        absorbed instead of propagated: the store's seal atomicity (I6)
        leaves the epoch cleanly pending, so the server marks itself
        degraded and keeps answering at the last published sealed
        snapshot — never a partial one (I11). Ingestion continues (the
        store's no-wait dispatch parks slices for the lagging shard), and
        the FIRST successful seal — the next healthy step, or an explicit
        :meth:`reseal` after ``FaultInjector.heal`` — catches up every
        backlogged epoch, because ``seal_epoch`` seals all lagging shards
        through its target. Ingest-side errors (bad version, malformed
        batch) still raise: they are caller bugs, not faults."""
        self._drain_touches()
        with self._ingest_lock:
            if self.auto_reshard:
                event = self.graph.maybe_reshard()
                if event is not None:
                    self.reshard_events.append(event)
            self.graph.ingest(batch)
            try:
                self.graph.seal_epoch(batch.version.epoch)
            except (ShardFaultError, MemoryError, OSError):
                self.seal_failures += 1
                if batch.version.epoch not in self._seal_backlog:
                    self._seal_backlog.append(batch.version.epoch)
                self._degraded_hint = True
                return
            if self._seal_backlog:
                # this seal closed every epoch <= batch's — including the
                # whole backlog (the frontier is the min local frontier)
                self._seal_backlog.clear()
                self._degraded_hint = False
        self._maybe_prewarm()

    def reseal(self) -> int:
        """Retry every pending seal (after ``FaultInjector.heal`` or
        operator intervention) without waiting for the next ingest tick.
        Returns the new global frontier. Raises — and stays degraded — if
        the fault persists; a no-op on a healthy server."""
        with self._ingest_lock:
            target = max([*self._seal_backlog,
                          *(n.local_frontier for n in self.graph.nodes)],
                         default=-1)
            if target < 0:
                return self.graph.coordinator.global_frontier
            frontier = self.graph.seal_epoch(target)
            self._seal_backlog.clear()
            self._degraded_hint = False
            return frontier

    def start_background_ingest(self, stream: Iterable[MutationBatch], *,
                                delay_s: float = 0.0) -> threading.Thread:
        """Drive :meth:`step` over ``stream`` on a daemon thread — queries
        keep flowing on the caller's thread while epochs seal behind the
        write lock. Returns the (started) thread; join it to wait for the
        stream to drain."""

        def pump():
            for batch in stream:
                self.step(batch)
                if delay_s:
                    time.sleep(delay_s)

        t = threading.Thread(target=pump, daemon=True,
                             name="graph-ingest")
        self.ingest_thread = t
        t.start()
        return t

    # -- query side (typed scheduler) --------------------------------------
    def latest_version(self) -> Optional[Version]:
        """Newest *published* sealed version (read plane, never blocks on
        ingest); falls back to the store when reads are unpipelined."""
        if self.pipeline_reads:
            with self._serve_lock:
                if self._serving is not None:
                    return self._serving[0]
            return None
        with self._ingest_lock:
            return self.graph.latest_sealed()

    def _classify(self, request: QueryRequest) -> str:
        """Lane classification at submission time. Cheap kinds (one
        bounded jitted sweep) always ride the cheap lane; an expensive
        kind whose answer is already memoized at its target version is a
        dict lookup and rides the cheap lane too. The cache probe is a
        heuristic snapshot — at worst a stale probe puts one expensive
        execution on the cheap lane, which costs latency, never
        correctness. Runs on RPC reader threads, so it must never block
        on the write plane: pipelined servers read the published serving
        pointer (serve lock only), unpipelined ones the lock-free
        seal-time hint."""
        if not self.two_lane:
            return "cheap"
        kind = query_kind(request.query)
        if kind is None or kind in CHEAP_KINDS:
            return "cheap"
        target = request.pin_version
        if target is None:
            target = (self.latest_version() if self.pipeline_reads
                      else self._sealed_hint)
        if target is not None and self.engine.has_cached_result(
                target, request.query):
            return "cheap"
        return "expensive"

    def submit_request(self, request: QueryRequest,
                       on_done: Optional[Callable[[QueryResponse], None]]
                       = None) -> Optional[QueryResponse]:
        """Admission-controlled enqueue of one typed request.

        Returns None when the request was accepted (it will be answered by
        a subsequent window — via ``on_done`` if given, and/or in the
        return of the :meth:`run_window` call that executes it). Returns
        an immediate typed *response* — never raises — when the request
        cannot be queued: ``ERR_BAD_QUERY`` for an unknown query kind,
        ``ERR_OVERLOADED`` when the pending queues are at ``max_pending``
        (load shed; the caller sees it instantly instead of a timeout).

        The request is classified into its scheduler lane here (queues
        are physically separate); ``max_pending`` bounds the two lanes
        together so admission control is unchanged by the split.
        """
        if query_kind(request.query) is None:
            return QueryResponse.failed(
                request.request_id, ERR_BAD_QUERY,
                f"unknown query type {type(request.query).__name__}")
        lane = self._classify(request)
        now = time.perf_counter()
        deadline_at = (now + request.deadline_s
                       if request.deadline_s is not None else None)
        with self._serve_lock:
            if (len(self._pending_cheap) + len(self._pending_expensive)
                    >= self.max_pending):
                self.shed_overload += 1
                return QueryResponse.failed(
                    request.request_id, ERR_OVERLOADED,
                    f"pending queue at max_pending={self.max_pending}")
            queue = (self._pending_cheap if lane == "cheap"
                     else self._pending_expensive)
            queue.append(_Entry(request, now, deadline_at, on_done, lane))
        self.work_available.set()
        (self.work_cheap if lane == "cheap" else self.work_expensive).set()
        return None

    def run_window(self, lane: Optional[str] = None
                   ) -> list[tuple[QueryRequest, QueryResponse]]:
        """Drain pending work and answer it as ONE window — the single
        code path that owns execution and cache accounting for every
        submission surface (legacy ``submit``/``flush``, point
        :meth:`query`, and the RPC tier's dispatchers all land here, so
        same-kind queries collapse across clients into one jitted call).

        ``lane=None`` (every in-process caller) drains BOTH lanes fully,
        merged back into submission order — identical semantics to the
        single-queue server. ``lane="cheap"`` drains only the cheap lane.
        ``lane="expensive"`` drains at most ``expensive_budget`` entries
        (plus any queued entry whose deadline already expired — those are
        shed as ``ERR_DEADLINE`` *now* instead of waiting out the convoy)
        and leaves the rest queued with ``work_expensive`` re-armed, so a
        PageRank flood yields the engine back to the cheap dispatcher
        between windows.

        Expired-deadline requests are answered with ``ERR_DEADLINE``
        without executing. Unpinned requests execute at the published
        serving snapshot; pinned requests at their pinned sealed version
        (published fast path, else a write-locked stitch; an unsealed pin
        is an ``ERR_BAD_PIN`` response). Completion callbacks run after
        the window, outside every lock; answered touch vertices are
        buffered (bounded, drop-oldest) for the next ingest tick.

        Legacy-compatible failure semantics: if nothing is globally
        sealed yet, the undeliverable entries are re-queued AHEAD of
        later submissions (each on its own lane) and ``RuntimeError``
        raises; if the engine fails mid-window, every live entry is
        re-queued un-answered and the error propagates — a window is
        delivered all-or-nothing.

        Returns ``(request, response)`` pairs in submission order.
        """
        now = time.perf_counter()
        leftovers = False
        with self._serve_lock:
            if lane is None:
                pending = sorted(
                    self._pending_cheap + self._pending_expensive,
                    key=lambda e: e.enqueued_at)
                self._pending_cheap = []
                self._pending_expensive = []
            elif lane == "cheap":
                pending = self._pending_cheap
                self._pending_cheap = []
            elif lane == "expensive":
                take: list[_Entry] = []
                rest: list[_Entry] = []
                for e in self._pending_expensive:
                    if len(take) < self.expensive_budget or (
                            e.deadline_at is not None
                            and now > e.deadline_at):
                        take.append(e)
                    else:
                        rest.append(e)
                pending = take
                self._pending_expensive = rest
                leftovers = bool(rest)
            else:
                raise ValueError(f"unknown lane {lane!r}")
            serving = self._serving
        if leftovers:
            # over-budget work stays queued; re-arm the dispatcher so the
            # next expensive window starts as soon as this one finishes
            self.work_expensive.set()
        if not pending:
            return []
        expired: list[tuple[_Entry, QueryResponse]] = []
        live: list[_Entry] = []
        for e in pending:
            if e.deadline_at is not None and now > e.deadline_at:
                expired.append((e, QueryResponse.failed(
                    e.request.request_id, ERR_DEADLINE,
                    f"deadline_s={e.request.deadline_s} expired in queue",
                    latency_s=now - e.enqueued_at)))
            else:
                live.append(e)
        if not self.pipeline_reads:
            # the pre-split discipline (benchmark baseline): pin the
            # snapshot under the write lock — behind in-flight applies
            with self._ingest_lock:
                v = self.graph.latest_sealed()
                serving = ((v, self.graph.join_view(v), None)
                           if v is not None else None)
        if serving is None and any(e.request.pin_version is None
                                   for e in live):
            # nothing answerable yet: re-queue AHEAD of anything submitted
            # since the swap so window order is preserved (nothing was
            # answered), deliver only the already-expired budgets
            with self._serve_lock:
                self._pending_cheap[:0] = [
                    e for e in live if e.lane == "cheap"]
                self._pending_expensive[:0] = [
                    e for e in live if e.lane != "cheap"]
                self.shed_deadline += len(expired)
            self._deliver(expired)
            raise RuntimeError(
                "no globally sealed snapshot yet — seal an epoch on "
                "every shard before querying")
        # group by effective snapshot so one engine call per (version,
        # kind) answers every client's same-kind queries together
        failed_pins: list[tuple[_Entry, QueryResponse]] = []
        groups: dict[int, list[_Entry]] = {}
        views: dict[int, tuple[Version, JoinView]] = {}
        routed = serving[2] if serving is not None else None
        for e in live:
            pin = e.request.pin_version
            if pin is None:
                v, view = serving[0], serving[1]
            else:
                v = pin
                packed = pin.pack()
                if packed not in views:
                    with self._serve_lock:
                        pinned = self._published.get(packed)
                    if pinned is None:
                        try:
                            with self._ingest_lock:
                                pinned = self.graph.join_view(pin)
                        except ValueError as exc:
                            failed_pins.append((e, QueryResponse.failed(
                                e.request.request_id, ERR_BAD_PIN,
                                str(exc))))
                            continue
                    views[packed] = (pin, pinned)
                view = views[packed][1]
            views.setdefault(v.pack(), (v, view))
            groups.setdefault(v.pack(), []).append(e)
        answered: dict[int, QueryResponse] = {}
        try:
            for packed in sorted(groups):
                v, view = views[packed]
                entries = groups[packed]
                # replica-first routing only for the serving snapshot the
                # mirrors were built for (the engine re-checks versions,
                # so a stale pairing degrades to the global view)
                values = self.engine.execute(
                    view, [e.request.query for e in entries],
                    routed=routed)
                done = time.perf_counter()
                for e, val in zip(entries, values, strict=True):
                    answered[id(e)] = QueryResponse.answered(
                        e.request.request_id, val, v, done - e.enqueued_at,
                        degraded=self._degraded_hint)
        except BaseException:
            # all-or-nothing: nothing from this window was delivered yet,
            # so re-queue every live entry (original order, each on its
            # own lane) for a retry and let the error surface — a failing
            # window is never silently discarded, and never
            # double-answered
            with self._serve_lock:
                self._pending_cheap[:0] = [
                    e for e in live if e.lane == "cheap"]
                self._pending_expensive[:0] = [
                    e for e in live if e.lane != "cheap"]
            raise
        ok_entries = [e for e in live if id(e) in answered]
        with self._serve_lock:
            self.windows += 1
            self.served += len(ok_entries)
            self.shed_deadline += len(expired)
            for e in ok_entries:
                lat = answered[id(e)].latency_s
                self.latencies_s.append(lat)
                self._kind_latencies[query_kind(e.request.query)].append(lat)
                self._lane_latencies[e.lane].append(lat)
            # access-pattern feed, buffered for the next ingest tick —
            # only AFTER the window succeeded, so a failing window
            # re-queued above cannot double-count touches on every retry.
            # Bounded drop-oldest: a serving-only server (no ingest tick
            # draining the buffer) must not grow it without bound
            touched = query_touch_vertices(
                [e.request.query for e in ok_entries])
            if touched.size:
                self._touch_buffer.append(touched)
                self._touch_buffered += int(touched.size)
                while (self._touch_buffered > self.max_touch_buffer
                       and len(self._touch_buffer) > 1):
                    dropped = self._touch_buffer.popleft()
                    self._touch_buffered -= int(dropped.size)
        pairs = []
        for e in pending:
            resp = answered.get(id(e))
            if resp is None:
                resp = next((r for x, r in expired + failed_pins
                             if x is e), None)
            if resp is not None:
                pairs.append((e, resp))
        self._deliver(pairs)
        return [(e.request, r) for e, r in pairs]

    @staticmethod
    def _deliver(pairs: Sequence[tuple[_Entry, QueryResponse]]) -> None:
        # completion callbacks run outside every lock: an RPC on_done
        # blocks on its connection's socket, never on the server
        for e, resp in pairs:
            if e.on_done is not None:
                e.on_done(resp)

    def query(self, q: Query) -> QueryResult:
        """Answer a single query through the SAME shared scheduler as
        every other path (it used to bypass window accounting): the
        request joins the pending window, :meth:`run_window` answers the
        whole window — collapsing it with any concurrently submitted
        same-kind queries — and this query's own response is returned.
        """
        done = threading.Event()
        box: dict[str, QueryResponse] = {}

        def on_done(resp: QueryResponse) -> None:
            box["resp"] = resp
            done.set()

        request = QueryRequest(query=q, request_id=next(self._auto_ids))
        shed = self.submit_request(request, on_done=on_done)
        if shed is not None:
            raise RuntimeError(f"query rejected: {shed.error.code} "
                               f"({shed.error.message})")
        while not done.is_set():
            self.run_window()
            if not done.is_set():
                # a concurrent window claimed the entry and is executing
                done.wait(0.002)
        resp = box["resp"]
        if not resp.ok:
            raise RuntimeError(
                f"query failed: {resp.error.code} ({resp.error.message})")
        return QueryResult(q, resp.value, resp.version, resp.latency_s)

    # -- deprecated shims ---------------------------------------------------
    def submit(self, query: Query) -> None:
        """DEPRECATED shim over :meth:`submit_request` (kept so existing
        examples/tests run unchanged; new code should submit typed
        :class:`~repro.graph.query.QueryRequest` envelopes). Enqueues a
        bare query into the current window with no admission control, no
        deadline and no callback — answered at the next window run.
        Thread-safe: submitters may race each other and the flusher."""
        request = QueryRequest(query=query,
                               request_id=next(self._auto_ids))
        lane = self._classify(request)
        with self._serve_lock:
            queue = (self._pending_cheap if lane == "cheap"
                     else self._pending_expensive)
            queue.append(_Entry(request, time.perf_counter(), lane=lane))
        self.work_available.set()
        (self.work_cheap if lane == "cheap" else self.work_expensive).set()

    def flush(self) -> list[QueryResult]:
        """DEPRECATED shim over :meth:`run_window`: answer every pending
        query against the newest frontier-sealed snapshot and return the
        successful answers as legacy :class:`QueryResult`\\ s (error
        responses — expired deadlines, bad pins — are delivered through
        their callbacks but not returned here). Raises if nothing is
        globally sealed yet."""
        return [QueryResult(req.query, resp.value, resp.version,
                            resp.latency_s)
                for req, resp in self.run_window() if resp.ok]

    # -- telemetry ---------------------------------------------------------
    def stats(self) -> ServerStats:
        """Serving snapshot as a frozen :class:`ServerStats`: latency
        quantiles (overall and per kind) over the recent window, queue
        depth and shed counters, cache sizes, vectorized-call and PageRank
        warm-start counters, plus re-sharding state. Thread-safe; the two
        planes are sampled one after the other, each under its own lock —
        consistent within a plane, not across them."""
        with self._ingest_lock:
            reshard_events = tuple(self.reshard_events)
            frontier = self.graph.coordinator.global_frontier
            cached_views = len(self.graph._views)
            n_shards = self.graph.n_shards
            plan = self.graph.plan
            split_events = sum(1 for m in self.graph.migrations
                               if m.get("kind", "split") == "split")
            merge_events = sum(1 for m in self.graph.migrations
                               if m.get("kind") == "merge")
            degraded = bool(self._seal_backlog)
            seal_failures = self.seal_failures
            last_ingested = (Version.unpack(self.graph._last_version).epoch
                             if self.graph._last_version >= 0 else -1)
            stale_epochs = max(0, last_ingested - frontier)
        replica = self.engine.replica_stats()
        hist = replica["fanout_hist"]
        total_routed = sum(hist.values())
        mean_fanout = (sum(k * c for k, c in hist.items()) / total_routed
                       if total_routed else -1.0)
        rcache = self.engine.result_cache_stats()
        with self._prewarm_lock:
            prewarm_runs = self.prewarm_runs
        with self._serve_lock:
            lat = np.asarray(self.latencies_s)
            p50, p95, p99 = _quantiles(lat)
            per_kind = {}
            for kind, dq in self._kind_latencies.items():
                if dq:
                    kp50, kp95, kp99 = _quantiles(np.asarray(dq))
                    per_kind[kind] = {"p50": kp50, "p95": kp95, "p99": kp99}
            per_lane = {}
            for lane, dq in self._lane_latencies.items():
                if dq:
                    lp50, lp95, lp99 = _quantiles(np.asarray(dq))
                    per_lane[lane] = {"p50": lp50, "p95": lp95, "p99": lp99}
            lane_depth = {"cheap": len(self._pending_cheap),
                          "expensive": len(self._pending_expensive)}
            serving = self._serving
            stats = ServerStats(
                served=self.served,
                windows=self.windows,
                queue_depth=(len(self._pending_cheap)
                             + len(self._pending_expensive)),
                shed_overload=self.shed_overload,
                shed_deadline=self.shed_deadline,
                serving_version=serving[0] if serving else None,
                global_frontier=frontier,
                n_shards=n_shards,
                routing_plan_id=plan.plan_id if plan is not None else None,
                reshard_events=reshard_events,
                query_p50_s=p50, query_p95_s=p95, query_p99_s=p99,
                per_kind_latency_s=per_kind,
                published_views=len(self._published),
                cached_stitched_views=cached_views,
                cached_rank_versions=len(self.engine.cached_rank_versions),
                vectorized_calls=dict(self.engine.vectorized_calls),
                rank_cache_hits=self.engine.rank_cache_hits,
                rank_warm_starts=self.engine.rank_warm_starts,
                rank_cold_starts=self.engine.rank_cold_starts,
                mirror_hits=replica["mirror_hits"],
                mirror_misses=replica["mirror_misses"],
                mirror_hit_rate=replica["mirror_hit_rate"],
                routed_windows=replica["routed_windows"],
                fanout_hist={str(k): c for k, c in sorted(hist.items())},
                mean_fanout=mean_fanout,
                mirrored_vertices=(serving[2].plan.n_mirrored
                                   if serving and serving[2] else 0),
                split_events=split_events,
                merge_events=merge_events,
                queue_depth_by_lane=lane_depth,
                per_lane_latency_s=per_lane,
                result_cache_hits=rcache["hits"],
                result_cache_misses=rcache["misses"],
                result_cache_hit_rate=rcache["hit_rate"],
                result_cache_entries=rcache["entries"],
                result_cache_evictions=rcache["evictions"],
                prewarm_runs=prewarm_runs,
                degraded=degraded,
                stale_epochs=stale_epochs,
                seal_failures=seal_failures)
        return stats


def _demo_queries(rng: np.random.Generator, n: int,
                  count: int) -> Sequence[Query]:
    qs: list[Query] = []
    for _ in range(count):
        roll = rng.random()
        if roll < 0.5:
            qs.append(KHop(int(rng.integers(0, n)), k=2))
        elif roll < 0.8:
            qs.append(Reachability(int(rng.integers(0, n)),
                                   int(rng.integers(0, n)), max_hops=8))
        elif roll < 0.95:
            qs.append(DegreeTopK(8))
        else:
            qs.append(PageRankQuery(top_k=8))
    return qs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=2_000)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--adds-per-epoch", type=int, default=1_000)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--queries-per-epoch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rpc-port", type=int, default=None,
                    help="serve the stream over the socket RPC front on "
                         "this port (0 = ephemeral) instead of the "
                         "in-process demo loop")
    ap.add_argument("--ingest-delay-s", type=float, default=0.05,
                    help="pause between epochs in --rpc-port mode")
    ap.add_argument("--wal-dir", type=str, default=None,
                    help="durability directory (write-ahead log + graph "
                         "checkpoints); survive kill -9 and resume with "
                         "--recover")
    ap.add_argument("--recover", action="store_true",
                    help="recover the store from --wal-dir and resume the "
                         "stream after the durable frontier")
    ap.add_argument("--checkpoint-every", type=int, default=4,
                    help="graph checkpoint cadence in sealed epochs "
                         "(with --wal-dir)")
    args = ap.parse_args()

    batches = synthesize_churn_stream(args.vertices, args.epochs,
                                      args.adds_per_epoch, seed=args.seed,
                                      delete_frac=0.2)
    e_max = sum(len(b.add_src) for b in batches) + 16
    if args.recover:
        if not args.wal_dir:
            ap.error("--recover needs --wal-dir")
        sg = ShardedDynamicGraph.recover(args.wal_dir)
        start = sg.coordinator.global_frontier + 1
        batches = [b for b in batches if b.version.epoch >= start]
        print(f"recovered at durable frontier {start - 1}; resuming "
              f"{len(batches)} remaining epochs", flush=True)
    else:
        sg = ShardedDynamicGraph(args.shards, args.vertices, e_max,
                                 wal_dir=args.wal_dir,
                                 checkpoint_every=args.checkpoint_every)
    server = GraphQueryServer(sg, prewarm_pagerank=args.rpc_port is None,
                              tol=1e-6, max_iter=200)

    if args.rpc_port is not None:
        from repro.launch.rpc import GraphRPCServer
        rpc = GraphRPCServer(server, port=args.rpc_port)
        rpc.start()
        host, port = rpc.address
        # the one line a driving process parses for the ephemeral port
        print(f"RPC listening on {host}:{port}", flush=True)
        thread = server.start_background_ingest(
            iter(batches), delay_s=args.ingest_delay_s)
        thread.join()
        print(f"stream drained after {args.epochs} epochs; serving until "
              "stdin closes", flush=True)
        try:
            import sys
            sys.stdin.read()      # parent closes stdin to stop us
        except KeyboardInterrupt:
            pass
        rpc.stop()
        s = server.stats()
        print(f"served {s.served} queries over RPC "
              f"(shed {s.shed_overload} overload / {s.shed_deadline} "
              f"deadline)")
        return

    rng = np.random.default_rng(args.seed + 1)
    t0 = time.perf_counter()
    for batch in batches:
        server.step(batch)                      # ingestion tick
        for q in _demo_queries(rng, args.vertices,
                               args.queries_per_epoch):
            server.submit(q)
        results = server.flush()                # one vectorized window
        v = results[0].version if results else None
        print(f"epoch {batch.version.epoch}: answered {len(results)} "
              f"queries @ snapshot {v}")
    wall = time.perf_counter() - t0
    s = server.stats()
    print(f"\nserved {s.served} queries over {args.epochs} epochs "
          f"in {wall:.2f}s")
    print(f"  p50={s.query_p50_s*1e3:.2f}ms p95={s.query_p95_s*1e3:.2f}ms "
          f"p99={s.query_p99_s*1e3:.2f}ms")
    print(f"  vectorized calls: {dict(s.vectorized_calls)} "
          f"(vs {s.served} queries)")
    print(f"  pagerank warm starts: {s.rank_warm_starts}, "
          f"cold: {s.rank_cold_starts}, cache hits: {s.rank_cache_hits}")
    print(f"  bounded caches: {s.cached_stitched_views} views, "
          f"{s.published_views} published, "
          f"{s.cached_rank_versions} rank versions")


if __name__ == "__main__":
    main()
