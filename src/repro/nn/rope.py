"""Rotary position embeddings (half-rotation layout, LLaMA-style)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, head_dim); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
