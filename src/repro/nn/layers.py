"""Core layers: norms, MLPs, embeddings. Pure-JAX, params as dicts.

Numerics policy: params live in ``param_dtype`` (f32 by default); matmuls cast
inputs to the activation dtype (bf16) and accumulate in f32 via
``preferred_element_type``; norms/softmax/gating run in f32.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

Init = jax.nn.initializers.normal(stddev=0.02)


@functools.cache
def _cpu_backend() -> bool:
    return jax.default_backend() == "cpu"


def compute_dtype(requested=jnp.bfloat16):
    """bf16 on TPU (and for dry-run lowering, REPRO_FORCE_BF16=1); f32 when
    actually *executing* on the CPU backend (XLA:CPU has no bf16 DotThunk)."""
    if os.environ.get("REPRO_FORCE_BF16") == "1":
        return jnp.dtype(requested)
    if _cpu_backend():
        return jnp.dtype(jnp.float32)
    return jnp.dtype(requested)


def accum_dtype(cfg) -> jnp.dtype:
    """Cross-shard reduction dtype for row-parallel (TP) matmuls. bf16
    halves the TP all-reduce bytes (§Perf knob); forced to f32 when actually
    executing on CPU."""
    req = getattr(cfg, "reduce_dtype", "float32")
    if req == "bfloat16" and compute_dtype(jnp.bfloat16) == jnp.bfloat16:
        return jnp.dtype(jnp.bfloat16)
    return jnp.dtype(jnp.float32)


import contextlib
import contextvars

_BWD_BF16 = contextvars.ContextVar("repro_bwd_bf16", default=False)


@contextlib.contextmanager
def bf16_backward_scope(enabled: bool = True):
    """§Perf knob: while tracing under this scope, dense() uses a custom VJP
    whose activation cotangents are bf16 (weight grads stay f32-accumulated).
    Halves backward activation traffic AND the TP cotangent all-reduces."""
    tok = _BWD_BF16.set(bool(enabled) and
                        compute_dtype(jnp.bfloat16) == jnp.bfloat16)
    try:
        yield
    finally:
        _BWD_BF16.reset(tok)


def _dot2d(a, b, preferred):
    return jax.lax.dot_general(a, b, (((a.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=preferred)


@functools.lru_cache(maxsize=None)
def _dense_bf16bwd(dtype, accum):
    """custom-VJP dense with bf16 activation cotangents; statics are closed
    over (nondiff_argnums don't survive jax.checkpoint)."""
    dtype = jnp.dtype(dtype)
    preferred = jnp.dtype(accum) if accum is not None else jnp.float32

    def fwd_only(x, w):
        xc, wc = x.astype(dtype), w.astype(dtype)
        return _dot2d(xc, wc, preferred).astype(dtype)

    def fwd(x, w):
        xc, wc = x.astype(dtype), w.astype(dtype)
        y = _dot2d(xc, wc, preferred).astype(dtype)
        # zero-size dtype carriers (residuals must be JAX types)
        return y, (xc, wc, jnp.zeros((0,), x.dtype), jnp.zeros((0,), w.dtype))

    def bwd(res, g):
        xc, wc, x_tag, w_tag = res
        x_dt, w_dt = x_tag.dtype, w_tag.dtype
        gc = g.astype(dtype)
        # dx in bf16 (cotangents tolerate it; TP all-reduce halves)
        dx = jax.lax.dot_general(gc, wc, (((gc.ndim - 1,), (1,)), ((), ())),
                                 preferred_element_type=dtype)
        # dw accumulated in f32 (optimizer-quality gradients)
        lead = tuple(range(gc.ndim - 1))
        dw = jax.lax.dot_general(xc, gc, ((lead, lead), ((), ())),
                                 preferred_element_type=jnp.float32)
        return dx.astype(x_dt), dw.astype(w_dt)

    f = jax.custom_vjp(fwd_only)
    f.defvjp(fwd, bwd)
    return f


def dense(x, w, b=None, *, dtype=jnp.bfloat16, accum=None):
    """x @ w with bf16 inputs, f32 accumulation (``accum`` overrides the
    partial-sum dtype for TP row-parallel projections)."""
    dtype = compute_dtype(dtype)
    if _BWD_BF16.get():
        y = _dense_bf16bwd(str(dtype), str(accum) if accum else None)(x, w)
    else:
        y = _dot2d(x.astype(dtype), w.astype(dtype),
                   accum or jnp.float32).astype(dtype)
    if b is not None:
        y = (y.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)
    return y


def rms_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def init_norm(key, d, kind):
    if kind == "rms":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(p, x, kind):
    if kind == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_mlp(key, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ffn in ("swiglu", "geglu"):
        return {
            "w1": Init(ks[0], (d, ff), cfg.param_dtype),
            "w3": Init(ks[1], (d, ff), cfg.param_dtype),
            "w2": Init(ks[2], (ff, d), cfg.param_dtype),
        }
    p = {
        "w1": Init(ks[0], (d, ff), cfg.param_dtype),
        "w2": Init(ks[1], (ff, d), cfg.param_dtype),
    }
    if cfg.mlp_bias:
        p["b1"] = jnp.zeros((ff,), cfg.param_dtype)
        p["b2"] = jnp.zeros((d,), cfg.param_dtype)
    return p


def mlp(p, x, cfg):
    act = jax.nn.silu if cfg.ffn == "swiglu" else (
        lambda v: jax.nn.gelu(v, approximate=True))
    if cfg.ffn in ("swiglu", "geglu"):
        h = act(dense(x, p["w1"])) * dense(x, p["w3"])
        return dense(h, p["w2"], accum=accum_dtype(cfg))
    h = act(dense(x, p["w1"], p.get("b1")))
    return dense(h, p["w2"], p.get("b2"), accum=accum_dtype(cfg))


def sinusoidal_positions(seq_len, d_model, offset=0):
    pos = np.arange(seq_len)[:, None] + offset
    dim = np.arange(0, d_model, 2)[None, :]
    angle = pos / np.power(10_000.0, dim / d_model)
    out = np.zeros((seq_len, d_model), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return jnp.asarray(out)


def sinusoidal_positions_dynamic(positions, d_model):
    """Traced-position variant for decode. positions: (S,) int."""
    pos = positions.astype(jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d_model)
    sin, cos = jnp.sin(angle), jnp.cos(angle)
    return jnp.stack([sin, cos], axis=-1).reshape(positions.shape[0], d_model)
