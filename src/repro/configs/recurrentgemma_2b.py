"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427]: 26L, d_model=2560,
10 heads MQA kv=1 head_dim=256, d_ff=7680 (geglu), vocab 256000,
pattern (RG-LRU, RG-LRU, local-attn window 2048). Hybrid => runs long_500k."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=("rglru", "rglru", "local"),
    ffn="geglu",
    norm="rms",
    rope=True,
    rope_theta=10_000.0,
    local_window=2048,
    lru_width=2560,
    conv_width=4,
    scale_embeddings=True,
    subquadratic=True,
))
