"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Terms (seconds, per step, per device — the compiled module IS the per-device
program, so dividing per-device quantities by per-chip peaks equals the
spec's total/(chips x peak)):

    compute    = flops_dev / PEAK_FLOPS
    memory     = hbm_bytes_dev / HBM_BW
    collective = collective_link_bytes_dev / ICI_BW

flops / bytes / collective bytes come from ``analysis.hlo`` (the while-loop-
corrected static analyzer — XLA's cost_analysis undercounts scanned programs
by the trip count). MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D
(prefill/decode) counts *useful* work; its ratio to HLO flops exposes remat
and MoE dense-dispatch waste.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI per chip.
"""
from __future__ import annotations

import json
import pathlib

from repro.analysis.hlo import analyze
from repro.configs import SHAPES, all_configs

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 1024**3  # v5e


def model_flops_per_device(cfg, cell, devices: int) -> float:
    n_active = cfg.active_param_count()
    if cfg.embed_mode == "tokens":
        n_active -= cfg.vocab_size * cfg.d_model   # input embed is a gather
    if cell.kind == "train":
        tokens = cell.seq_len * cell.global_batch
        return 6.0 * n_active * tokens / devices
    if cell.kind == "prefill":
        tokens = cell.seq_len * cell.global_batch
        return 2.0 * n_active * tokens / devices
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch / devices


def _advice(dominant, cfg, cell, ratio):
    if dominant == "compute":
        if cfg.ffn == "moe" and cfg.moe_impl == "dense":
            return ("switch MoE to capacity-bounded dispatch "
                    f"(dense mode computes all {cfg.n_experts} experts; "
                    f"useful ratio {ratio:.2f})")
        if cell.kind == "train":
            return ("relax remat policy (full -> dots_saveable) to cut "
                    "recompute flops")
        return "fuse attention (Pallas flash kernel) to cut masked-chunk flops"
    if dominant == "memory":
        if cell.kind == "decode":
            return ("KV-cache reads dominate: shard cache over more axes / "
                    "quantize cache to int8")
        return ("reduce activation traffic: larger fusion blocks, bf16 "
                "master-weight option, chunked loss already on")
    return ("re-shard per replica-coherence policy: move the dominant "
            "all-gather's tensor to replicated or overlap it with compute")


def roofline_row(result: dict, hlo_stats: dict) -> dict:
    cfg = all_configs()[result["arch"]]
    cell = SHAPES[result["shape"]]
    dev = result["devices"]
    flops = hlo_stats["flops"]
    hbm = hlo_stats["hbm_bytes"]
    coll = hlo_stats["collective_link_bytes"]
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(cfg, cell, dev)
    ratio = mf / max(flops, 1.0)
    # fraction of roofline: time the useful flops need at peak vs the time
    # the dominant term actually costs
    step_time = max(terms.values())
    roofline_frac = (mf / PEAK_FLOPS) / max(step_time, 1e-30)
    return {
        "arch": result["arch"], "shape": result["shape"],
        "mesh": result["mesh"], "devices": dev,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant,
        "model_flops_dev": mf, "hlo_flops_dev": flops,
        "useful_ratio": ratio,
        "roofline_fraction": roofline_frac,
        "hbm_fit": (result.get("memory", {}).get("temp_bytes") or 0)
        + (result.get("memory", {}).get("argument_bytes") or 0),
        "advice": _advice(dominant, cfg, cell, ratio),
        "collectives": hlo_stats["collectives"],
    }


def analyze_cell(results_dir: pathlib.Path, arch: str, shape: str,
                 mesh: str = "single") -> dict | None:
    jf = results_dir / f"{arch}__{shape}__{mesh}.json"
    hf = results_dir / f"{arch}__{shape}__{mesh}.hlo.txt"
    if not jf.exists():
        return None
    result = json.loads(jf.read_text())
    if "skipped" in result:
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "skipped": result["skipped"]}
    if not hf.exists():
        return None
    stats = analyze(hf.read_text(), default_group=16)
    return roofline_row(result, stats)


def full_table(results_dir, mesh="single") -> list[dict]:
    rows = []
    for arch in sorted(all_configs()):
        for shape in SHAPES:
            row = analyze_cell(pathlib.Path(results_dir), arch, shape, mesh)
            if row is not None:
                rows.append(row)
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP "
                         f"| — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} |")
    return hdr + "\n".join(lines)
