"""TS0xx — jit trace stability.

Every ``jax.jit``-decorated function in ``graph/``, ``kernels/``,
``launch/`` is analyzed with a simple forward taint pass: parameters not
named in ``static_argnames`` (or positioned in ``static_argnums``) are
*traced*; taint propagates through arithmetic, calls, subscripts and
assignments, and is *broken* by the things that are static at trace time
— ``.shape`` / ``.ndim`` / ``.dtype`` / ``.size`` access, ``len()``, and
``is None`` comparisons. On that lattice:

* TS001: Python ``if`` / ``while`` / ``assert`` / conditional expression
  on a traced value — a concretization error at trace time, or worse, a
  silent per-value retrace.
* TS002: ``int()`` / ``float()`` / ``bool()`` / ``.item()`` /
  ``.tolist()`` / ``np.asarray`` on a traced value (``jnp.asarray`` is
  fine — it stays in the traced world).
* TS003: Python ``for`` over a traced value (unrolls or fails; loop
  bounds must come from shapes or statics).
* TS004: a padding-width assignment (``width`` / ``*_width``) whose
  right-hand side is not provably pow2-shaped — no ``pad_pow2`` /
  ``next_pow2`` call, power-of-two literal, or shift. PR 3's padding
  discipline keeps trace-cache keys pow2-quantized; an ad-hoc width
  reintroduces per-size retraces. Checked in every function, jitted or
  not, since widths are usually computed in the un-jitted wrapper.

Nested ``def``s inside a jitted function (``fori_loop`` bodies,
``while_loop`` conds) are analyzed too, their parameters traced — those
are exactly the loop carries.

The pass is sequential and intra-function: both branches of an ``if``
are walked in order with accumulated taint (union, no joins), and
comprehensions are treated as opaque/untainted. That imprecision is
deliberate — the rule set targets the handful of shapes that actually
break tracing, with suppressions for anything exotic.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.staticcheck.core import (FileContext, Finding,
                                             register_checker, register_rule)

TS001 = register_rule(
    "TS001", "Python control flow on a traced value inside jit")
TS002 = register_rule(
    "TS002", "concretization of a traced value inside jit")
TS003 = register_rule(
    "TS003", "Python iteration over a traced value inside jit")
TS004 = register_rule(
    "TS004", "padding width not provably pow2 (trace-key discipline)")

SCOPE = ("graph", "kernels", "launch")

# attribute reads that yield static (trace-time) values
_STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size"})
_CONCRETIZERS = frozenset({"int", "float", "bool", "complex"})
_CONCRETIZE_METHODS = frozenset({"item", "tolist"})
_NUMPY_NAMES = frozenset({"np", "numpy"})
_POW2_FNS = frozenset({"pad_pow2", "next_pow2"})


# -------------------------------------------------- jit decorator parsing
def _dotted(node: ast.AST) -> str:
    """'jax.jit' for Attribute chains, 'jit' for Names, '' otherwise."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _is_jit_ref(node: ast.AST) -> bool:
    return _dotted(node) in {"jit", "jax.jit"}


def _static_names(fn: ast.FunctionDef) -> Optional[frozenset[str]]:
    """Static parameter names when ``fn`` is jit-decorated, else None."""
    a = fn.args
    positional = [arg.arg for arg in a.posonlyargs + a.args]
    for deco in fn.decorator_list:
        if _is_jit_ref(deco):
            return frozenset()
        if not isinstance(deco, ast.Call):
            continue
        # @jax.jit(...) or @functools.partial(jax.jit, ...)
        is_jit_call = _is_jit_ref(deco.func)
        is_partial = (_dotted(deco.func) in {"partial", "functools.partial"}
                      and deco.args and _is_jit_ref(deco.args[0]))
        if not (is_jit_call or is_partial):
            continue
        static: set[str] = set()
        for kw in deco.keywords:
            if kw.arg == "static_argnames":
                v = kw.value
                elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
                static |= {e.value for e in elts
                           if isinstance(e, ast.Constant)
                           and isinstance(e.value, str)}
            elif kw.arg == "static_argnums":
                v = kw.value
                elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
                for e in elts:
                    if (isinstance(e, ast.Constant)
                            and isinstance(e.value, int)
                            and e.value < len(positional)):
                        static.add(positional[e.value])
        return frozenset(static)
    return None


def _param_names(fn) -> list[str]:
    a = fn.args
    names = [arg.arg for arg in a.posonlyargs + a.args + a.kwonlyargs]
    return [n for n in names if n not in ("self", "cls")]


# ------------------------------------------------------------ taint engine
class _TaintScan:
    def __init__(self, ctx: FileContext, findings: list[Finding]):
        self.ctx = ctx
        self.findings = findings

    # -- expression taint ---------------------------------------------------
    def tainted(self, node: ast.AST, t: set[str]) -> bool:
        if isinstance(node, ast.Name):
            return node.id in t
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.tainted(node.value, t)
        if isinstance(node, ast.Subscript):
            return self.tainted(node.value, t) or self.tainted(node.slice, t)
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id == "len":
                return False
            if isinstance(fn, ast.Name) and fn.id in _CONCRETIZERS:
                return False     # concrete result; the call site is TS002
            if (isinstance(fn, ast.Attribute)
                    and fn.attr in _CONCRETIZE_METHODS):
                return False
            parts = ([self.tainted(a, t) for a in node.args]
                     + [self.tainted(kw.value, t) for kw in node.keywords])
            if isinstance(fn, ast.Attribute):
                parts.append(self.tainted(fn.value, t))
            return any(parts)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return (self.tainted(node.left, t)
                    or any(self.tainted(c, t) for c in node.comparators))
        if isinstance(node, ast.BoolOp):
            return any(self.tainted(v, t) for v in node.values)
        if isinstance(node, ast.BinOp):
            return self.tainted(node.left, t) or self.tainted(node.right, t)
        if isinstance(node, ast.UnaryOp):
            return self.tainted(node.operand, t)
        if isinstance(node, ast.IfExp):
            return (self.tainted(node.body, t)
                    or self.tainted(node.orelse, t))
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.tainted(e, t) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.tainted(node.value, t)
        if isinstance(node, ast.Slice):
            return any(self.tainted(s, t)
                       for s in (node.lower, node.upper, node.step) if s)
        if isinstance(node, ast.NamedExpr):
            return self.tainted(node.value, t)
        return False   # constants, comprehensions (opaque), f-strings, ...

    # -- violations inside one expression ----------------------------------
    def scan_expr(self, node: ast.AST, t: set[str]) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, (ast.Lambda, ast.FunctionDef,
                                ast.AsyncFunctionDef)):
                continue   # handled as nested scopes by scan_stmts
            if isinstance(sub, ast.IfExp) and self.tainted(sub.test, t):
                self.findings.append(self.ctx.finding(
                    sub, TS001, "conditional expression on a traced value"))
            if not isinstance(sub, ast.Call):
                continue
            fn = sub.func
            if (isinstance(fn, ast.Name) and fn.id in _CONCRETIZERS
                    and any(self.tainted(a, t) for a in sub.args)):
                self.findings.append(self.ctx.finding(
                    sub, TS002,
                    f"'{fn.id}()' concretizes a traced value"))
            elif (isinstance(fn, ast.Attribute)
                  and fn.attr in _CONCRETIZE_METHODS
                  and self.tainted(fn.value, t)):
                self.findings.append(self.ctx.finding(
                    sub, TS002,
                    f"'.{fn.attr}()' concretizes a traced value"))
            elif (isinstance(fn, ast.Attribute)
                  and isinstance(fn.value, ast.Name)
                  and fn.value.id in _NUMPY_NAMES
                  and fn.attr in {"asarray", "array"}
                  and any(self.tainted(a, t) for a in sub.args)):
                self.findings.append(self.ctx.finding(
                    sub, TS002,
                    f"'np.{fn.attr}' pulls a traced value to host "
                    "(use jnp)"))

    # -- statement walk -----------------------------------------------------
    def assign_names(self, target: ast.AST, is_tainted: bool,
                     t: set[str]) -> None:
        if isinstance(target, ast.Name):
            (t.add if is_tainted else t.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self.assign_names(e, is_tainted, t)
        elif isinstance(target, ast.Starred):
            self.assign_names(target.value, is_tainted, t)
        # subscript/attribute targets: no name taint to update

    def scan_stmts(self, stmts, t: set[str]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # fori_loop/while_loop bodies: params are traced carries
                inner = set(t) | set(_param_names(st))
                self.scan_stmts(st.body, inner)
                continue
            if isinstance(st, ast.Assign):
                self.scan_expr(st.value, t)
                self._scan_lambdas(st.value, t)
                is_t = self.tainted(st.value, t)
                if (len(st.targets) == 1
                        and isinstance(st.targets[0], (ast.Tuple, ast.List))
                        and isinstance(st.value, (ast.Tuple, ast.List))
                        and len(st.targets[0].elts) == len(st.value.elts)):
                    for tgt, val in zip(st.targets[0].elts, st.value.elts, strict=True):
                        self.assign_names(tgt, self.tainted(val, t), t)
                else:
                    for tgt in st.targets:
                        self.assign_names(tgt, is_t, t)
            elif isinstance(st, ast.AugAssign):
                self.scan_expr(st.value, t)
                if isinstance(st.target, ast.Name):
                    is_t = (st.target.id in t
                            or self.tainted(st.value, t))
                    self.assign_names(st.target, is_t, t)
            elif isinstance(st, ast.AnnAssign):
                if st.value is not None:
                    self.scan_expr(st.value, t)
                    self.assign_names(st.target,
                                      self.tainted(st.value, t), t)
            elif isinstance(st, ast.If):
                self.scan_expr(st.test, t)
                if self.tainted(st.test, t):
                    self.findings.append(self.ctx.finding(
                        st, TS001, "Python 'if' on a traced value"))
                self.scan_stmts(st.body, t)
                self.scan_stmts(st.orelse, t)
            elif isinstance(st, ast.While):
                self.scan_expr(st.test, t)
                if self.tainted(st.test, t):
                    self.findings.append(self.ctx.finding(
                        st, TS001, "Python 'while' on a traced value"))
                self.scan_stmts(st.body, t)
                self.scan_stmts(st.orelse, t)
            elif isinstance(st, ast.Assert):
                self.scan_expr(st.test, t)
                if self.tainted(st.test, t):
                    self.findings.append(self.ctx.finding(
                        st, TS001, "assert on a traced value"))
            elif isinstance(st, ast.For):
                self.scan_expr(st.iter, t)
                if self.tainted(st.iter, t):
                    self.findings.append(self.ctx.finding(
                        st, TS003, "Python 'for' over a traced value"))
                    self.assign_names(st.target, True, t)
                else:
                    self.assign_names(st.target, False, t)
                self.scan_stmts(st.body, t)
                self.scan_stmts(st.orelse, t)
            elif isinstance(st, (ast.With, ast.AsyncWith)):
                for item in st.items:
                    self.scan_expr(item.context_expr, t)
                self.scan_stmts(st.body, t)
            elif isinstance(st, ast.Try):
                self.scan_stmts(st.body, t)
                for h in st.handlers:
                    self.scan_stmts(h.body, t)
                self.scan_stmts(st.orelse, t)
                self.scan_stmts(st.finalbody, t)
            elif isinstance(st, (ast.Return, ast.Expr)):
                if st.value is not None:
                    self.scan_expr(st.value, t)
                    self._scan_lambdas(st.value, t)
            # other statements (pass, import, raise, ...) carry no taint

    def _scan_lambdas(self, expr: ast.AST, t: set[str]) -> None:
        """Lambdas in jitted code (BlockSpec index maps) get their params
        traced; their bodies are expression-only."""
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Lambda):
                inner = set(t) | set(_param_names(sub))
                self.scan_expr(sub.body, inner)


@register_checker(scope=SCOPE)
def check_trace_stability(ctx: FileContext):
    findings: list[Finding] = []
    scan = _TaintScan(ctx, findings)
    for fn in [n for n in ast.walk(ctx.tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        static = _static_names(fn)
        if static is None:
            continue
        traced = {p for p in _param_names(fn) if p not in static}
        scan.scan_stmts(fn.body, traced)
    return findings


@register_checker(scope=SCOPE)
def check_pad_widths(ctx: FileContext):
    """TS004 — runs on every function: widths are computed in wrappers."""
    findings: list[Finding] = []
    for st in [n for n in ast.walk(ctx.tree) if isinstance(n, ast.Assign)]:
        for tgt in st.targets:
            if not (isinstance(tgt, ast.Name)
                    and (tgt.id == "width" or tgt.id.endswith("_width"))):
                continue
            if _pow2_ok(st.value):
                continue
            findings.append(ctx.finding(
                st, TS004,
                f"'{tgt.id}' is not provably pow2 — route through "
                "pad_pow2() so trace-cache keys stay quantized"))
    return findings


def _pow2_ok(expr: ast.AST) -> bool:
    """Structurally pow2-shaped: a pad_pow2/next_pow2 call, a pow2 int
    literal, a left shift, a bare alias (no new decision), or min/max /
    conditional over such expressions."""
    if isinstance(expr, (ast.Name, ast.Attribute)):
        return True    # alias of something already decided upstream
    if isinstance(expr, ast.Constant):
        return (isinstance(expr.value, int) and expr.value > 0
                and expr.value & (expr.value - 1) == 0)
    if isinstance(expr, ast.BinOp):
        return isinstance(expr.op, ast.LShift)
    if isinstance(expr, ast.Call):
        fn = expr.func
        name = fn.attr if isinstance(fn, ast.Attribute) \
            else (fn.id if isinstance(fn, ast.Name) else "")
        if name in _POW2_FNS:
            return True
        if name in {"min", "max"}:
            return all(_pow2_ok(a) for a in expr.args)
        return False
    if isinstance(expr, ast.IfExp):
        return _pow2_ok(expr.body) and _pow2_ok(expr.orelse)
    return False
