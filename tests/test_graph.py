"""Dynamic-graph engine tests: schema evolution, versioned mutations,
snapshot isolation, algorithms (vs NetworkX-free oracles), programming models
vs the pure-jnp oracle, distributed modes vs single-device oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.versioned import Version
from repro.graph import compute as gc
from repro.graph.dyngraph import DynamicGraph, MutationBatch, synthesize_stream
from repro.graph.models import (pagerank_program, run_edge_centric,
                                run_mapreduce, run_pregel)
from repro.graph.partition import (comm_model, distributed_join_group_by,
                                   partition_graph)
from repro.graph.schema import citation_schema


# ------------------------------------------------------------------- schema
def test_schema_evolution_fig2():
    reg = citation_schema()
    assert reg.fields_of("Author", 1) == {"name": "String"}
    # V2 inherits V1's fields (template-like inheritance)
    assert reg.fields_of("Author", 2) == {"name": "String", "contact": "String"}
    assert reg.versions_of("Author") == [1, 2]
    assert reg.link_allowed(("Author", 1), ("Paper", 1))
    assert reg.link_allowed(("Author", 2), ("School", 1))
    assert not reg.link_allowed(("Author", 1), ("School", 1))  # V2-only link
    assert reg.validate("Author", 2, {"name": "a", "contact": "b"})
    assert not reg.validate("Author", 1, {"contact": "b"})


def test_schema_versions_immutable():
    reg = citation_schema()
    with pytest.raises(ValueError):
        reg.declare_node("Author", 1, {"x": "Int"})


# ----------------------------------------------------------------- dyngraph
def _mini_graph():
    g = DynamicGraph(8, 64)
    g.apply(MutationBatch(Version(0, 0),
                          add_src=np.array([0, 1, 2], np.int32),
                          add_dst=np.array([1, 2, 3], np.int32)))
    g.apply(MutationBatch(Version(1, 0),
                          add_src=np.array([3], np.int32),
                          add_dst=np.array([0], np.int32),
                          del_src=np.array([0], np.int32),
                          del_dst=np.array([1], np.int32)))
    return g


def test_snapshot_isolation():
    g = _mini_graph()
    m0 = g.snapshot_mask(Version(0, 0))
    m1 = g.snapshot_mask(Version(1, 0))
    assert m0.sum() == 3                      # 0->1,1->2,2->3
    assert m1.sum() == 3                      # (0->1 deleted) + 3->0
    v0 = g.join_view(Version(0, 0))
    v1 = g.join_view(Version(1, 0))
    assert v0.m == 3 and v1.m == 3
    # old snapshot still addressable after mutation (multi-version semantics)
    assert g.join_view(Version(0, 0)).m == 3


def test_view_gc():
    g = _mini_graph()
    for e in range(2):
        g.join_view(Version(e, 0))
    assert g.gc_views(keep_latest=1) == 1


# --------------------------------------------------------------- algorithms
def _pagerank_dense_oracle(view, damping=0.85, iters=200):
    n = view.n
    A = np.zeros((n, n))
    src, dst = np.asarray(view.src), np.asarray(view.dst)
    for s, d in zip(src, dst, strict=True):
        A[d, s] += 1.0
    out_deg_raw = np.asarray(view.out_degree)
    out_deg = np.maximum(out_deg_raw, 1.0)
    pr = np.full(n, 1.0 / n)
    for _ in range(iters):
        dmass = pr[out_deg_raw == 0].sum()
        pr = (1 - damping) / n + damping * (A @ (pr / out_deg) + dmass / n)
    return pr


def test_pagerank_matches_dense_oracle():
    g, _ = synthesize_stream(32, 4, 40, seed=1)
    view = g.join_view(Version(3, 0))
    res = gc.pagerank(view, tol=1e-10, max_iter=500)
    oracle = _pagerank_dense_oracle(view)
    np.testing.assert_allclose(np.asarray(res.ranks), oracle, atol=1e-6)


def test_incremental_pagerank_matches_full_and_converges_faster():
    # realistic online scenario: a SMALL mutation delta on a converged graph
    g, _ = synthesize_stream(64, 6, 60, seed=2)
    g.apply(MutationBatch(Version(6, 0),
                          add_src=np.array([1, 2, 3], np.int32),
                          add_dst=np.array([5, 6, 7], np.int32)))
    v_old, v_new = Version(5, 0), Version(6, 0)
    old = gc.pagerank(g.join_view(v_old), tol=1e-7, max_iter=500)
    cold = gc.pagerank(g.join_view(v_new), tol=1e-7, max_iter=500)
    warm = gc.incremental_pagerank(old, g.join_view(v_old),
                                   g.join_view(v_new), tol=1e-7, max_iter=500)
    np.testing.assert_allclose(np.asarray(warm.ranks), np.asarray(cold.ranks),
                               atol=1e-5)
    assert warm.iterations <= cold.iterations   # warm start converges faster


def _sssp_oracle(view, source):
    n = view.n
    src, dst = np.asarray(view.src), np.asarray(view.dst)
    dist = np.full(n, np.inf)
    dist[source] = 0
    for _ in range(n):
        nd = dist.copy()
        for s, d in zip(src, dst, strict=True):
            nd[d] = min(nd[d], dist[s] + 1.0)
        if np.array_equal(nd, dist, equal_nan=True):
            break
        dist = nd
    return dist


def test_sssp_both_schedulers_match_oracle():
    g, _ = synthesize_stream(48, 4, 80, seed=3)
    view = g.join_view(Version(3, 0))
    oracle = _sssp_oracle(view, 0)
    plain = gc.sssp(view, 0)
    prio = gc.sssp(view, 0, priority_fraction=0.25)
    np.testing.assert_allclose(np.asarray(plain.dist), oracle)
    np.testing.assert_allclose(np.asarray(prio.dist), oracle)
    # priority scheduling trades rounds for fewer relaxations
    assert prio.relaxations <= plain.relaxations


def test_wcc_matches_union_find():
    g, _ = synthesize_stream(40, 3, 30, seed=4)
    view = g.join_view(Version(2, 0))
    labels = np.asarray(gc.wcc(view))
    # union-find oracle
    parent = list(range(view.n))
    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x
    for s, d in zip(np.asarray(view.src), np.asarray(view.dst), strict=True):
        parent[find(int(s))] = find(int(d))
    for a in range(view.n):
        for b in range(a):
            assert (labels[a] == labels[b]) == (find(a) == find(b))


def test_khop_and_reachability():
    g = _mini_graph()
    view = g.join_view(Version(0, 0))       # 0->1->2->3 chain
    reach = np.asarray(gc.k_hop(view, jnp.array([0]), 2))
    assert reach[:3].all() and not reach[3]
    assert gc.reachability(view, 0, 3)
    assert not gc.reachability(view, 3, 0)
    view1 = g.join_view(Version(1, 0))      # 3->0 added
    assert gc.reachability(view1, 3, 0)


def test_temporal_analytics():
    g, _ = synthesize_stream(32, 5, 40, seed=5)
    versions = [Version(e, 0) for e in range(5)]
    tl = gc.degree_timeline(g, versions)
    assert tl.shape == (5, 32)
    assert (tl[-1].sum() >= tl[0].sum())     # graph grows
    top = gc.emerging_vertices(g, versions[1], versions[-1], top_k=3)
    growth = tl[-1] - tl[1]
    assert growth[top[0]] == growth.max()
    prs = gc.pagerank_timeline(g, versions, incremental=True, tol=1e-8)
    assert len(prs) == 5


# --------------------------------------------------- models on protocol dataflow
def test_pregel_pagerank_matches_oracle():
    g, _ = synthesize_stream(24, 3, 30, seed=6)
    view = g.join_view(Version(2, 0))
    ref = gc.pagerank(view, tol=1e-12, max_iter=60, handle_dangling=False)
    got = run_pregel(view, pagerank_program(n=view.n), n_parts=3,
                     init_value=1.0 / view.n, supersteps=60)
    np.testing.assert_allclose(got, np.asarray(ref.ranks), atol=1e-4)


def test_edge_centric_pagerank_matches_oracle():
    g, _ = synthesize_stream(24, 3, 30, seed=7)
    view = g.join_view(Version(2, 0))
    ref = gc.pagerank(view, tol=1e-12, max_iter=40, handle_dangling=False)
    got = run_edge_centric(view, n_parts=4, iters=40)
    np.testing.assert_allclose(got, np.asarray(ref.ranks), atol=1e-5)


def test_mapreduce_wordcount():
    records = ["a b a", "b c", "a"]
    out = run_mapreduce(records,
                        map_fn=lambda line: [(w, 1) for w in line.split()],
                        reduce_fn=lambda k, vs: sum(vs))
    assert out == {"a": 3, "b": 2, "c": 1}


# ------------------------------------------------------------- distribution
@pytest.mark.parametrize("mode", ["allgather", "scatter", "hub"])
def test_distributed_join_group_by_matches_single(mode):
    g, _ = synthesize_stream(32, 3, 60, seed=8)
    view = g.join_view(Version(2, 0))
    pg = partition_graph(view, 1, hub_k=4)
    mesh = jax.make_mesh((1,), ("data",))
    vals = jnp.arange(pg.n, dtype=jnp.float32)
    got = distributed_join_group_by(pg, vals, mesh, mode=mode)
    expect = jax.ops.segment_sum(vals[view.src], view.dst, num_segments=pg.n)
    np.testing.assert_allclose(np.asarray(got)[:view.n],
                               np.asarray(expect)[:view.n], rtol=1e-6)


def test_comm_model_hub_beats_allgather():
    g, _ = synthesize_stream(64, 3, 120, seed=9)
    view = g.join_view(Version(2, 0))
    pg = partition_graph(view, 8, hub_k=4)
    cm = comm_model(pg)
    assert cm["hub"] < cm["allgather"]
