"""reprolint CLI: run the invariant checkers over the tree.

    PYTHONPATH=src python scripts/run_staticcheck.py            # report
    PYTHONPATH=src python scripts/run_staticcheck.py --gate     # CI gate
    PYTHONPATH=src python scripts/run_staticcheck.py --json
    PYTHONPATH=src python scripts/run_staticcheck.py src/repro/graph

Default targets are ``src/repro``, ``scripts``, ``benchmarks`` and
``examples``; ``tests/`` is skipped (test bodies poke internals on
purpose) and the known-violation fixture corpus is never gated. The
committed baseline (``scripts/staticcheck_baseline.json``) maps
``"RULE:path"`` to an allowed finding count; ``--gate`` exits non-zero
only for findings beyond it — a clean tree keeps the baseline empty.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import staticcheck  # noqa: E402

DEFAULT_TARGETS = ["src/repro", "scripts", "benchmarks", "examples"]
EXCLUDE_PARTS = ("tests", "staticcheck_fixtures", "__pycache__")
BASELINE = ROOT / "scripts" / "staticcheck_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="files or directories (default: the repo tree)")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 on findings beyond the baseline")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--baseline", type=pathlib.Path, default=BASELINE,
                    help=f"baseline file (default {BASELINE})")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the registered rules and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, summary in sorted(staticcheck.RULES.items()):
            print(f"{rule}  {summary}")
        return 0

    targets = [pathlib.Path(p) for p in args.paths] if args.paths else \
        [ROOT / t for t in DEFAULT_TARGETS if (ROOT / t).exists()]
    findings = staticcheck.check_paths(targets, ROOT,
                                       exclude_parts=EXCLUDE_PARTS)
    baseline = staticcheck.load_baseline(args.baseline)
    new, _used = staticcheck.gate(findings, baseline)

    if args.as_json:
        print(staticcheck.to_json(new if args.gate else findings))
    else:
        shown = new if args.gate else findings
        for f in shown:
            print(f.format())
        absorbed = len(findings) - len(new)
        tail = f" ({absorbed} baselined)" if absorbed else ""
        print(f"reprolint: {len(shown)} finding(s) "
              f"across {len(staticcheck.RULES)} rules{tail}")
    if args.gate and new:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
