"""RL003 fixture: blocking calls made while holding a lock."""
import threading
import time


class Applier:
    def __init__(self):
        self._lock = threading.Lock()
        self.done = 0

    def seal(self, futures):
        with self._lock:
            for f in futures:
                f.result()               # RL003: barrier under lock
            self.done += 1

    def throttle(self):
        with self._lock:
            time.sleep(0.1)              # RL003: sleep under lock
            self.done += 1
