"""TS002 clean twin: host conversions of statics, jnp for tracers."""
import jax
import jax.numpy as jnp


@jax.jit
def scaled(x):
    scale = float(x.shape[0])    # shape is static: fine
    return jnp.asarray(x, jnp.float32) / scale   # jnp stays traced: fine


@jax.jit
def widened(x):
    n = int(x.ndim)              # ndim is static: fine
    return x.reshape((1,) * n + x.shape)
