"""Versioned-snapshot checkpointing — the paper's §2.3.1 data model applied
to training state.

Every checkpoint is a version ``(epoch, step)`` in a :class:`VersionedStore`
directory; restore resolves ``snapshot(v) = max{v' <= v}`` — the paper's
rule — so "restart from where we were at step N" and "restart from latest"
are the same query. Old versions remain addressable until ``gc_below``
(obsolete-replica collection).

On a real pod each host writes its own shards (the manifest records the
sharding rules); here leaves are gathered and written whole.
"""
from __future__ import annotations

import json
import os
import pathlib

import jax
import numpy as np

from repro.core.versioned import Version, VersionedStore


class CheckpointStructureError(ValueError):
    """The checkpoint on disk does not contain the requested state
    structure (missing leaves). Distinct from corruption/IO errors so
    callers probing for an alternative state shape (e.g. params-only vs
    full train state) can retry on THIS and re-raise everything else."""


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.index = VersionedStore()
        self._load_index()

    def _manifest_path(self):
        return self.dir / "MANIFEST.json"

    def _load_index(self):
        mp = self._manifest_path()
        if mp.exists():
            for entry in json.loads(mp.read_text()):
                self.index.put("ckpt", Version(*entry["version"]),
                               entry["file"])

    def _write_atomic(self, fname: str, writer) -> None:
        """Crash-atomic file write: temp file in the same directory,
        flush + fsync, then ``os.replace`` over the final name (and an
        fsync of the directory so the rename itself is durable). A crash
        at any point leaves either the previous file or no file — never
        a torn one."""
        tmp = self.dir / (fname + ".tmp")
        with open(tmp, "wb") as f:
            writer(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.dir / fname)
        dfd = os.open(self.dir, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)

    def _save_index(self):
        entries = [{"version": [v.epoch, v.number],
                    "file": self.index.get("ckpt", v)}
                   for v in self.index.versions("ckpt")]
        payload = json.dumps(entries, indent=1).encode()
        self._write_atomic("MANIFEST.json", lambda f: f.write(payload))

    # ------------------------------------------------------------------ API
    def save(self, state, *, epoch: int, step: int) -> Version:
        v = Version(epoch, step)
        fname = f"ckpt_e{epoch}_s{step}.npz"
        flat = _flatten(state)
        # data before manifest: the manifest must never name a checkpoint
        # that is not durably on disk (a crash between the two leaves an
        # unlisted .npz, which a later save's GC removes)
        self._write_atomic(fname, lambda f: np.savez(f, **flat))
        self.index.put("ckpt", v, fname)
        self._save_index()
        self._gc()
        return v

    def restore(self, like, version: Version | None = None):
        """Restore into the structure of ``like`` (a state pytree or its
        eval_shape). ``version=None`` -> latest; otherwise the paper's
        snapshot rule picks max{v' <= version}."""
        fname = self.index.get("ckpt", version)
        data = np.load(self.dir / fname)
        flat_like = _flatten(like)
        missing = set(flat_like) - set(data.files)
        if missing:
            raise CheckpointStructureError(
                f"checkpoint missing leaves: {sorted(missing)[:4]}")
        leaves_paths = jax.tree_util.tree_flatten_with_path(like)
        restored = []
        for path, leaf in leaves_paths[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = data[key]
            restored.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype")
                            else arr)
        return jax.tree_util.tree_unflatten(leaves_paths[1], restored)

    def versions(self):
        return self.index.versions("ckpt")

    def _gc(self):
        versions = self.index.versions("ckpt")
        if len(versions) <= self.keep:
            return
        cutoff = versions[-self.keep]
        for v in versions:
            if v < cutoff:
                fname = self.index.get("ckpt", v)
                (self.dir / fname).unlink(missing_ok=True)
        self.index.gc_below(cutoff)
        self._save_index()
