"""Gradient compression: int8 quantization with error feedback.

Applied around the DP all-reduce: each worker quantizes its local gradient
to int8 with a per-tensor scale, the all-reduce sums int32-accumulated
quantized values, and the dequantization error is fed back into the next
step's gradient (error feedback keeps SGD/Adam convergence).

In the SPMD dry-run the quantize/dequantize pair brackets the psum so the
collective moves 1/4 the bytes (visible in the parsed HLO); on the CPU
examples it runs inline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def quantize(g, err):
    """-> (q int8, scale f32 scalar, new residual)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, err_state):
    """Tree-wise error-feedback quantization. Returns (dequantized grads,
    new error state, stats)."""
    flat, treedef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(err_state)
    outs, new_errs = [], []
    for g, e in zip(flat, errs, strict=True):
        q, scale, resid = quantize(g, e)
        outs.append(dequantize(q, scale).astype(g.dtype))
        new_errs.append(resid)
    raw = sum(g.size * g.dtype.itemsize for g in flat)
    compressed = sum(g.size + 4 for g in flat)  # int8 + scale
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, new_errs),
            {"bytes_raw": raw, "bytes_compressed": compressed,
             "ratio": raw / max(compressed, 1)})
