"""SP002 fixture: ad-hoc closures with shared-state writes on the pool."""


class Plane:
    def __init__(self):
        self.results = []
        self.frontier = -1

    def seal_epoch(self, pool, nodes, epoch):
        futures = [
            pool.submit(lambda: self.results.append(epoch))   # SP002
            for _ in nodes
        ]

        def task():
            self.frontier = epoch                             # SP002
        futures.append(pool.submit(task))
        return futures
