"""Recurrent mixers: RG-LRU (Griffin/RecurrentGemma), mLSTM and sLSTM (xLSTM).

RG-LRU is a *diagonal* linear recurrence -> parallelized with
``jax.lax.associative_scan`` (the Pallas ``lru_scan`` kernel is the TPU fast
path). mLSTM (matrix memory) and sLSTM (scalar memory with recurrent gate
connections) use stabilized exponential gating and run as ``lax.scan`` over
time; every block also exposes a single-step decode update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.layers import Init, dense

C_RGLRU = 8.0


# ---------------------------------------------------------------- causal conv
def init_conv(key, width, channels, dtype):
    return {"w": Init(key, (width, channels), dtype)}


def causal_conv(p, x):
    """Depthwise causal conv. x: (B,S,C); kernel (W,C)."""
    w = p["w"].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    out = jnp.zeros_like(xf)
    for k in range(w.shape[0]):
        shifted = jnp.pad(xf, ((0, 0), (k, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[k]
    return out.astype(x.dtype)


def causal_conv_step(p, x_t, state):
    """x_t: (B,C); state: (B, W-1, C) of prior inputs (most recent last)."""
    w = p["w"].astype(jnp.float32)
    width = w.shape[0]
    hist = jnp.concatenate([state, x_t[:, None].astype(jnp.float32)], axis=1)
    taps = hist[:, -width:]                                  # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", taps, w)
    return out.astype(x_t.dtype), hist[:, 1:]


# -------------------------------------------------------------------- RG-LRU
def init_rglru_block(key, cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 8)
    return {
        "in_x": Init(ks[0], (d, w), cfg.param_dtype),
        "in_gate": Init(ks[1], (d, w), cfg.param_dtype),
        "conv": init_conv(ks[2], cfg.conv_width, w, cfg.param_dtype),
        # per-channel gate affines + recurrence parameter Lambda
        "w_ig": Init(ks[3], (w,), jnp.float32),
        "b_ig": jnp.zeros((w,), jnp.float32),
        "w_rg": Init(ks[4], (w,), jnp.float32),
        "b_rg": jnp.zeros((w,), jnp.float32),
        "a_param": jnp.full((w,), 2.0, jnp.float32),  # softplus^-1-ish init
        "out": Init(ks[5], (w, d), cfg.param_dtype),
    }


def _rglru_coeffs(p, u):
    """u: (B,S,W) f32 conv output -> per-step (a, b) of the recurrence."""
    r = jax.nn.sigmoid(u * p["w_rg"] + p["b_rg"])
    i = jax.nn.sigmoid(u * p["w_ig"] + p["b_ig"])
    log_a = -C_RGLRU * jax.nn.softplus(p["a_param"]) * r
    a = jnp.exp(log_a)
    # 1 - a^2 computed stably
    b = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12)) * (i * u)
    return a, b


def rglru_forward(p, x, cfg, use_kernel=False, return_state=False):
    """x: (B,S,D) -> (B,S,D). Diagonal linear recurrence via associative scan."""
    conv_in = dense(x, p["in_x"]).astype(jnp.float32)
    gate = jax.nn.gelu(dense(x, p["in_gate"]).astype(jnp.float32))
    u = causal_conv({"w": p["conv"]["w"]}, conv_in)
    a, b = _rglru_coeffs(p, u)
    if use_kernel:
        from repro.kernels import ops
        h = ops.lru_scan(a, b)
    else:
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h * gate).astype(x.dtype)
    y = dense(out, p["out"])
    if return_state:
        cw = cfg.conv_width
        state = {"h": h[:, -1], "conv": conv_in[:, x.shape[1] - (cw - 1):]}
        return y, state
    return y


def init_rglru_cache(cfg, batch):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32),
    }


def rglru_decode(p, x, cfg, cache):
    """x: (B,1,D) -> (B,1,D) with carried state."""
    xt = x[:, 0]
    u = dense(xt, p["in_x"]).astype(jnp.float32)
    gate = jax.nn.gelu(dense(xt, p["in_gate"]).astype(jnp.float32))
    u, conv_state = causal_conv_step({"w": p["conv"]["w"]}, u, cache["conv"])
    a, b = _rglru_coeffs(p, u.astype(jnp.float32))
    h = a * cache["h"] + b
    out = dense((h * gate).astype(x.dtype), p["out"])
    return out[:, None], {"h": h, "conv": conv_state}


# --------------------------------------------------------------------- mLSTM
def init_mlstm_block(key, cfg):
    d = cfg.d_model
    dp = int(cfg.mlstm_proj_factor * d)
    h = cfg.n_heads
    hd = dp // h
    ks = jax.random.split(key, 8)
    return {
        "up": Init(ks[0], (d, 2 * dp), cfg.param_dtype),
        "conv": init_conv(ks[1], cfg.conv_width, dp, cfg.param_dtype),
        "wq": Init(ks[2], (h, hd, hd), cfg.param_dtype),
        "wk": Init(ks[3], (h, hd, hd), cfg.param_dtype),
        "wv": Init(ks[4], (h, hd, hd), cfg.param_dtype),
        "w_if": Init(ks[5], (dp, 2 * h), cfg.param_dtype),
        "b_if": jnp.concatenate([jnp.zeros((h,)),
                                 jnp.full((h,), 3.0)]).astype(jnp.float32),
        "head_norm": jnp.zeros((dp,), jnp.float32),
        "down": Init(ks[6], (dp, d), cfg.param_dtype),
    }


def _mlstm_qkvif(p, xm, cfg):
    B, S, dp = xm.shape
    h = cfg.n_heads
    hd = dp // h
    conv_out = jax.nn.silu(causal_conv({"w": p["conv"]["w"]}, xm).astype(jnp.float32))
    xh = conv_out.reshape(B, S, h, hd)
    q = jnp.einsum("bshd,hde->bshe", xh, p["wq"].astype(jnp.float32))
    k = jnp.einsum("bshd,hde->bshe", xh, p["wk"].astype(jnp.float32)) * hd ** -0.5
    v = jnp.einsum("bshd,hde->bshe",
                   xm.reshape(B, S, h, hd).astype(jnp.float32),
                   p["wv"].astype(jnp.float32))
    gates = xm.astype(jnp.float32) @ p["w_if"].astype(jnp.float32) + p["b_if"]
    i_pre, f_pre = gates[..., :h], gates[..., h:]          # (B,S,H)
    return q, k, v, i_pre, f_pre


def _mlstm_cell_step(carry, inp):
    C, n, m = carry                                        # (B,H,hd,hd),(B,H,hd),(B,H)
    q, k, v, i_pre, f_pre = inp                            # (B,H,hd)...,(B,H)
    log_f = -jax.nn.softplus(-f_pre)                       # log sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(log_f + m - m_new)
    C_new = f[..., None, None] * C + i[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n_new = f[..., None] * n + i[..., None] * k
    h_num = jnp.einsum("bhde,bhe->bhd", C_new, q)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)),
                        jnp.exp(-m_new))[..., None]
    return (C_new, n_new, m_new), h_num / h_den


def _mlstm_chunkwise(q, k, v, i_pre, f_pre, L):
    """Chunkwise-parallel stabilized mLSTM (exact reformulation of the
    sequential recurrence; TFLA-style TPU adaptation).

    Within a chunk of L steps the outputs are computed with (L,L) decay-
    masked attention matmuls (MXU work, no per-step (hd,hd) matrix-memory
    materialization); only chunk-boundary (C~, n~, m) carries cross chunks.
    Inputs: q,k,v (B,S,H,hd) f32 (k pre-scaled by hd^-0.5); i_pre,f_pre
    (B,S,H). Returns (h (B,S,H,hd), final carry).
    """
    B, S, H, hd = q.shape
    nch = S // L

    def to_chunks(t, feat):
        if feat:
            return t.reshape(B, nch, L, H, hd).transpose(1, 0, 3, 2, 4)
        return t.reshape(B, nch, L, H).transpose(1, 0, 3, 2)

    qc, kc, vc = (to_chunks(t, True) for t in (q, k, v))     # (nch,B,H,L,hd)
    ic = to_chunks(i_pre, False)                             # (nch,B,H,L)
    lfc = to_chunks(-jax.nn.softplus(-f_pre), False)         # log sigmoid(f)

    neg_inf = jnp.float32(-1e30)
    tri = jnp.tril(jnp.ones((L, L), bool))

    @jax.checkpoint
    def chunk(carry, xs):
        Cin, nin, m_in = carry              # (B,H,hd,hd),(B,H,hd),(B,H)
        qL, kL, vL, iL, lfL = xs
        b = jnp.cumsum(lfL, axis=-1)                         # (B,H,L)
        D = b[..., :, None] - b[..., None, :] + iL[..., None, :]
        D = jnp.where(tri, D, neg_inf)                       # (B,H,L,L)
        m_intra = D.max(axis=-1)
        m_t = jnp.maximum(m_intra, b + m_in[..., None])      # (B,H,L)
        A = jnp.exp(D - m_t[..., None])
        scores = jnp.einsum("bhtd,bhsd->bhts", qL, kL)
        P = A * scores
        inter = jnp.exp(b + m_in[..., None] - m_t)           # (B,H,L)
        h_num = (jnp.einsum("bhts,bhsd->bhtd", P, vL)
                 + inter[..., None] * jnp.einsum("bhvk,bhtk->bhtv", Cin, qL))
        den_raw = P.sum(axis=-1) + inter * jnp.einsum("bhk,bhtk->bht", nin, qL)
        h = h_num / jnp.maximum(jnp.abs(den_raw),
                                jnp.exp(-m_t))[..., None]
        # chunk-boundary carry (same stabilizer as the sequential form)
        bL = b[..., -1]
        m_out = m_t[..., -1]
        wgt = jnp.exp(bL[..., None] - b + iL - m_out[..., None])  # (B,H,L)
        decay_in = jnp.exp(bL + m_in - m_out)
        C_out = (jnp.einsum("bhs,bhsv,bhsk->bhvk", wgt, vL, kL)
                 + decay_in[..., None, None] * Cin)
        n_out = (jnp.einsum("bhs,bhsk->bhk", wgt, kL)
                 + decay_in[..., None] * nin)
        return (C_out, n_out, m_out), h

    c0 = (jnp.zeros((B, H, hd, hd), jnp.float32),
          jnp.zeros((B, H, hd), jnp.float32),
          jnp.zeros((B, H), jnp.float32))
    carry, hs = jax.lax.scan(chunk, c0, (qc, kc, vc, ic, lfc))
    # hs: (nch,B,H,L,hd) -> (B,S,H,hd)
    hs = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    return hs, carry


def mlstm_forward(p, x, cfg, return_state=False):
    B, S, d = x.shape
    dp = int(cfg.mlstm_proj_factor * d)
    h = cfg.n_heads
    hd = dp // h
    z = dense(x, p["up"])
    xm, og = z[..., :dp], z[..., dp:]
    q, k, v, i_pre, f_pre = _mlstm_qkvif(p, xm, cfg)
    tc = cfg.mlstm_chunk
    if cfg.mlstm_impl == "chunkwise" and tc and S % tc == 0:
        hs, (C, n, m) = _mlstm_chunkwise(q, k, v, i_pre, f_pre, tc)
        hs = hs.reshape(B, S, dp)
        hs = _headwise_rms(hs, p["head_norm"], h)
        out = hs * jax.nn.silu(og.astype(jnp.float32))
        y = dense(out.astype(x.dtype), p["down"])
        if return_state:
            cw = cfg.conv_width
            return y, {"C": C, "n": n, "m": m,
                       "conv": xm.astype(jnp.float32)[:, S - (cw - 1):]}
        return y
    c0 = (jnp.zeros((B, h, hd, hd), jnp.float32),
          jnp.zeros((B, h, hd), jnp.float32),
          jnp.zeros((B, h), jnp.float32))
    xs = tuple(t.transpose(1, 0, 2, 3) for t in (q, k, v))  # (S,B,H,hd)
    xs = xs + (i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1))
    if tc and S % tc == 0 and S > tc:
        # §Perf: chunked scan + remat. The plain scan saves per-STEP
        # residuals (incl. the (B,H,hd,hd) matrix memory) for backward; the
        # chunked form saves only per-chunk carries and recomputes inside
        # each chunk, cutting saved-residual bytes by ~tc/1.
        nch = S // tc
        xs_c = tuple(t.reshape(nch, tc, *t.shape[1:]) for t in xs)

        @jax.checkpoint
        def chunk_body(carry, xc):
            return jax.lax.scan(_mlstm_cell_step, carry, xc)

        (C, n, m), hs = jax.lax.scan(chunk_body, c0, xs_c)
        hs = hs.reshape(S, B, h, hd)
    else:
        (C, n, m), hs = jax.lax.scan(_mlstm_cell_step, c0, xs)  # (S,B,H,hd)
    hs = hs.swapaxes(0, 1).reshape(B, S, dp)
    hs = _headwise_rms(hs, p["head_norm"], h)
    out = hs * jax.nn.silu(og.astype(jnp.float32))
    y = dense(out.astype(x.dtype), p["down"])
    if return_state:
        cw = cfg.conv_width
        state = {"C": C, "n": n, "m": m,
                 "conv": xm.astype(jnp.float32)[:, S - (cw - 1):]}
        return y, state
    return y


def _headwise_rms(x, scale, n_heads, eps=1e-6):
    B, S, dp = x.shape
    xh = x.reshape(B, S, n_heads, dp // n_heads)
    var = jnp.mean(xh * xh, axis=-1, keepdims=True)
    xh = xh * jax.lax.rsqrt(var + eps)
    return xh.reshape(B, S, dp) * (1.0 + scale)


def init_mlstm_cache(cfg, batch):
    dp = int(cfg.mlstm_proj_factor * cfg.d_model)
    h = cfg.n_heads
    hd = dp // h
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, dp), jnp.float32),
    }


def mlstm_decode(p, x, cfg, cache):
    B, _, d = x.shape
    dp = int(cfg.mlstm_proj_factor * d)
    h = cfg.n_heads
    hd = dp // h
    z = dense(x[:, 0], p["up"])
    xm, og = z[..., :dp], z[..., dp:]
    conv_out, conv_state = causal_conv_step({"w": p["conv"]["w"]},
                                            xm.astype(jnp.float32), cache["conv"])
    xh = jax.nn.silu(conv_out.astype(jnp.float32)).reshape(B, h, hd)
    q = jnp.einsum("bhd,hde->bhe", xh, p["wq"].astype(jnp.float32))
    k = jnp.einsum("bhd,hde->bhe", xh, p["wk"].astype(jnp.float32)) * hd ** -0.5
    v = jnp.einsum("bhd,hde->bhe",
                   xm.reshape(B, h, hd).astype(jnp.float32),
                   p["wv"].astype(jnp.float32))
    gates = xm.astype(jnp.float32) @ p["w_if"].astype(jnp.float32) + p["b_if"]
    i_pre, f_pre = gates[..., :h], gates[..., h:]
    (C, n, m), hvec = _mlstm_cell_step(
        (cache["C"], cache["n"], cache["m"]), (q, k, v, i_pre, f_pre))
    hs = _headwise_rms(hvec.reshape(B, 1, dp), p["head_norm"], h)[:, 0]
    out = hs * jax.nn.silu(og.astype(jnp.float32))
    y = dense(out.astype(x.dtype), p["down"])
    return y[:, None], {"C": C, "n": n, "m": m, "conv": conv_state}


# --------------------------------------------------------------------- sLSTM
def init_slstm_block(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    dff = int(cfg.slstm_proj_factor * d)
    ks = jax.random.split(key, 6)
    return {
        "w_gates": Init(ks[0], (d, 4 * d), cfg.param_dtype),   # z,i,f,o preacts
        "r_gates": Init(ks[1], (h, hd, 4 * hd), cfg.param_dtype),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "head_norm": jnp.zeros((d,), jnp.float32),
        "up1": Init(ks[2], (d, dff), cfg.param_dtype),
        "up2": Init(ks[3], (d, dff), cfg.param_dtype),
        "down": Init(ks[4], (dff, d), cfg.param_dtype),
    }


def _slstm_step(p_r, carry, wx_t):
    """carry: (c,n,m,h_prev) each (B,H,hd); wx_t: (B,H,4*hd)."""
    c, n, m, h_prev = carry
    rec = jnp.einsum("bhd,hde->bhe", h_prev, p_r)          # (B,H,4hd)
    pre = wx_t + rec
    hd = c.shape[-1]
    z_pre, i_pre, f_pre, o_pre = [pre[..., j * hd:(j + 1) * hd] for j in range(4)]
    z = jnp.tanh(z_pre)
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(log_f + m - m_new)
    c_new = f * c + i * z
    n_new = f * n + i
    h = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h), h


def slstm_forward(p, x, cfg, return_state=False):
    B, S, d = x.shape
    h_heads = cfg.n_heads
    hd = d // h_heads
    wx = (x.astype(jnp.float32) @ p["w_gates"].astype(jnp.float32) + p["b_gates"])
    wx = wx.reshape(B, S, 4, h_heads, hd).transpose(1, 0, 3, 2, 4)  # (S,B,H,4,hd)
    wx = wx.reshape(S, B, h_heads, 4 * hd)
    zeros = jnp.zeros((B, h_heads, hd), jnp.float32)
    carry0 = (zeros, zeros, jnp.zeros((B, h_heads, hd), jnp.float32), zeros)
    r = p["r_gates"].astype(jnp.float32)
    tc = cfg.mlstm_chunk
    if tc and S % tc == 0 and S > tc:
        # §Perf: chunk + remat the sequential sLSTM scan — backward saves
        # only per-chunk (c,n,m,h) carries instead of per-step residuals.
        @jax.checkpoint
        def chunk_body(cr, wxc):
            return jax.lax.scan(lambda c2, w: _slstm_step(r, c2, w), cr, wxc)
        wx_c = wx.reshape(S // tc, tc, *wx.shape[1:])
        (c, n, m, hstate), hs = jax.lax.scan(chunk_body, carry0, wx_c)
        hs = hs.reshape(S, B, h_heads, hd)
    else:
        (c, n, m, hstate), hs = jax.lax.scan(
            lambda cr, w: _slstm_step(r, cr, w), carry0, wx)
    hs = hs.swapaxes(0, 1).reshape(B, S, d)
    hs = _headwise_rms(hs, p["head_norm"], h_heads)
    up = jax.nn.gelu(dense(hs.astype(x.dtype), p["up1"]).astype(jnp.float32))
    gate = dense(hs.astype(x.dtype), p["up2"]).astype(jnp.float32)
    y = dense((up * gate).astype(x.dtype), p["down"])
    if return_state:
        return y, {"c": c, "n": n, "m": m, "h": hstate}
    return y


def init_slstm_cache(cfg, batch):
    h = cfg.n_heads
    hd = cfg.d_model // h
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}


def slstm_decode(p, x, cfg, cache):
    B, _, d = x.shape
    h_heads = cfg.n_heads
    hd = d // h_heads
    wx = (x[:, 0].astype(jnp.float32) @ p["w_gates"].astype(jnp.float32)
          + p["b_gates"])
    wx = wx.reshape(B, 4, h_heads, hd).transpose(0, 2, 1, 3).reshape(B, h_heads, 4 * hd)
    r = p["r_gates"].astype(jnp.float32)
    carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    (c, n, m, hstate), hvec = _slstm_step(r, carry, wx)
    hs = _headwise_rms(hvec.reshape(B, 1, d), p["head_norm"], h_heads)
    up = jax.nn.gelu(dense(hs.astype(x.dtype), p["up1"]).astype(jnp.float32))
    gate = dense(hs.astype(x.dtype), p["up2"]).astype(jnp.float32)
    y = dense((up * gate).astype(x.dtype), p["down"])
    return y, {"c": c, "n": n, "m": m, "h": hstate}
