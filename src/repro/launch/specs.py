"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation. Used by the dry-run and the roofline
benchmarks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SHAPES, ShapeCell
from repro.models import transformer as tf

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, cell: ShapeCell):
    B, S = cell.global_batch, cell.seq_len
    if cfg.embed_mode == "tokens":
        inputs = SDS((B, S), jnp.int32)
    else:
        inputs = SDS((B, S, cfg.d_model), jnp.bfloat16)
    return {"inputs": inputs, "labels": SDS((B, S), jnp.int32)}


def decode_input_specs(cfg: ModelConfig, cell: ShapeCell):
    B, S = cell.global_batch, cell.seq_len
    if cfg.embed_mode == "tokens":
        inputs = SDS((B, 1), jnp.int32)
    else:
        inputs = SDS((B, 1, cfg.d_model), jnp.bfloat16)
    cache = tf.cache_shapes(cfg, B, S)
    pos = SDS((), jnp.int32)
    return {"inputs": inputs, "cache": cache, "pos": pos}


def input_specs(cfg: ModelConfig, shape_name: str):
    """All inputs for the step that this shape cell lowers."""
    cell = SHAPES[shape_name]
    if cell.kind == "train":
        from repro.launch.steps import train_state_shapes
        return {"state": train_state_shapes(cfg),
                "batch": batch_specs(cfg, cell)}
    if cell.kind == "prefill":
        return {"params": tf.param_shapes(cfg),
                "batch": {"inputs": batch_specs(cfg, cell)["inputs"]}}
    # decode
    return {"params": tf.param_shapes(cfg), **decode_input_specs(cfg, cell)}
