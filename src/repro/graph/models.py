"""Programming models as protocol-dataflow *protocols* — paper §2.3.4.

"Protocol dataflow is general enough to be used to implement ... graph
parallel models (vertex-centric, edge-centric, graph-centric) and data
parallel models (MapReduce)". Each model here is a protocol (message format +
vertex semantics) over ``core.protocol_dataflow``; one dataflow vertex hosts
one *partition* and does its local compute vectorized in JAX (the TPU-
idiomatic reading of the paper's per-vertex actors).

All models are verified against the pure-jnp oracles in ``graph.compute``.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocol_dataflow import (CoalescingOutput, Dataflow, Egress,
                                          Ingress, Protocol, Vertex)
from repro.graph.dyngraph import JoinView


# ----------------------------------------------------------------- vertex-centric
@dataclasses.dataclass
class PregelMsg:
    superstep: int
    # destination-partition payload: dict global dst id -> value
    values: dict


PREGEL = Protocol(
    name="pregel",
    validate=lambda m: isinstance(m, PregelMsg),
    happens_before=lambda e1, e2: (
        True if (e1.kind == "superstep" and e2.kind == "superstep"
                 and e1.payload is not None and e2.payload is not None
                 and e1.payload.get("part") == e2.payload.get("part")
                 and e1.payload["step"] < e2.payload["step"]) else None),
)


class PregelPartition(Vertex):
    """Hosts a contiguous vertex range; combiner=sum (coalescing output
    scheduler merges messages to the same destination partition).

    Execution is *asynchronous* (paper goal 3): a vertex re-emits only when
    its value moved by more than ``eps`` (change-driven halting); damping
    makes the chaotic relaxation converge to the synchronous fixed point.
    """

    def __init__(self, name, part_id, n_parts, view: JoinView,
                 vertex_program, init_value, n_local, eps=1e-12):
        super().__init__(
            name, PREGEL, fn=self._on_receive,
            output_scheduler=CoalescingOutput(
                key=lambda m: m.superstep,
                combine=_merge_pregel))
        self.part_id = part_id
        self.n_parts = n_parts
        self.n_local = n_local
        self.lo = part_id * n_local
        self.vertex_program = vertex_program
        self.eps = eps
        # local out-edges: src in range, any dst
        src = np.asarray(view.src)
        dst = np.asarray(view.dst)
        sel = (src >= self.lo) & (src < self.lo + n_local)
        self.out_src = src[sel]
        self.out_dst = dst[sel]
        self.values = np.full(n_local, init_value, np.float64)
        self.out_degree = np.bincount(self.out_src - self.lo,
                                      minlength=n_local).astype(np.float64)
        self.first = True

    def _on_receive(self, _self, port, payloads):
        step = max(p.superstep for p in payloads)
        incoming = defaultdict(float)
        for p in payloads:
            for vid, val in p.values.items():
                incoming[vid] += val
        new_vals, out_value = self.vertex_program(self.values, incoming, self)
        changed = np.abs(new_vals - self.values) > self.eps
        if self.first:
            changedtous = np.ones_like(changed)
        else:
            changedtous = changed
        self.values = new_vals
        self.first = False
        self.emit_event("superstep", {"part": self.part_id, "step": step})
        if not changedtous.any():
            return ()
        # emit out-edge messages from changed vertices only
        buckets: dict[int, dict] = defaultdict(dict)
        for s, d in zip(self.out_src, self.out_dst, strict=True):
            li = s - self.lo
            if not changedtous[li]:
                continue
            p = min(int(d) // self.n_local, self.n_parts - 1)
            buckets[p][int(d)] = buckets[p].get(int(d), 0.0) + out_value[li]
        return [(f"to{p}", PregelMsg(step + 1, vals))
                for p, vals in buckets.items()]


def _merge_pregel(a: PregelMsg, b: PregelMsg) -> PregelMsg:
    vals = dict(a.values)
    for k, v in b.values.items():
        vals[k] = vals.get(k, 0.0) + v
    return PregelMsg(max(a.superstep, b.superstep), vals)


def run_pregel(view: JoinView, vertex_program, *, n_parts=4, init_value=0.0,
               supersteps=200, eps=1e-12) -> np.ndarray:
    """Run a vertex program until change-driven quiescence; returns the
    concatenated vertex values."""
    n_local = (view.n + n_parts - 1) // n_parts
    df = Dataflow("pregel")
    parts = [df.add(PregelPartition(f"part{p}", p, n_parts, view,
                                    vertex_program, init_value, n_local, eps))
             for p in range(n_parts)]
    ingress = df.add(Ingress("ingress", PREGEL))
    egress = df.add(Egress("egress", PREGEL, lambda m: None))
    for p, v in enumerate(parts):
        ingress.connect(f"to{p}", v, "in")
        for q, w in enumerate(parts):
            v.connect(f"to{q}", w, "in")
        v.connect("done", egress, "in")
    for p, v in enumerate(parts):
        ingress.push([PregelMsg(0, {})], out_port=f"to{p}")
    df.run_until_quiescent(max_rounds=supersteps * max(n_parts, 1) * 10)
    df.deliver_events()
    return np.concatenate([v.values for v in parts])[:view.n]


def pagerank_program(damping=0.85, n=None):
    """The classic Pregel PageRank vertex program.

    Because execution is message-driven, a vertex's rank is recomputed from
    the *accumulated* neighbor contributions; incoming carries deltas of
    src contributions, which the partition state tracks."""
    def program(values, incoming, part: PregelPartition):
        new = values.copy()
        if not hasattr(part, "acc"):
            part.acc = np.zeros(part.n_local, np.float64)
        for vid, val in incoming.items():
            li = vid - part.lo
            if 0 <= li < part.n_local:
                part.acc[li] += val
        new = (1 - damping) / n + damping * part.acc
        # out message value = DELTA of this vertex's contribution
        if not hasattr(part, "sent"):
            part.sent = np.zeros(part.n_local, np.float64)
        contrib = np.divide(new, np.maximum(part.out_degree, 1.0))
        delta = contrib - part.sent
        part.sent = contrib
        return new, delta
    return program


# ----------------------------------------------------------------- edge-centric
EDGE_CENTRIC = Protocol("xstream", validate=lambda m: isinstance(m, tuple))


def run_edge_centric(view: JoinView, *, n_parts=4, iters=10,
                     damping=0.85) -> np.ndarray:
    """X-Stream-style scatter/gather: stream edge partitions, scatter updates
    to a shuffler vertex, gather applies — PageRank as the example program."""
    n = view.n
    src = np.asarray(view.src)
    dst = np.asarray(view.dst)
    bounds = np.linspace(0, len(src), n_parts + 1).astype(int)
    out_deg = np.maximum(np.asarray(view.out_degree), 1.0)
    state = {"pr": np.full(n, 1.0 / n)}

    df = Dataflow("xstream")
    def scatter_fn(vertex, port, payloads):
        outs = []
        for (lo, hi) in payloads:
            contrib = state["pr"][src[lo:hi]] / out_deg[src[lo:hi]]
            agg = np.bincount(dst[lo:hi], weights=contrib, minlength=n)
            outs.append(("out", ("partial", agg)))
        return outs

    def gather_fn(vertex, port, payloads):
        total = np.zeros(n)
        for (_, agg) in payloads:
            total += agg
        state["pr"] = (1 - damping) / n + damping * total
        return [("out", ("done", None))]

    ingress = df.add(Ingress("ingress", EDGE_CENTRIC))
    scatter = df.add(Vertex("scatter", EDGE_CENTRIC, scatter_fn,
                            budget=n_parts))
    gather = df.add(Vertex("gather", EDGE_CENTRIC, gather_fn,
                           budget=n_parts))
    egress = df.add(Egress("egress", EDGE_CENTRIC, lambda m: None))
    ingress.connect("out", scatter)
    scatter.connect("out", gather)
    gather.connect("out", egress)

    for _ in range(iters):
        ingress.push([(int(bounds[i]), int(bounds[i + 1]))
                      for i in range(n_parts)])
        df.run_until_quiescent()
    return state["pr"]


# -------------------------------------------------------------------- MapReduce
MAPREDUCE = Protocol("mapreduce", validate=lambda m: isinstance(m, tuple))


def run_mapreduce(records, map_fn, reduce_fn, *, n_reducers=4) -> dict:
    """MapReduce as a protocol: mapper vertex -> hash-shuffle -> reducers.
    Proves the data-parallel model runs on the same runtime (paper Fig 6)."""
    df = Dataflow("mapreduce")
    results: dict = {}

    def mapper(vertex, port, payloads):
        outs = []
        for tag, rec in payloads:
            for k, v in map_fn(rec):
                outs.append((f"r{hash(k) % n_reducers}", (k, v)))
        return outs

    def make_reducer(rid):
        def reducer(vertex, port, payloads):
            groups = defaultdict(list)
            for k, v in payloads:
                groups[k].append(v)
            for k, vs in groups.items():
                prev = results.get(k)
                vs = ([prev] if prev is not None else []) + vs
                results[k] = reduce_fn(k, vs)
            return [("out", ("ack", rid))]
        return reducer

    ingress = df.add(Ingress("ingress", MAPREDUCE,
                             encode=lambda rec: ("record", rec)))
    m = df.add(Vertex("map", MAPREDUCE, mapper, budget=1 << 20))
    egress = df.add(Egress("egress", MAPREDUCE, lambda x: None))
    ingress.connect("out", m)
    for r in range(n_reducers):
        red = df.add(Vertex(f"reduce{r}", MAPREDUCE, make_reducer(r)))
        m.connect(f"r{r}", red)
        red.connect("out", egress)
    ingress.push(records)
    df.run_until_quiescent()
    df.deliver_events()
    return results
