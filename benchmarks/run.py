"""Benchmark harness — one function per paper evaluation axis (§3).

The paper is a proposal with no tables of its own; its §3 evaluation plan
defines the four axes benchmarked here, plus kernel µbenches and the
roofline report derived from the dry-run artifacts.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def _time(fn, *, repeat=3, number=1):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            out = fn()
        best = min(best, (time.perf_counter() - t0) / number)
    return best, out


def row(name, seconds, derived=""):
    print(f"{name},{seconds*1e6:.1f},{derived}", flush=True)


# ---------------------------------------------------------------- §3.3 axis 1
def bench_online(quick=False):
    """Online computing: query latency on a live snapshot."""
    import jax.numpy as jnp
    from repro.core.versioned import Version
    from repro.graph import compute as gc
    from repro.graph.dyngraph import synthesize_stream

    n = 2_000 if quick else 20_000
    g, _ = synthesize_stream(n, 6, n, seed=0)
    view = g.join_view(Version(5, 0))
    srcs = jnp.arange(4)
    t, _ = _time(lambda: gc.k_hop(view, srcs, 2).block_until_ready())
    row("online.khop2", t, f"n={n};m={view.m}")
    t, _ = _time(lambda: gc.reachability(view, 0, n - 1, max_hops=8))
    row("online.reachability", t, f"n={n}")
    t, _ = _time(lambda: g.join_view(Version(4, 0)))  # cached snapshot view
    row("online.snapshot_view_cached", t, "cache hit")


# ---------------------------------------------------------------- §3.3 axis 2
def bench_offline(quick=False):
    """Offline analytics throughput."""
    from repro.core.versioned import Version
    from repro.graph import compute as gc
    from repro.graph.dyngraph import synthesize_stream

    n = 2_000 if quick else 20_000
    g, _ = synthesize_stream(n, 6, n, seed=1)
    view = g.join_view(Version(5, 0))
    t, res = _time(lambda: gc.pagerank(view, tol=1e-8, max_iter=100))
    eps = view.m * res.iterations / t
    row("offline.pagerank", t, f"edges_per_s={eps:.3e};iters={res.iterations}")
    old = res
    g.apply(_small_delta(g, n))
    new_view = g.join_view(Version(6, 0))
    t, res2 = _time(lambda: gc.incremental_pagerank(
        old, view, new_view, tol=1e-8, max_iter=100))
    row("offline.incremental_pagerank", t,
        f"iters={res2.iterations};cold_iters={_cold_iters(new_view)}")
    t, _ = _time(lambda: gc.wcc(view).block_until_ready())
    row("offline.wcc", t, f"n={n}")
    # weighted SSSP: priority scheduling only pays off when weights vary
    import jax
    w = jax.random.uniform(jax.random.PRNGKey(0), (view.m,),
                           minval=0.1, maxval=10.0)
    t, res3 = _time(lambda: gc.sssp(view, 0, weights=w))
    row("offline.sssp", t, f"rounds={res3.rounds};relax={res3.relaxations}")
    t, res4 = _time(lambda: gc.sssp(view, 0, weights=w,
                                    priority_fraction=0.25))
    row("offline.sssp_priority", t,
        f"rounds={res4.rounds};relax={res4.relaxations}")


def _merge_bench_json(path, sections):
    """Update sections of BENCH_ingest.json in one read-modify-write,
    preserving the sections other axes wrote (ingest_graph and
    ingest_sharded share the file)."""
    import json
    report = {}
    if path.exists():
        try:
            report = json.loads(path.read_text())
        except ValueError:
            report = {}
    report.update(sections)
    path.write_text(json.dumps(report, indent=2))


def _small_delta(g, n):
    from repro.core.versioned import Version
    from repro.graph.dyngraph import MutationBatch
    rng = np.random.default_rng(7)
    k = max(4, n // 200)
    return MutationBatch(Version(6, 0),
                         add_src=rng.integers(0, n, k).astype(np.int32),
                         add_dst=rng.integers(0, n, k).astype(np.int32))


def _cold_iters(view):
    from repro.graph import compute as gc
    return gc.pagerank(view, tol=1e-8, max_iter=100).iterations


# ---------------------------------------------------------------- §3.3 axis 3
def bench_ingest(quick=False):
    """Timeliness of mutation incorporation: no-wait dispatch vs a central
    (Kineograph-style) snapshoter that blocks epoch e+1 on global e.

    One node is a STRAGGLER (seals each epoch one round late). The paper's
    no-wait rule keeps dispatching to the 7 healthy nodes; the central
    snapshoter buffers every epoch-e+1 mutation until the global snapshot of
    epoch e (gated by the straggler) is sealed."""
    from repro.core.snapshotter import (DataNode, IngestNode, Mutation,
                                        SnapshotCoordinator)

    n_muts = 20_000 if quick else 100_000
    epochs = 20
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 1 << 30, n_muts)
    ep = np.sort(rng.integers(0, epochs, n_muts))

    def run_nowait():
        nodes = [DataNode(i) for i in range(8)]
        ingest = IngestNode(nodes, route=lambda k: k % 8)
        coord = SnapshotCoordinator(nodes)
        cur = 0
        delayed = 0
        for e in range(epochs):
            while cur < n_muts and ep[cur] == e:
                if not ingest.dispatch(Mutation(int(keys[cur]), e)):
                    delayed += 1
                cur += 1
            for node in nodes[:-1]:
                node.seal_epoch(e)
            if e > 0:
                nodes[-1].seal_epoch(e - 1)   # straggler: one epoch behind
            ingest.retry_blocked()
            coord.advance()
        nodes[-1].seal_epoch(epochs - 1)
        ingest.retry_blocked()
        coord.advance()
        return ingest.dispatched, delayed

    t, (dispatched, delayed_nw) = _time(run_nowait, repeat=2)
    row("ingest.nowait_dispatch", t,
        f"muts_per_s={dispatched/t:.3e};delayed={delayed_nw}")

    def run_nowait_batched():
        nodes = [DataNode(i) for i in range(8)]
        ingest = IngestNode(nodes, route=lambda k: k % 8)
        coord = SnapshotCoordinator(nodes)
        for e in range(epochs):
            sel = ep == e
            ingest.dispatch_batch(keys[sel], ep[sel])
            for node in nodes[:-1]:
                node.seal_epoch(e)
            if e > 0:
                nodes[-1].seal_epoch(e - 1)
            ingest.retry_blocked_batches()
            coord.advance()
        nodes[-1].seal_epoch(epochs - 1)
        ingest.retry_blocked_batches()
        coord.advance()
        return ingest.dispatched

    t_b, dispatched_b = _time(run_nowait_batched, repeat=2)
    row("ingest.nowait_dispatch_batched", t_b,
        f"muts_per_s={dispatched_b/t_b:.3e};speedup=x{t/t_b:.1f}")

    def run_central():
        # central snapshoter: mutations of epoch e+1 buffered until the
        # GLOBAL snapshot of epoch e is sealed (straggler gates everyone)
        nodes = [DataNode(i) for i in range(8)]
        coord = SnapshotCoordinator(nodes)
        cur, delays = 0, 0
        for e in range(epochs):
            while cur < n_muts and ep[cur] == e:
                if coord.global_frontier >= e - 1:
                    nodes[int(keys[cur]) % 8].receive(Mutation(int(keys[cur]), e))
                else:
                    delays += 1
                cur += 1
            for node in nodes[:-1]:
                node.seal_epoch(e)
            if e > 0:
                nodes[-1].seal_epoch(e - 1)
            coord.advance()
        return delays

    t2, delays = _time(run_central, repeat=2)
    row("ingest.central_snapshoter", t2, f"delayed={delays}")


# ----------------------------------------------------- ingestion (data plane)
def bench_ingest_graph(quick=False):
    """Graph-store ingestion + snapshot view maintenance.

    Measures (a) mutations/sec of the vectorized hash-indexed ``apply``
    against the seed's loop path (O(E) scan per delete) on a delete-heavy
    stream, and (b) join-view build latency: delta patch vs full rebuild at
    several delete fractions. Emits ``BENCH_ingest.json`` next to the repo
    root so later PRs have a perf trajectory to diff against.
    """
    import pathlib

    from repro.core.versioned import Version
    from repro.graph.dyngraph import (DynamicGraph, MutationBatch,
                                      synthesize_churn_stream)
    from repro.graph.reference import LoopDynamicGraph

    report = {"mutation_ingest": {}, "view_build": {}}

    # --- (a) ingestion throughput, delete-heavy stream -----------------
    n = 2_000 if quick else 8_000
    epochs = 10
    adds = 400 if quick else 1_000
    # same generator the equivalence tests use — identical stream semantics
    batches = synthesize_churn_stream(n, epochs, adds, seed=0,
                                      delete_frac=0.5)
    n_muts = sum(b.size for b in batches)
    e_max = sum(len(b.add_src) for b in batches) + 16

    def run_vectorized():
        g = DynamicGraph(n, e_max)
        for b in batches:
            g.apply(b)
        return g

    def run_loop():
        g = LoopDynamicGraph(n, e_max)
        for b in batches:
            g.apply(b)
        return g

    t_vec, _ = _time(run_vectorized, repeat=3)
    t_loop, _ = _time(run_loop, repeat=1)
    speedup = t_loop / t_vec
    row("ingest.apply_vectorized", t_vec,
        f"muts={n_muts};muts_per_s={n_muts/t_vec:.3e}")
    row("ingest.apply_loop_reference", t_loop,
        f"muts={n_muts};muts_per_s={n_muts/t_loop:.3e}")
    row("ingest.apply_speedup", 0, f"x{speedup:.1f}")
    report["mutation_ingest"] = {
        "n_mutations": int(n_muts),
        "vectorized_s": t_vec, "loop_reference_s": t_loop,
        "vectorized_muts_per_s": n_muts / t_vec,
        "loop_muts_per_s": n_muts / t_loop,
        "speedup": speedup,
    }

    # --- (b) view maintenance: delta patch vs full rebuild -------------
    # a larger snapshot so the O(E + m log m) rebuild vs O(m + k log k)
    # patch asymptotics are visible; the delta carries adds AND deletes
    n2 = 4_000 if quick else 20_000
    adds2 = 4_000 if quick else 20_000
    epochs2 = 8
    rng2 = np.random.default_rng(1)
    for churn_frac in (0.005, 0.02, 0.10):
        g = DynamicGraph(n2, (epochs2 + 1) * adds2 + 16, churn_threshold=10.0)
        for e in range(epochs2):
            g.apply(MutationBatch(
                Version(e, 0),
                add_src=rng2.integers(0, n2, adds2).astype(np.int32),
                add_dst=rng2.integers(0, n2, adds2).astype(np.int32)))
        base = g.join_view(Version(epochs2 - 1, 0))   # warm base view
        k = max(8, int(base.m * churn_frac / 2))
        rows_del = rng2.choice(g.n_edges, size=k, replace=False)
        g.apply(MutationBatch(
            Version(epochs2, 0),
            add_src=rng2.integers(0, n2, k).astype(np.int32),
            add_dst=rng2.integers(0, n2, k).astype(np.int32),
            del_src=g.src[rows_del].copy(), del_dst=g.dst[rows_del].copy()))
        v_new = Version(epochs2, 0)

        def build_delta():
            g._views.pop(v_new.pack(), None)
            return g._delta_patch(v_new.pack(), v_new)

        def build_full():
            return g._full_rebuild(v_new)

        t_delta, view_d = _time(build_delta, repeat=3)
        t_full, view_f = _time(build_full, repeat=3)
        assert view_d is not None and view_d.m == view_f.m
        row(f"ingest.view_delta_c{churn_frac}", t_delta,
            f"m={view_d.m};churn={2*k}")
        row(f"ingest.view_full_c{churn_frac}", t_full,
            f"m={view_f.m};speedup=x{t_full/t_delta:.1f}")
        report["view_build"][str(churn_frac)] = {
            "m": view_d.m, "churn_edges": int(2 * k),
            "delta_patch_s": t_delta, "full_rebuild_s": t_full,
            "speedup": t_full / t_delta,
        }

    out = pathlib.Path(__file__).resolve().parents[1] / "BENCH_ingest.json"
    _merge_bench_json(out, {"mutation_ingest": report["mutation_ingest"],
                            "view_build": report["view_build"]})
    row("ingest.report", 0, str(out))


# ------------------------------------------------- sharded ingestion (§2.3.1)
def bench_ingest_sharded(quick=False):
    """Sharded graph-store ingestion: N DynamicGraph shards behind
    dst-hash-routed DataNodes (``graph.sharded.ShardedDynamicGraph``).

    Parallelism is MEASURED, not modeled: every shard count runs once with
    the serial apply plane and once with ``parallel_apply=N`` worker
    threads, and ``parallel_wall_s`` is real wall clock for the identical
    stream the single store ingests back-to-back in the same repeat
    (median of paired per-repeat ratios — pairing cancels host-load drift
    that independent best-of-N timings do not). The stream is sized so
    per-shard batches are large enough for the vectorized apply plane to
    spend its time inside GIL-releasing NumPy kernels; thread payoff is
    therefore core-count-bound, and ``cpu_count`` rides along in the
    report so the gate (``check_bench.py``) can calibrate. Also measures
    stitch latency — merging the per-shard CSRs into the global join
    view — against the single store's full view build. Lands in
    ``BENCH_ingest.json`` under ``sharded_ingest``.

    The 1-shard configuration exercises the single-shard passthrough
    (no payload encode/route/decode); its wall clock must stay within 5%
    of the single store (asserted here — the old path ran at 0.87x).
    """
    import os
    import pathlib

    from repro.core.versioned import Version
    from repro.graph.dyngraph import DynamicGraph, synthesize_churn_stream
    from repro.graph.sharded import ShardedDynamicGraph, stitch_join_views

    n = 120_000 if quick else 200_000
    epochs = 4
    adds = 150_000 if quick else 250_000
    # moderate churn at serving-scale batches (the delete-heavy/small-batch
    # regime is covered by the ingest_graph axis)
    batches = synthesize_churn_stream(n, epochs, adds, seed=0,
                                      delete_frac=0.2)
    n_muts = sum(b.size for b in batches)
    e_max = sum(len(b.add_src) for b in batches) + 16
    v_last = Version(epochs - 1, 0)

    def run_single():
        g = DynamicGraph(n, e_max)
        for b in batches:
            g.apply(b)
        return g

    # more workers than cores thrashes the GIL instead of overlapping it;
    # CI's >= 4-CPU runners run the full 4-thread plane
    workers = max(os.cpu_count() or 1, 1)

    def run_sharded(ns, pa):
        sg = ShardedDynamicGraph(ns, n, e_max,
                                 parallel_apply=min(pa, workers))
        t0 = time.perf_counter()
        for b in batches:
            sg.apply(b)
        wall = time.perf_counter() - t0
        sg.shutdown()
        return wall, sg

    shard_counts = (1, 2, 4)
    repeats = 5
    singles = []
    reps = {ns: [] for ns in shard_counts}
    last_sg = {}          # one store per shard count (for the stitch bench)
    g_single = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        g_single = run_single()
        singles.append(time.perf_counter() - t0)
        for ns in shard_counts:
            wall, sg = run_sharded(ns, 0)
            # parallel_apply <= 1 is the serial plane, so the 1-shard
            # parallel wall IS the serial wall (no second run needed)
            pwall = wall if ns == 1 else run_sharded(ns, ns)[0]
            shard_s = sg.shard_apply_seconds
            reps[ns].append({
                "wall_s": wall,
                "route_s": max(wall - sum(shard_s), 0.0),
                "per_shard_apply_s": shard_s,
                "parallel_wall_s": pwall,
                "speedup_vs_single": singles[-1] / wall,
                "parallel_speedup_vs_single": singles[-1] / pwall,
            })
            last_sg[ns] = sg

    t_single = sorted(singles)[len(singles) // 2]
    row("ingest_sharded.single_store", t_single,
        f"muts={n_muts};muts_per_s={n_muts/t_single:.3e}")
    t_single_view, single_view = _time(
        lambda: g_single._full_rebuild(v_last), repeat=3)

    report = {"n_mutations": int(n_muts),
              "single_store_s": t_single,
              "single_store_muts_per_s": n_muts / t_single,
              "single_view_build_s": t_single_view,
              "cpu_count": os.cpu_count(),
              "shards": {}}
    for ns in shard_counts:
        by_speedup = sorted(reps[ns],
                            key=lambda r: r["parallel_speedup_vs_single"])
        rep = by_speedup[len(by_speedup) // 2]      # median-speedup repeat
        shard_s = rep["per_shard_apply_s"]
        # stitch latency with warm shard views (the steady-state query path)
        views = last_sg[ns].shard_views(v_last)
        t_stitch, stitched = _time(
            lambda: stitch_join_views(v_last, views), repeat=3)
        assert stitched.m == single_view.m, "sharded/single view diverged"
        per_shard_rate = [
            (n_muts / ns) / s if s > 0 else 0.0 for s in shard_s]
        row(f"ingest_sharded.shards{ns}", rep["parallel_wall_s"],
            f"parallel_muts_per_s={n_muts/rep['parallel_wall_s']:.3e};"
            f"serial_wall_ms={rep['wall_s']*1e3:.1f};"
            f"route_ms={rep['route_s']*1e3:.1f};"
            f"parallel_speedup_vs_single="
            f"x{rep['parallel_speedup_vs_single']:.2f}")
        row(f"ingest_sharded.stitch{ns}", t_stitch,
            f"m={stitched.m};vs_full_build=x{t_single_view/t_stitch:.2f}")
        report["shards"][str(ns)] = {
            # the worker count the parallel run ACTUALLY used (clamped to
            # the host's cores), not the shard count
            "parallel_apply": 0 if ns == 1 else min(ns, workers),
            "wall_s": rep["wall_s"],
            "route_s": rep["route_s"],
            "per_shard_apply_s": shard_s,
            "per_shard_muts_per_s": per_shard_rate,
            "parallel_wall_s": rep["parallel_wall_s"],
            "parallel_muts_per_s": n_muts / rep["parallel_wall_s"],
            "speedup_vs_single": rep["speedup_vs_single"],
            "parallel_speedup_vs_single": rep["parallel_speedup_vs_single"],
            "stitch_s": t_stitch,
            "stitched_m": int(stitched.m),
        }

    # single-shard passthrough: sharded bookkeeping on a path that routes
    # nowhere must cost <= 5% over the bare store (median-paired ratio)
    passthrough = sorted(r["speedup_vs_single"] for r in reps[1])[
        len(reps[1]) // 2]
    assert passthrough >= 0.95, (
        f"1-shard sharded ingest at {passthrough:.2f}x of the single store "
        "(>= 0.95x required — passthrough fast path regressed)")

    out = pathlib.Path(__file__).resolve().parents[1] / "BENCH_ingest.json"
    _merge_bench_json(out, {"sharded_ingest": report})
    row("ingest_sharded.report", 0, str(out))


# ---------------------------------------------- adaptive re-sharding (§2.2:
# partition adjustment from observed access patterns, measured)
def bench_resharding(quick=False):
    """Access-pattern-adaptive re-sharding vs static dst-hash on a
    zipf-skewed stream.

    Destination keys follow a Zipf rank distribution (a few hot vertices
    take most of the edges), so static ``key % n`` routing leaves one
    shard carrying well over its share. The adaptive run attaches a
    ``ShardPlanner``: when the observed per-shard load trips the
    imbalance threshold, the hot shard's key range is split
    (consistent-hash half-range migration at a seal boundary). Throughput
    is the same modeled critical path as ``ingest_sharded`` — serial
    route/dispatch plus the slowest shard's apply time — measured per
    epoch; the gate compares the post-stabilization tail (epochs after
    the last split activation, identical epoch window for both runs).
    Lands in ``BENCH_ingest.json`` under ``resharding``.
    """
    import pathlib

    from repro.core.replica import ShardPlanner
    from repro.graph.dyngraph import synthesize_skewed_stream
    from repro.graph.sharded import ShardedDynamicGraph

    # no reduced quick scale for this axis: the claim needs the hot
    # shard's APPLY to dominate the modeled critical path, and the
    # vectorized apply plane is ~7x faster than the dict-loop era — the
    # old 8k-adds quick stream degenerated into a route-bound measurement
    # where splits cannot win by construction
    n = 20_000
    epochs = 14
    adds = 20_000
    zipf_a = 1.2
    n_shards = 4
    batches = synthesize_skewed_stream(n, epochs, adds, seed=0,
                                       zipf_a=zipf_a, delete_frac=0.1)
    n_muts = sum(b.size for b in batches)
    e_max = sum(len(b.add_src) for b in batches) + 16   # per shard

    def drive(adaptive: bool):
        # min_epochs=1: with a strongly-skewed stream one sealed epoch of
        # the EWMA ledger identifies the hot shard; splitting early leaves
        # a long post-stabilization tail to measure
        planner = ShardPlanner(imbalance_threshold=1.2,
                               min_load=adds / 4.0, min_epochs=1,
                               max_shards=2 * n_shards) if adaptive else None
        sg = ShardedDynamicGraph(n_shards, n, e_max, planner=planner)
        per_epoch = []
        events = []
        prev = list(sg.shard_apply_seconds)
        for i, b in enumerate(batches):
            t0 = time.perf_counter()
            sg.apply(b)
            # no planner tick after the final epoch: its migration would
            # never apply (nothing seals the activation epoch) and the
            # report would describe a move that never happened
            ev = sg.maybe_reshard() if i < len(batches) - 1 else None
            wall = time.perf_counter() - t0
            if ev is not None:
                events.append(ev)
            cur = list(sg.shard_apply_seconds)
            prev += [0.0] * (len(cur) - len(prev))
            deltas = [c - p for c, p in zip(cur, prev, strict=True)]
            prev = cur
            # modeled parallel critical path for this epoch: serial
            # routing/dispatch + the slowest shard's apply
            per_epoch.append({
                "muts": b.size,
                "route_s": max(wall - sum(deltas), 0.0),
                "max_shard_s": max(deltas),
                "shard_s": deltas,
            })
        return sg, per_epoch, events

    def tail_stats(per_epoch, tail_start):
        tail = per_epoch[tail_start:]
        route = sum(t["route_s"] for t in tail)
        max_shard = sum(t["max_shard_s"] for t in tail)
        crit = route + max_shard
        muts = sum(t["muts"] for t in tail)
        return crit, muts / max(crit, 1e-12), max_shard, route

    # paired repeats, median speedup (same rationale as ingest_sharded;
    # 5 repeats because the per-epoch critical path is ms-scale and noisy)
    reps = []
    for _ in range(5):
        _, static_epochs, _ = drive(adaptive=False)
        sg_a, adaptive_epochs, events = drive(adaptive=True)
        tail_start = (max(e["activation_epoch"] for e in events) + 1
                      if events else epochs - 4)
        # keep >= 2 tail epochs; when this clamp pulls an activation epoch
        # into the tail it charges the one-off migration apply to the
        # ADAPTIVE side, so the gate only ever errs against adaptive
        tail_start = min(tail_start, epochs - 2)
        s_crit, s_tput, s_max, s_route = tail_stats(static_epochs, tail_start)
        a_crit, a_tput, a_max, a_route = tail_stats(adaptive_epochs,
                                                    tail_start)
        reps.append({
            "tail_start_epoch": tail_start,
            "static_tail_critical_s": s_crit,
            "static_tail_muts_per_s": s_tput,
            "static_tail_max_shard_s": s_max,
            "static_tail_route_s": s_route,
            "adaptive_tail_critical_s": a_crit,
            "adaptive_tail_muts_per_s": a_tput,
            "adaptive_tail_max_shard_s": a_max,
            "adaptive_tail_route_s": a_route,
            "adaptive_vs_static_speedup": a_tput / s_tput,
            "splits": events,
            "final_shards": sg_a.n_shards,
        })
    rep = sorted(reps, key=lambda r: r["adaptive_vs_static_speedup"])[
        len(reps) // 2]

    row("resharding.static_tail", rep["static_tail_critical_s"],
        f"muts_per_s={rep['static_tail_muts_per_s']:.3e};"
        f"max_shard_ms={rep['static_tail_max_shard_s']*1e3:.1f}")
    row("resharding.adaptive_tail", rep["adaptive_tail_critical_s"],
        f"muts_per_s={rep['adaptive_tail_muts_per_s']:.3e};"
        f"max_shard_ms={rep['adaptive_tail_max_shard_s']*1e3:.1f};"
        f"shards={rep['final_shards']};"
        f"speedup=x{rep['adaptive_vs_static_speedup']:.2f}")
    for ev in rep["splits"]:
        row("resharding.split", 0,
            f"epoch={ev['activation_epoch']};shard{ev['source']}->"
            f"{ev['target']};migrated={ev['migrated_edges']}")

    report = {
        "n_vertices": n, "n_mutations": int(n_muts), "zipf_a": zipf_a,
        "initial_shards": n_shards, "epochs": epochs,
        **rep,
    }
    out = pathlib.Path(__file__).resolve().parents[1] / "BENCH_ingest.json"
    _merge_bench_json(out, {"resharding": report})
    row("resharding.report", 0, str(out))


# ------------------------------------------------- online serving (§3.3 axis 1
# on the sharded store: the integrated online/offline claim, measured)
def bench_serve_graph(quick=False):
    """Graph query serving on live sharded snapshots.

    Drives a ``GraphQueryServer`` through a build-up phase plus a
    steady-state tail of small-churn epochs (the serving regime: large
    accumulated graph, small per-epoch delta), submitting a mixed query
    window every epoch while ingestion streams. Reports steady-state query
    latency percentiles (windows answered by vectorized jitted calls whose
    traces survive across snapshots thanks to pow2 edge/source padding)
    and warm-started vs cold PageRank convergence on the final serving
    snapshot. Lands in ``BENCH_ingest.json`` under ``serve_graph``.
    """
    import pathlib

    from repro.core.versioned import Version
    from repro.graph import compute as gcomp
    from repro.graph.dyngraph import MutationBatch, synthesize_churn_stream
    from repro.graph.query import (DegreeTopK, KHop, PageRankQuery,
                                   Reachability)
    from repro.graph.sharded import ShardedDynamicGraph
    from repro.launch.serve_graph import GraphQueryServer

    n = 2_000 if quick else 10_000
    build_epochs = 4 if quick else 6
    adds = 1_000 if quick else 5_000
    tail_epochs = 6 if quick else 8
    tail_adds = max(2, n // 1000)        # ~0.1% of vertices per epoch
    # online-serving tolerance: ranks good to 1e-4 — loose enough that the
    # warm start's head start is most of the distance to convergence
    tol = 1e-4
    rng = np.random.default_rng(1)
    batches = synthesize_churn_stream(n, build_epochs, adds, seed=0,
                                      delete_frac=0.1)
    for e in range(build_epochs, build_epochs + tail_epochs):
        batches.append(MutationBatch(
            Version(e, 0),
            add_src=rng.integers(0, n, tail_adds).astype(np.int32),
            add_dst=rng.integers(0, n, tail_adds).astype(np.int32)))
    e_max = sum(len(b.add_src) for b in batches) + 16
    sg = ShardedDynamicGraph(4, n, e_max)
    server = GraphQueryServer(sg, prewarm_pagerank=True, tol=tol,
                              max_iter=200)

    qrng = np.random.default_rng(2)
    steady_lat: list[float] = []
    for b in batches:
        server.step(b)                         # ingestion tick
        for _ in range(8):
            server.submit(KHop(int(qrng.integers(0, n)), k=2))
        for _ in range(4):
            server.submit(Reachability(int(qrng.integers(0, n)),
                                       int(qrng.integers(0, n)),
                                       max_hops=8))
        server.submit(DegreeTopK(16))
        server.submit(PageRankQuery(top_k=16))
        results = server.flush()
        if b.version.epoch >= build_epochs:    # steady state only
            steady_lat.extend(r.latency_s for r in results)

    lat = np.asarray(steady_lat)
    p50, p95 = (float(np.percentile(lat, q)) for q in (50, 95))
    stats = server.stats()
    v_last = batches[-1].version
    view_last = sg.join_view(v_last)
    warm = server.engine.pagerank(view_last)   # cache hit: warm-chain result
    cold = gcomp.pagerank(view_last, tol=tol, max_iter=200)
    reduction = cold.iterations / max(warm.iterations, 1)
    n_queries = stats.served
    calls = sum(stats.vectorized_calls.values())
    row("serve_graph.query_latency", p50,
        f"p95_us={p95*1e6:.1f};m={view_last.m};steady_windows={tail_epochs}")
    row("serve_graph.batching", 0,
        f"queries={n_queries};vectorized_calls={calls}")
    row("serve_graph.pagerank_warm_vs_cold", 0,
        f"warm_iters={warm.iterations};cold_iters={cold.iterations};"
        f"reduction=x{reduction:.1f}")
    report = {
        "n_vertices": n, "n_shards": sg.n_shards,
        "edges_final": int(view_last.m),
        "queries_total": int(n_queries),
        "vectorized_calls_total": int(calls),
        "steady_state_epochs": tail_epochs,
        "query_p50_s": p50, "query_p95_s": p95,
        "warm_pagerank_iters": int(warm.iterations),
        "cold_pagerank_iters": int(cold.iterations),
        "warm_start_iter_reduction": reduction,
        "rank_warm_starts": stats.rank_warm_starts,
        "rank_cold_starts": stats.rank_cold_starts,
    }
    out = pathlib.Path(__file__).resolve().parents[1] / "BENCH_ingest.json"
    _merge_bench_json(out, {"serve_graph": report})
    row("serve_graph.report", 0, str(out))


# ------------------------------------------------- online serving (§3.3 axis 1
# over the wire: the RPC front + epoch-pipelined reads, measured)
def bench_serve_rpc(quick=False):
    """Concurrent RPC serving under simultaneous heavy ingest.

    Eight socket clients hammer the ``launch.rpc`` front of one
    ``GraphQueryServer`` while the ingest thread streams large churn
    epochs, in BOTH serving disciplines: ``single_lock``
    (``pipeline_reads=False`` — every window pins its snapshot under the
    write lock, exactly the pre-split behavior, so queries convoy behind
    in-flight shard applies) and ``pipelined`` (the seal-swap discipline:
    windows answer at the published sealed epoch *e* while epoch *e+1*'s
    applies run). Reports sustained client-observed QPS and p50/p95/p99
    round-trip latency per mode, the pipelined-vs-single-lock speedups
    ``check_bench.py`` gates (> 1.2x QPS and > 1.2x median round trip —
    the convoy does not shrink with core count, so both hold even on a
    one-core host), and a replay-oracle audit: EVERY
    successful answer from both modes is recomputed on a single
    non-sharded store at its served version and compared byte for byte.

    Each mode's ingest window is only a few seconds, so a single sample
    is at the mercy of OS scheduling: the QPS speedup is the MEDIAN over
    paired repeats run in alternating order (so neither mode
    systematically enjoys a warmer process), and the latency percentiles
    pool every repeat's round trips.
    PageRank is excluded from the client mix — its warm-started ranks are
    reproducible only by replaying the whole warm chain, not by a
    stateless oracle. Lands in ``BENCH_ingest.json`` under ``serve_rpc``.
    """
    import os
    import pathlib
    import threading

    from repro.core.versioned import Version
    from repro.graph.dyngraph import DynamicGraph, synthesize_churn_stream
    from repro.graph.query import (DegreeTopK, KHop, Reachability,
                                   SnapshotQueryEngine)
    from repro.graph.sharded import ShardedDynamicGraph
    from repro.launch.rpc import GraphRPCClient, GraphRPCServer
    from repro.launch.serve_graph import GraphQueryServer

    # "heavy ingest" is load-bearing: the convoy penalty a single-lock
    # pin pays is the residual of the in-flight epoch apply, so epochs
    # must be large enough that an apply takes at least a query round
    # trip (~50ms warm) and the inter-epoch delay small enough that the
    # write plane stays busy — tiny epochs make both disciplines measure
    # the same (nothing to convoy behind) and the axis gates noise
    n = 2_000 if quick else 8_000
    epochs = 24
    adds = 50_000 if quick else 150_000
    ingest_delay_s = 0.002
    n_clients = 8
    repeats = 5 if quick else 3
    # high churn: deletes add apply work (chain walks) while keeping the
    # live edge set — and so per-query cost — smaller, which is what
    # keeps the apply/query cost ratio (the convoy) large
    batches = synthesize_churn_stream(n, epochs, adds, seed=0,
                                      delete_frac=0.3)
    e_max = sum(len(b.add_src) for b in batches) + 16

    def warmup(server):
        # prime every jitted trace the client mix can hit (k-hop and
        # reachability pad source counts to pow2: window sizes 1..8 hit
        # the padded shapes 1/2/4/8) so the measured window is execution,
        # not compilation — both modes get the identical warm start
        rng = np.random.default_rng(7)
        for sz in (8, 4, 2, 1):
            for _ in range(sz):
                server.submit(KHop(int(rng.integers(0, n)), k=2))
            server.flush()
            for _ in range(sz):
                server.submit(Reachability(int(rng.integers(0, n)),
                                           int(rng.integers(0, n)),
                                           max_hops=6))
            server.flush()
        server.submit(DegreeTopK(8))
        server.flush()

    def run_mode(pipeline_reads: bool):
        sg = ShardedDynamicGraph(4, n, e_max)
        server = GraphQueryServer(sg, pipeline_reads=pipeline_reads)
        server.step(batches[0])                 # first epoch queryable
        warmup(server)
        front = GraphRPCServer(server, port=0).start()
        host, port = front.address
        stop = threading.Event()
        lat: list[list[float]] = [[] for _ in range(n_clients)]
        answered: list[list] = [[] for _ in range(n_clients)]
        failures: list[BaseException] = []

        def client(ci: int) -> None:
            rng = np.random.default_rng(1000 + ci)
            # a failure inside a client thread must fail the RUN, not
            # silently thin the sample set and skew the percentiles —
            # collect it here and re-raise on the main thread after join
            try:
                with GraphRPCClient(host, port) as c:
                    while not stop.is_set():
                        roll = rng.random()
                        if roll < 0.7:
                            q = KHop(int(rng.integers(0, n)), k=2)
                        elif roll < 0.9:
                            q = Reachability(int(rng.integers(0, n)),
                                             int(rng.integers(0, n)),
                                             max_hops=6)
                        else:
                            q = DegreeTopK(8)
                        t0 = time.perf_counter()
                        r = c.query(q)
                        lat[ci].append(time.perf_counter() - t0)
                        assert r.ok, r.error
                        answered[ci].append((q, r))
            except BaseException as exc:
                failures.append(exc)
                stop.set()

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_clients)]
        t0 = time.perf_counter()
        ingest = server.start_background_ingest(iter(batches[1:]),
                                                delay_s=ingest_delay_s)
        for t in threads:
            t.start()
        ingest.join()                 # heavy ingest defines the window
        stop.set()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        front.stop()
        if failures:
            raise failures[0]
        flat = np.asarray([x for per in lat for x in per])
        s = server.stats()
        mode = {
            "qps": float(len(flat) / wall),
            "queries": int(len(flat)),
            "windows": int(s.windows),
            "wall_s": float(wall),
            "latencies_s": flat,     # pooled across repeats by aggregate()
        }
        return mode, [qr for per in answered for qr in per]

    runs = {False: [], True: []}     # mode -> [(mode_dict, answers)]
    for rep in range(repeats):
        order = (False, True) if rep % 2 == 0 else (True, False)
        for pipeline_reads in order:
            runs[pipeline_reads].append(run_mode(pipeline_reads))

    def aggregate(mode_runs):
        lats = np.concatenate([np.asarray(m["latencies_s"])
                               for m, _ in mode_runs])
        return {
            "qps": float(np.median([m["qps"] for m, _ in mode_runs])),
            "p50_s": float(np.percentile(lats, 50)),
            "p95_s": float(np.percentile(lats, 95)),
            "p99_s": float(np.percentile(lats, 99)),
            "queries": int(sum(m["queries"] for m, _ in mode_runs)),
            "windows": int(sum(m["windows"] for m, _ in mode_runs)),
            "wall_s": float(sum(m["wall_s"] for m, _ in mode_runs)),
            "repeats": len(mode_runs),
        }

    single = aggregate(runs[False])
    pipe = aggregate(runs[True])
    answers_single = [qr for _, ans in runs[False] for qr in ans]
    answers_pipe = [qr for _, ans in runs[True] for qr in ans]
    speedup = float(np.median(
        [p["qps"] / s["qps"] for (s, _), (p, _)
         in zip(runs[False], runs[True], strict=True)]))
    # the round-trip MEDIAN is the convoy effect itself: single-lock
    # round trips sit out the in-flight whole-epoch apply before they
    # can pin, pipelined ones answer at the published snapshot
    p50_improvement = single["p50_s"] / pipe["p50_s"]
    p99_improvement = single["p99_s"] / pipe["p99_s"]

    # replay oracle: ONE non-sharded store over the same stream; every
    # answer from both modes recomputed at its served version, compared
    # byte for byte (grouped per version so the oracle batches too)
    g = DynamicGraph(n, e_max)
    for b in batches:
        g.apply(b)
    eng = SnapshotQueryEngine()
    by_version: dict[int, list] = {}
    for q, r in answers_single + answers_pipe:
        by_version.setdefault(r.version.pack(), []).append((q, r))
    audited = 0
    mismatches = 0
    for packed, items in sorted(by_version.items()):
        view = g.join_view(Version.unpack(packed))
        vals = eng.execute(view, [q for q, _ in items])
        for (q, r), exp in zip(items, vals, strict=True):
            if isinstance(exp, tuple):
                same = all(np.asarray(a).tobytes() == np.asarray(b).tobytes()
                           for a, b in zip(r.value, exp, strict=True))
            elif isinstance(exp, np.ndarray):
                same = np.asarray(r.value).tobytes() == exp.tobytes()
            else:
                same = r.value == exp
            audited += 1
            mismatches += 0 if same else 1
    assert mismatches == 0, f"{mismatches}/{audited} answers diverged"

    for rep, ((s, _), (p, _)) in enumerate(
            zip(runs[False], runs[True], strict=True)):
        row(f"serve_rpc.rep{rep}", 0,
            f"single_qps={s['qps']:.1f};pipelined_qps={p['qps']:.1f}")
    row("serve_rpc.single_lock", single["p50_s"],
        f"qps={single['qps']:.1f};p99_us={single['p99_s']*1e6:.1f}")
    row("serve_rpc.pipelined", pipe["p50_s"],
        f"qps={pipe['qps']:.1f};p99_us={pipe['p99_s']*1e6:.1f}")
    row("serve_rpc.pipelining", 0,
        f"qps_speedup=x{speedup:.2f};p50_improvement=x{p50_improvement:.2f};"
        f"p99_improvement=x{p99_improvement:.2f};clients={n_clients}")
    row("serve_rpc.oracle_audit", 0,
        f"audited={audited};mismatches={mismatches}")
    report = {
        "n_vertices": n, "epochs": epochs, "adds_per_epoch": adds,
        "n_clients": n_clients,
        "cpu_count": os.cpu_count(),
        "single_lock": single,
        "pipelined": pipe,
        "pipelined_vs_single_lock_speedup": speedup,
        "p50_improvement": p50_improvement,
        "p99_improvement": p99_improvement,
        "answers_audited": audited,
        "oracle_mismatches": mismatches,
    }
    out = pathlib.Path(__file__).resolve().parents[1] / "BENCH_ingest.json"
    _merge_bench_json(out, {"serve_rpc": report})
    row("serve_rpc.report", 0, str(out))


# ----------------------------------------------- low-latency fast path
# (ROADMAP tail-latency item: versioned result cache + two-lane
# scheduler + publish-time trace prewarm, measured against the PR 8
# single-queue discipline)
def bench_serve_fastpath(quick=False):
    """Cheap-query tail latency under an expensive-query convoy, with
    concurrent ingest.

    Eight socket clients drive a mixed workload — zipf-hot k-hop and
    degree-top-k (the cheap kinds; the hot pool makes the result cache
    earn hits), cold reachability, and ~10% PageRank (the convoy
    generator: a multi-iteration window that holds the engine for tens
    of milliseconds) — against BOTH serving disciplines: ``single_queue``
    (``two_lane=False, result_cache=False, prewarm_traces=False`` — the
    PR 8 shape: one dispatcher, one queue, every cheap round trip can
    land behind an in-flight PageRank window) and ``fastpath`` (the
    two-lane scheduler + versioned result cache + publish-time trace
    prewarm). Reports per-kind pooled p50/p95/p99 round trips and the
    cheap-lane (k-hop + degree-top-k pooled) improvements
    ``check_bench.py`` gates: ``cheap_p99_improvement >= 2.0`` — the
    convoy is structural, not a tuning artifact — plus a non-zero cache
    hit rate and a zero-mismatch replay audit (every successful
    non-PageRank answer from both modes recomputed byte-for-byte on a
    single non-sharded store at its served version; PageRank's
    warm-start chain is serving-history-dependent, so it is workload,
    not auditable oracle).

    Same repeat discipline as ``serve_rpc``: paired repeats in
    alternating order, improvements from percentiles pooled across
    repeats. Lands in ``BENCH_ingest.json`` under ``serve_fastpath``.

    The stream separates the two costs the axis must keep apart. One
    big seed batch sets a large STANDING edge set — that is what makes
    a PageRank window expensive (per-iteration cost is O(edges)), i.e.
    the convoy the baseline pays. The churn epochs after it are small
    (adds balanced by 50% self-deletes), so each apply is a short
    burst: the apply plane's host-side chain walks hold the GIL, and on
    a small host a long apply would floor BOTH modes' cheap tails at
    the burst length, drowning the scheduling difference under
    ingest-thread noise. Small epochs keep that floor low while the
    live edge count stays inside ONE pow2 bucket for the whole run
    (seed + churn steady state both inside ``(P/2, P]`` for the bucket
    ``P`` the warmup primed) — so every jit trace stays hot in both
    modes and the axis measures the scheduling disciplines, not retrace
    luck: a mid-run bucket step would put a multi-hundred-ms compile
    storm into whichever mode's window it lands in (on a 1-core host
    the prewarm thread's compiles steal the only core from the cheap
    lane — exactly the one-off cost prewarm exists to absorb, but a
    latency-percentile axis must not gate on where that one-off
    lands). Bucket-step retrace behavior is covered by the prewarm
    tests, not timed here.
    """
    import dataclasses
    import os
    import pathlib
    import threading

    from repro.core.versioned import Version
    from repro.graph.dyngraph import DynamicGraph, synthesize_churn_stream
    from repro.graph.query import (DegreeTopK, KHop, PageRankQuery,
                                   Reachability, SnapshotQueryEngine,
                                   query_kind)
    from repro.graph.sharded import ShardedDynamicGraph
    from repro.launch.rpc import GraphRPCClient, GraphRPCServer
    from repro.launch.serve_graph import CHEAP_KINDS, GraphQueryServer

    n = 2_000 if quick else 6_000
    # the full run doubles the graph, which doubles every kernel — so it
    # runs more epochs (a p99 needs many convoy events averaged, not
    # longer ones) and TRIMS the PageRank sweep to hold the convoy at a
    # few hundred ms. The convoy is structural at any size — every
    # single-queue cheap query can land behind one — but its absolute
    # size sets where the DODGED tail lands: on a small host the lanes
    # timeshare one core, so an expensive window several seconds long
    # floors the cheap lane's p99 at raw compute scarcity in both modes
    # and the axis stops measuring scheduling. Same reason the churn
    # burst stays at the quick size: the apply plane's GIL-held chain
    # walks stall both modes equally, and a 2x burst just dilutes the
    # tail ratio with mode-independent noise.
    epochs = 24 if quick else 48
    max_iter = 150 if quick else 40
    # bucket-stable sizing (see docstring): seed 100k + churn steady
    # state stays in (65k, 131k] for quick; 200k + steady state in
    # (131k, 262k] for full. The churn's 50% deletes only ever target
    # its own stream's edges, so the two streams concatenate cleanly.
    seed_edges = 100_000 if quick else 200_000
    churn_adds = 6_000
    ingest_delay_s = 0.08 if quick else 0.1
    n_clients = 8
    repeats = 3
    hot_pool_size = 16
    churn = synthesize_churn_stream(n, epochs - 1, churn_adds,
                                    seed=12, delete_frac=0.5)
    batches = (synthesize_churn_stream(n, 1, seed_edges, seed=11)
               + [dataclasses.replace(
                      b, version=Version(b.version.epoch + 1, 0))
                  for b in churn])
    e_max = sum(len(b.add_src) for b in batches) + 16
    hot_pool = np.random.default_rng(2).integers(0, n, hot_pool_size)

    def pick_query(rng):
        roll = rng.random()
        if roll < 0.55:
            # zipf-hot: most k-hops land on the small hot pool, so the
            # fastpath's cache sees the same fingerprints again within a
            # version while the baseline recomputes every time
            src = (int(hot_pool[rng.integers(0, hot_pool_size)])
                   if rng.random() < 0.8 else int(rng.integers(0, n)))
            return KHop(src, k=2)
        if roll < 0.75:
            return Reachability(int(rng.integers(0, n)),
                                int(rng.integers(0, n)), max_hops=6)
        if roll < 0.9:
            return DegreeTopK(8)
        return PageRankQuery(top_k=8)

    def warmup(server):
        rng = np.random.default_rng(7)
        for sz in (8, 4, 2, 1):
            for _ in range(sz):
                server.submit(KHop(int(rng.integers(0, n)), k=2))
            server.flush()
            for _ in range(sz):
                server.submit(Reachability(int(rng.integers(0, n)),
                                           int(rng.integers(0, n)),
                                           max_hops=6))
            server.flush()
        server.submit(DegreeTopK(8))
        server.submit(PageRankQuery(top_k=8))
        server.flush()

    def run_mode(fastpath: bool):
        sg = ShardedDynamicGraph(4, n, e_max)
        # tol=0 pins every PageRank window at the full max_iter sweep —
        # the convoy must be a fixed structural cost, not whatever the
        # warm-start chain happens to converge to on the low-churn
        # stream (identical in both modes, so the comparison is fair)
        server = GraphQueryServer(
            sg, two_lane=fastpath, result_cache=fastpath,
            prewarm_traces=fastpath, tol=0.0, max_iter=max_iter)
        server.step(batches[0])
        warmup(server)
        front = GraphRPCServer(server, port=0).start()
        host, port = front.address
        stop = threading.Event()
        lat: list[list] = [[] for _ in range(n_clients)]
        answered: list[list] = [[] for _ in range(n_clients)]
        failures: list[BaseException] = []

        def client(ci: int) -> None:
            rng = np.random.default_rng(500 + ci)
            # a failure inside a client thread must fail the RUN, not
            # silently thin the sample set and skew the percentiles —
            # collect it here and re-raise on the main thread after join
            try:
                with GraphRPCClient(host, port) as c:
                    while not stop.is_set():
                        q = pick_query(rng)
                        t0 = time.perf_counter()
                        r = c.query(q)
                        lat[ci].append((query_kind(q),
                                        time.perf_counter() - t0))
                        assert r.ok, r.error
                        answered[ci].append((q, r))
            except BaseException as exc:
                failures.append(exc)
                stop.set()

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_clients)]
        t0 = time.perf_counter()
        ingest = server.start_background_ingest(iter(batches[1:]),
                                                delay_s=ingest_delay_s)
        for t in threads:
            t.start()
        ingest.join()               # concurrent ingest defines the window
        stop.set()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        s = server.stats()
        front.stop()
        if failures:
            raise failures[0]
        flat = [x for per in lat for x in per]
        mode = {
            "qps": float(len(flat) / wall),
            "queries": int(len(flat)),
            "windows": int(s.windows),
            "wall_s": float(wall),
            "kind_lat": flat,        # pooled across repeats by aggregate()
            "cache_hits": int(s.result_cache_hits),
            "cache_misses": int(s.result_cache_misses),
            "cache_hit_rate": float(s.result_cache_hit_rate),
            "prewarm_runs": int(s.prewarm_runs),
        }
        return mode, [(q, r) for per in answered for q, r in per
                      if not isinstance(q, PageRankQuery)]

    runs = {False: [], True: []}
    for rep in range(repeats):
        order = (False, True) if rep % 2 == 0 else (True, False)
        for fastpath in order:
            runs[fastpath].append(run_mode(fastpath))

    def pooled(mode_runs, kinds=None):
        vals = np.asarray([t for m, _ in mode_runs
                           for k, t in m["kind_lat"]
                           if kinds is None or k in kinds])
        return {q: float(np.percentile(vals, p))
                for q, p in (("p50_s", 50), ("p95_s", 95), ("p99_s", 99))}

    def aggregate(mode_runs):
        agg = pooled(mode_runs)
        agg["per_kind"] = {
            kind: pooled(mode_runs, {kind})
            for kind in ("k_hop", "reachability", "degree_topk",
                         "pagerank")}
        agg["cheap"] = pooled(mode_runs, CHEAP_KINDS)
        agg.update({
            "qps": float(np.median([m["qps"] for m, _ in mode_runs])),
            "queries": int(sum(m["queries"] for m, _ in mode_runs)),
            "windows": int(sum(m["windows"] for m, _ in mode_runs)),
            "wall_s": float(sum(m["wall_s"] for m, _ in mode_runs)),
            "repeats": len(mode_runs),
            "cache_hits": int(sum(m["cache_hits"] for m, _ in mode_runs)),
            "cache_hit_rate": float(np.mean(
                [m["cache_hit_rate"] for m, _ in mode_runs])),
            "prewarm_runs": int(sum(m["prewarm_runs"]
                                    for m, _ in mode_runs)),
        })
        return agg

    single = aggregate(runs[False])
    fast = aggregate(runs[True])
    cheap_p50_improvement = single["cheap"]["p50_s"] / fast["cheap"]["p50_s"]
    cheap_p99_improvement = single["cheap"]["p99_s"] / fast["cheap"]["p99_s"]

    # replay oracle: one non-sharded store, every non-PageRank answer
    # from BOTH modes recomputed at its served version, byte for byte
    g = DynamicGraph(n, e_max)
    for b in batches:
        g.apply(b)
    eng = SnapshotQueryEngine(result_cache=False)
    by_version: dict[int, list] = {}
    for _, answers in runs[False] + runs[True]:
        for q, r in answers:
            by_version.setdefault(r.version.pack(), []).append((q, r))
    audited = mismatches = 0
    for packed, items in sorted(by_version.items()):
        view = g.join_view(Version.unpack(packed))
        vals = eng.execute(view, [q for q, _ in items])
        for (q, r), exp in zip(items, vals, strict=True):
            if isinstance(exp, tuple):
                same = all(np.asarray(a).tobytes() == np.asarray(b).tobytes()
                           for a, b in zip(r.value, exp, strict=True))
            elif isinstance(exp, np.ndarray):
                same = np.asarray(r.value).tobytes() == exp.tobytes()
            else:
                same = r.value == exp
            audited += 1
            mismatches += 0 if same else 1
    assert mismatches == 0, f"{mismatches}/{audited} answers diverged"

    row("serve_fastpath.single_queue", single["cheap"]["p50_s"],
        f"cheap_p99_us={single['cheap']['p99_s']*1e6:.1f};"
        f"qps={single['qps']:.1f}")
    row("serve_fastpath.fastpath", fast["cheap"]["p50_s"],
        f"cheap_p99_us={fast['cheap']['p99_s']*1e6:.1f};"
        f"qps={fast['qps']:.1f};hit_rate={fast['cache_hit_rate']:.2f};"
        f"prewarms={fast['prewarm_runs']}")
    row("serve_fastpath.improvement", 0,
        f"cheap_p50=x{cheap_p50_improvement:.2f};"
        f"cheap_p99=x{cheap_p99_improvement:.2f};clients={n_clients}")
    row("serve_fastpath.oracle_audit", 0,
        f"audited={audited};mismatches={mismatches}")
    report = {
        "n_vertices": n, "epochs": epochs, "seed_edges": seed_edges,
        "churn_adds_per_epoch": churn_adds, "pagerank_max_iter": max_iter,
        "n_clients": n_clients, "hot_pool": hot_pool_size,
        "cpu_count": os.cpu_count(),
        "single_queue": single,
        "fastpath": fast,
        "cheap_p50_improvement": float(cheap_p50_improvement),
        "cheap_p99_improvement": float(cheap_p99_improvement),
        "cache_hits": int(fast["cache_hits"]),
        "cache_hit_rate": float(fast["cache_hit_rate"]),
        "prewarm_runs": int(fast["prewarm_runs"]),
        "answers_audited": int(audited),
        "oracle_mismatches": int(mismatches),
    }
    out = pathlib.Path(__file__).resolve().parents[1] / "BENCH_ingest.json"
    _merge_bench_json(out, {"serve_fastpath": report})
    row("serve_fastpath.report", 0, str(out))


# ------------------------------------------- replica-coherent read plane
# (§2.2 data management on the live store: hot-vertex mirrors +
# locality-aware query routing, measured)
def bench_replica_locality(quick=False):
    """Replica-first routing vs global-view execution on a zipf-hot
    query stream.

    The graph is scale-free (zipf destinations: a small head of hub
    vertices receives most edges) and the query stream is zipf-hot over
    those hubs — the regime the replica plane exists for: most frontier
    mass lands on a few dozen vertices, so mirroring their adjacency
    lets same-kind windows resolve expansions locally instead of
    touching every shard's CSR. Two servers drive the identical
    mutation + query stream over 4 shards, one with ``replicate_hot``
    on and one off, alternating order across paired repeats. After a
    heat-warmup phase (the ledger needs sealed epochs of query touches
    before ``MirrorPlanner`` nominates; the warmup also primes the
    routed jit traces so the timed window measures execution), the
    steady-state phase measures:

    * mean fan-out — shards touched per routed group, from the engine's
      ``fanout_hist`` delta over the timed phase — against the
      structural fan-out of global-view execution (every window reads
      the stitched CSR of all ``n_shards`` shards). The gate is
      ``fanout_reduction >= 1.5`` at 4 shards;
    * p50/p99 submit-to-answer latency per mode (pooled across
      repeats); the gate is ``p99_improvement > 1.15`` — mirrored
      windows run the frontier kernels on pow2-padded edge subsets
      orders of magnitude smaller than the global CSR;
    * a replay oracle: EVERY answer from BOTH modes is recomputed on a
      single non-sharded store at its served version and compared byte
      for byte (mirrors must be invisible in answers — I10), exactly
      the ``serve_rpc`` audit discipline.

    Lands in ``BENCH_ingest.json`` under ``replica_locality``.
    """
    import pathlib

    from repro.core.versioned import Version
    from repro.graph.dyngraph import (DynamicGraph, MutationBatch,
                                      synthesize_skewed_stream)
    from repro.graph.query import (KHop, Reachability, SnapshotQueryEngine)
    from repro.graph.sharded import ShardedDynamicGraph
    from repro.launch.serve_graph import GraphQueryServer

    n = 6_000 if quick else 20_000
    n_shards = 4
    build_epochs = 4 if quick else 5
    adds = 5_000 if quick else 12_000
    warm_epochs = 2            # mirrors live from the 2nd warm publish
    steady_epochs = 4 if quick else 6
    tail_adds = max(2, n // 1000)
    zipf_a = 1.8               # scale-free head: top-48 dsts carry ~97%
    pool_size = 48             # hot anchor pool (< mirror_k: full cover)
    mirror_k = 64
    # zipf-tail pool anchors settle at EWMA heat well below 1.0 (decay
    # 0.5/epoch over ~38 touches split zipf-wise across 48 anchors), so
    # the nomination floor must sit below the tail's steady state
    mirror_min_heat = 0.05
    repeats = 2 if quick else 3

    batches = synthesize_skewed_stream(n, build_epochs, adds, seed=0,
                                       zipf_a=zipf_a)
    rng = np.random.default_rng(1)
    total_epochs = build_epochs + warm_epochs + steady_epochs
    for e in range(build_epochs, total_epochs):
        batches.append(MutationBatch(
            Version(e, 0),
            add_src=rng.integers(0, n, tail_adds).astype(np.int32),
            add_dst=rng.integers(0, n, tail_adds).astype(np.int32)))
    e_max = sum(len(b.add_src) for b in batches) + 16

    # the replay oracle (one non-sharded store over the same stream)
    # doubles as the hub finder: the hot pool is the in-degree head of
    # the final graph — the vertices most frontier mass lands on
    g_oracle = DynamicGraph(n, e_max)
    for b in batches:
        g_oracle.apply(b)
    final_view = g_oracle.join_view(batches[-1].version)
    indeg = np.asarray(final_view.in_degree)
    pool = np.argsort(-indeg, kind="stable")[:pool_size].astype(np.int64)
    w = 1.0 / np.arange(1, pool_size + 1) ** 1.1     # zipf-hot anchors
    w /= w.sum()

    def windows_for_epoch(qrng):
        """One epoch's query windows, each flushed alone so one flush is
        one same-kind routed group. Reachability endpoints both come
        from the pool (hub-to-hub connectivity) so the heat ledger's
        candidate set stays the pool."""
        wins = []
        for _ in range(7):
            wins.append([KHop(int(s), k=1)
                         for s in qrng.choice(pool, 4, p=w)])
        for _ in range(3):
            wins.append([KHop(int(s), k=2)
                         for s in qrng.choice(pool, 2, p=w)])
        for _ in range(2):
            wins.append([Reachability(int(s), int(d), max_hops=2)
                         for s, d in zip(qrng.choice(pool, 2, p=w),
                                         qrng.choice(pool, 2, p=w))])
        return wins

    def run_mode(replicate: bool):
        sg = ShardedDynamicGraph(n_shards, n, e_max)
        server = GraphQueryServer(sg, replicate_hot=replicate,
                                  mirror_k=mirror_k,
                                  mirror_min_heat=mirror_min_heat)
        qrng = np.random.default_rng(42)     # identical stream per mode
        lats: list[float] = []
        answered = []
        stats0: dict = {}
        for b in batches:
            server.step(b)
            e = b.version.epoch
            if e < build_epochs - 1:
                continue                     # build phase: ingest only
            timed = e >= build_epochs + warm_epochs
            if timed and not stats0:
                # telemetry baseline: warmup windows route before the
                # heat ledger warms (0-mirror plans fan out wide) and
                # must not pollute the steady-state fan-out numbers
                stats0 = server.engine.replica_stats()
            for win in windows_for_epoch(qrng):
                for q in win:
                    server.submit(q)
                results = server.flush()
                if timed:
                    lats.extend(r.latency_s for r in results)
                    answered.extend(results)
        stats1 = server.engine.replica_stats()
        s = server.stats()
        sg.shutdown()
        hist = {k: stats1["fanout_hist"].get(k, 0) - stats0.get(
                    "fanout_hist", {}).get(k, 0)
                for k in stats1["fanout_hist"]}
        return {
            "latencies_s": lats,
            "routed_windows": (stats1["routed_windows"]
                               - stats0.get("routed_windows", 0)),
            "fanout_hist": {k: v for k, v in hist.items() if v},
            "mirror_hits": (stats1["mirror_hits"]
                            - stats0.get("mirror_hits", 0)),
            "mirror_misses": (stats1["mirror_misses"]
                              - stats0.get("mirror_misses", 0)),
            "mirrored_vertices": s.mirrored_vertices,
        }, answered

    runs = {False: [], True: []}
    for rep in range(repeats):
        order = (False, True) if rep % 2 == 0 else (True, False)
        for replicate in order:
            runs[replicate].append(run_mode(replicate))

    def pooled(mode_runs):
        lat = np.concatenate([np.asarray(m["latencies_s"])
                              for m, _ in mode_runs])
        return {"p50_s": float(np.percentile(lat, 50)),
                "p95_s": float(np.percentile(lat, 95)),
                "p99_s": float(np.percentile(lat, 99)),
                "queries": int(lat.size)}

    base = pooled(runs[False])
    repl = pooled(runs[True])
    hist: dict[int, int] = {}
    routed_windows = hits = misses = 0
    for m, _ in runs[True]:
        for k, v in m["fanout_hist"].items():
            hist[k] = hist.get(k, 0) + v
        routed_windows += m["routed_windows"]
        hits += m["mirror_hits"]
        misses += m["mirror_misses"]
    mean_fanout = (sum(k * v for k, v in hist.items())
                   / max(sum(hist.values()), 1))
    # all-mirrored steady states drive the mean toward 0; the clamp
    # keeps the reported ratio finite (and JSON-encodable)
    fanout_reduction = n_shards / max(mean_fanout, 0.05)
    hit_rate = hits / max(hits + misses, 1)
    p50_improvement = base["p50_s"] / repl["p50_s"]
    p99_improvement = base["p99_s"] / repl["p99_s"]

    # replay oracle: every answer from both modes, byte for byte
    eng = SnapshotQueryEngine()
    by_version: dict[int, list] = {}
    for _, answered in runs[False] + runs[True]:
        for r in answered:
            by_version.setdefault(r.version.pack(), []).append(r)
    audited = mismatches = 0
    for packed, items in sorted(by_version.items()):
        view = g_oracle.join_view(Version.unpack(packed))
        vals = eng.execute(view, [r.query for r in items])
        for r, exp in zip(items, vals, strict=True):
            if isinstance(exp, np.ndarray):
                same = np.asarray(r.value).tobytes() == exp.tobytes()
            else:
                same = r.value == exp
            audited += 1
            mismatches += 0 if same else 1
    assert mismatches == 0, f"{mismatches}/{audited} answers diverged"

    row("replica_locality.no_replica", base["p50_s"],
        f"p99_us={base['p99_s']*1e6:.1f};fanout={n_shards}")
    row("replica_locality.replicated", repl["p50_s"],
        f"p99_us={repl['p99_s']*1e6:.1f};mean_fanout={mean_fanout:.2f};"
        f"hit_rate={hit_rate:.2f}")
    row("replica_locality.routing", 0,
        f"fanout_reduction=x{fanout_reduction:.2f};"
        f"p50_improvement=x{p50_improvement:.2f};"
        f"p99_improvement=x{p99_improvement:.2f};"
        f"routed_windows={routed_windows}")
    row("replica_locality.oracle_audit", 0,
        f"audited={audited};mismatches={mismatches}")
    report = {
        "n_vertices": n, "n_shards": n_shards, "zipf_a": zipf_a,
        "mirror_k": mirror_k, "hot_pool": pool_size, "repeats": repeats,
        "edges_final": int(final_view.m),
        "routed_windows": int(routed_windows),
        "fanout_hist": {str(k): int(v) for k, v in sorted(hist.items())},
        "routed_mean_fanout": float(mean_fanout),
        "structural_fanout": n_shards,
        "fanout_reduction": float(fanout_reduction),
        "mirror_hit_rate": float(hit_rate),
        "mirrored_vertices": int(runs[True][-1][0]["mirrored_vertices"]),
        "no_replica": base,
        "replicated": repl,
        "p50_improvement": float(p50_improvement),
        "p99_improvement": float(p99_improvement),
        "answers_audited": int(audited),
        "oracle_mismatches": int(mismatches),
    }
    out = pathlib.Path(__file__).resolve().parents[1] / "BENCH_ingest.json"
    _merge_bench_json(out, {"replica_locality": report})
    row("replica_locality.report", 0, str(out))


# ---------------------------------------------------------------- §3.3 axis 4
def bench_replica(quick=False):
    """Data-management efficiency: hit rate + modeled comm per mode."""
    from repro.core.replica import ReplicaManager
    from repro.core.versioned import Version
    from repro.graph.dyngraph import synthesize_stream
    from repro.graph.partition import comm_model, partition_graph

    n = 1_000 if quick else 4_000
    g, _ = synthesize_stream(n, 5, n, seed=2)
    view = g.join_view(Version(4, 0))
    deg = np.asarray(view.in_degree)
    rm = ReplicaManager(8, mirror_threshold=4)
    for vid in range(n):
        rm.add_item(vid, owner=vid % 8)
    rng = np.random.default_rng(3)
    hot = np.argsort(-deg)[:32]

    def workload():
        for _ in range(5_000):
            rm.read(int(rng.integers(0, 8)), int(hot[rng.integers(0, 32)]))
        return rm.stats()["hit_rate"]

    t, before = _time(workload, repeat=1)
    rm.rebalance()
    rm.local_hits = rm.remote_misses = 0
    t2, after = _time(workload, repeat=1)
    row("replica.reads", t2 / 5_000,
        f"hit_before={before:.2f};hit_after={after:.2f}")
    pg = partition_graph(view, 16, hub_k=64)
    cm = comm_model(pg)
    row("replica.comm_model", 0,
        f"allgather={cm['allgather']:.0f};scatter={cm['scatter']:.0f};"
        f"hub={cm['hub']:.0f}")


# ------------------------------------------------- durable plane (robustness)
def bench_recovery(quick=False):
    """Durability axis: WAL-on ingest overhead, crash-recovery wall clock
    vs replay-tail length, and a recovered-store equivalence audit.

    The overhead claim is the one the WAL's default fsync policy exists
    for: with batched fsync, appending every sealed epoch's payload rows
    to the per-shard segment files must cost < 15% of ingest wall clock
    (``check_bench.py`` gates ``wal_overhead`` at 1.15, median of paired
    per-repeat ratios — pairing cancels host-load drift). Recovery is
    timed twice from the same log: the LONG tail replays every epoch from
    an empty store (no checkpoint), the SHORT tail loads the last
    checkpoint and replays only the epochs past it — the gap is the
    reason the checkpoint ladder exists. The audit recovers the store and
    byte-compares its joined view at every sealed version against the
    uncrashed WAL-on store; ``recovered_mismatches`` must be zero. Lands
    in ``BENCH_ingest.json`` under ``recovery``.
    """
    import pathlib
    import shutil
    import tempfile

    from repro.graph.dyngraph import synthesize_churn_stream
    from repro.graph.sharded import ShardedDynamicGraph

    n = 60_000 if quick else 150_000
    epochs = 10
    adds = 40_000 if quick else 120_000
    n_shards = 2
    batches = synthesize_churn_stream(n, epochs, adds, seed=0,
                                      delete_frac=0.2)
    n_muts = sum(b.size for b in batches)
    e_max = sum(len(b.add_src) for b in batches) + 16

    def run(wal_dir=None, checkpoint_every=0):
        kw = {}
        if wal_dir is not None:
            kw = dict(wal_dir=wal_dir, wal_fsync="batch",
                      checkpoint_every=checkpoint_every)
        sg = ShardedDynamicGraph(n_shards, n, e_max, **kw)
        t0 = time.perf_counter()
        for b in batches:
            sg.apply(b)
        wall = time.perf_counter() - t0
        if sg.wal is not None:
            # flush the batched tail OUTSIDE the timed window: the
            # overhead gate is about the steady-state append cost the
            # fsync batcher amortizes, not the final flush
            for w in sg.wal_shards:
                w.sync()
            sg.wal.sync()
        return wall, sg

    root = pathlib.Path(tempfile.mkdtemp(prefix="bench_recovery_"))
    try:
        repeats = 3 if quick else 5
        ratios, off_walls, on_walls = [], [], []
        for i in range(repeats):
            off, _ = run()
            on, _ = run(root / f"wal{i}")
            ratios.append(on / off)
            off_walls.append(off)
            on_walls.append(on)
        overhead = sorted(ratios)[len(ratios) // 2]
        t_off = sorted(off_walls)[len(off_walls) // 2]
        t_on = sorted(on_walls)[len(on_walls) // 2]
        row("recovery.wal_off_ingest", t_off,
            f"muts={n_muts};muts_per_s={n_muts/t_off:.3e}")
        row("recovery.wal_on_ingest", t_on,
            f"muts_per_s={n_muts/t_on:.3e};overhead=x{overhead:.3f}")

        # long tail: no checkpoint, recovery replays every epoch
        long_dir = root / f"wal{repeats - 1}"
        t_long, rec = _time(
            lambda: ShardedDynamicGraph.recover(long_dir), repeat=3)
        assert rec.coordinator.global_frontier == epochs - 1
        row("recovery.recover_long_tail", t_long,
            f"replayed_epochs={epochs};from=empty+wal")

        # short tail: checkpoint ladder leaves only the rungs past the
        # last checkpoint to replay
        _, sg_ckpt = run(root / "wal_ckpt", checkpoint_every=4)
        last_ckpt = sg_ckpt._last_ckpt_epoch
        t_short, rec_s = _time(
            lambda: ShardedDynamicGraph.recover(root / "wal_ckpt"),
            repeat=3)
        assert rec_s.coordinator.global_frontier == epochs - 1
        short_replayed = epochs - 1 - last_ckpt
        row("recovery.recover_short_tail", t_short,
            f"replayed_epochs={short_replayed};ckpt_epoch={last_ckpt};"
            f"vs_long=x{t_long/t_short:.2f}")

        # equivalence audit: the recovered store must serve byte-identical
        # joined views at EVERY sealed version
        audited = mismatches = 0
        for b in batches:
            got = rec_s.join_view(b.version)
            want = sg_ckpt.join_view(b.version)
            for f in ("offsets", "src", "dst"):
                audited += 1
                if not np.array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f))):
                    mismatches += 1
        row("recovery.audit", 0,
            f"views_audited={audited};mismatches={mismatches}")

        report = {
            "n_mutations": int(n_muts),
            "epochs": epochs,
            "n_shards": n_shards,
            "fsync": "batch",
            "wal_off_wall_s": t_off,
            "wal_on_wall_s": t_on,
            "wal_off_muts_per_s": n_muts / t_off,
            "wal_on_muts_per_s": n_muts / t_on,
            "wal_overhead": overhead,
            "recovery_long_tail_s": t_long,
            "recovery_long_replayed_epochs": epochs,
            "recovery_short_tail_s": t_short,
            "recovery_short_replayed_epochs": int(short_replayed),
            "checkpoint_epoch": int(last_ckpt),
            "durable_frontier": int(rec_s.coordinator.global_frontier),
            "views_audited": int(audited),
            "recovered_mismatches": int(mismatches),
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)
    out = pathlib.Path(__file__).resolve().parents[1] / "BENCH_ingest.json"
    _merge_bench_json(out, {"recovery": report})
    row("recovery.report", 0, str(out))


# ------------------------------------------------------------------- kernels
def bench_kernels(quick=False):
    """Kernel µbench (interpret mode on CPU — correctness-speed only; real
    perf numbers come from the §Roofline dry-run analysis)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.segment_sum import segment_sum

    m, F, n = (2_000, 64, 256) if quick else (8_000, 128, 1024)
    key = jax.random.PRNGKey(0)
    vals = jax.random.normal(key, (m, F), jnp.float32)
    segs = jnp.sort(jax.random.randint(key, (m,), 0, n))
    t_ref, _ = _time(
        lambda: ref.segment_sum(vals, segs, n).block_until_ready())
    row("kernel.segment_sum.ref", t_ref, f"m={m};F={F}")
    t_k, out_k = _time(
        lambda: segment_sum(vals, segs, n, interpret=True).block_until_ready(),
        repeat=1)
    ok = bool(jnp.allclose(out_k, ref.segment_sum(vals, segs, n), atol=1e-4))
    row("kernel.segment_sum.pallas_interp", t_k, f"allclose={ok}")


# ------------------------------------------------------------------ roofline
def bench_roofline(quick=False):
    """Emit the per-cell roofline terms (from the dry-run artifacts)."""
    import pathlib
    from repro.analysis.roofline import full_table
    rd = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not rd.exists():
        print("roofline.skipped,0,run launch.dryrun first", file=sys.stderr)
        return
    for r in full_table(rd):
        if "skipped" in r:
            row(f"roofline.{r['arch']}.{r['shape']}", 0, "SKIP")
            continue
        row(f"roofline.{r['arch']}.{r['shape']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]),
            f"dominant={r['dominant']};useful={r['useful_ratio']:.2f};"
            f"frac={r['roofline_fraction']:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: online,offline,ingest,"
                         "ingest_graph,ingest_sharded,resharding,"
                         "serve_graph,serve_rpc,serve_fastpath,"
                         "replica_locality,replica,recovery,kernels,"
                         "roofline")
    args = ap.parse_args()
    benches = {
        "online": bench_online, "offline": bench_offline,
        "ingest": bench_ingest, "ingest_graph": bench_ingest_graph,
        "ingest_sharded": bench_ingest_sharded,
        "resharding": bench_resharding,
        "serve_graph": bench_serve_graph,
        "serve_rpc": bench_serve_rpc,
        "serve_fastpath": bench_serve_fastpath,
        "replica_locality": bench_replica_locality,
        "replica": bench_replica,
        "recovery": bench_recovery,
        "kernels": bench_kernels, "roofline": bench_roofline,
    }
    wanted = args.only.split(",") if args.only else list(benches)
    print("name,us_per_call,derived")
    for name in wanted:
        benches[name](quick=args.quick)


if __name__ == "__main__":
    main()
