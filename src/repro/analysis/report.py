"""Regenerate the data-driven sections of EXPERIMENTS.md from results/.

    PYTHONPATH=src python -m repro.analysis.report > EXPERIMENTS_tables.md
"""
from __future__ import annotations

import json
import pathlib

from repro.analysis.hlo import analyze
from repro.analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, full_table,
                                     to_markdown)
from repro.configs import SHAPES, all_configs

ROOT = pathlib.Path(__file__).resolve().parents[3]
RD = ROOT / "results" / "dryrun"


def dryrun_table(mesh: str) -> str:
    rows = ["| arch | shape | devices | compile s | args GB/dev | temp GB/dev "
            "| XLA flops/dev (per-body) |",
            "|---|---|---|---|---|---|---|"]
    for arch in sorted(all_configs()):
        for shape in SHAPES:
            f = RD / f"{arch}__{shape}__{mesh}.json"
            if not f.exists():
                continue
            d = json.loads(f.read_text())
            if "skipped" in d:
                rows.append(f"| {arch} | {shape} | — | — | — | — | SKIP |")
                continue
            mem = d["memory"]
            gb = 1024 ** 3
            rows.append(
                f"| {arch} | {shape} | {d['devices']} | {d['compile_s']} "
                f"| {(mem['argument_bytes'] or 0)/gb:.2f} "
                f"| {(mem['temp_bytes'] or 0)/gb:.2f} "
                f"| {d['cost']['flops']:.3e} |")
    return "\n".join(rows)


def variant_rows(tags: list[tuple[str, str, str]]) -> str:
    out = ["| cell | variant | compute s | memory s | collective s "
           "| dominant | roofline frac |",
           "|---|---|---|---|---|---|---|"]
    for arch, shape, tag in tags:
        suffix = f"__{tag}" if tag else ""
        hf = RD / f"{arch}__{shape}__single{suffix}.hlo.txt"
        if not hf.exists():
            continue
        r = analyze(hf.read_text(), default_group=16)
        tc = r["flops"] / PEAK_FLOPS
        tm = r["hbm_bytes"] / HBM_BW
        tx = r["collective_link_bytes"] / ICI_BW
        terms = {"compute": tc, "memory": tm, "collective": tx}
        dom = max(terms, key=terms.get)
        from repro.analysis.roofline import model_flops_per_device
        cfg = all_configs()[arch]
        mf = model_flops_per_device(cfg, SHAPES[shape], 256)
        frac = (mf / PEAK_FLOPS) / max(terms.values())
        out.append(f"| {arch} {shape} | {tag or 'baseline'} | {tc:.2f} "
                   f"| {tm:.2f} | {tx:.2f} | {dom} | {frac:.3f} |")
    return "\n".join(out)


def main():
    print("## §Dry-run (single-pod 16x16 = 256 chips)\n")
    print(dryrun_table("single"))
    print("\n## §Dry-run (multi-pod 2x16x16 = 512 chips)\n")
    print(dryrun_table("multi"))
    print("\n## §Roofline (single-pod, per (arch x shape))\n")
    print(to_markdown(full_table(RD)))


if __name__ == "__main__":
    main()
