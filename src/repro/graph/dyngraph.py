"""Versioned dynamic graph store — the JAX data plane of the paper's data
model.

JAX needs static shapes, so the graph is a capacity-bounded *multi-version*
edge/vertex store: a mutation never overwrites — an edge add writes a row
stamped ``created=v``; an edge delete stamps ``deleted=v``. A snapshot is a
*mask* (``created <= v < deleted``), which is exactly the paper's Fig 3(b)
multi-version item semantics (every version stays addressable), vectorized.

Ingestion (``apply``) is fully vectorized and indexed:

* vertex adds, edge-row appends, and endpoint auto-creation are batched
  NumPy ops — O(batch) with no per-element Python work on arrays;
* edge deletes resolve through a ``(src, dst) -> latest live row`` hash
  index backed by a per-row ``prev-live`` chain (a LIFO stack per key), so
  a delete is O(1) amortized instead of the seed's O(E) scan per edge —
  O(batch) per mutation batch overall.

The per-snapshot CSR ("join view", §2.3.3.2) is built once per queried
version and cached — it is what makes the join-group-by operator a segment
reduction. Views are maintained **delta-first**: when a view for an earlier
version is cached, the CSR for the requested version is patched from the
mutation delta (sorted-merge row insert/remove + incremental degree
updates) in O(m + |delta| log |delta|) instead of the full O(E + m log m)
mask-and-re-sort rebuild; past a churn threshold (delta larger than
``churn_threshold`` · m) it falls back to the full rebuild. Rows are kept
in canonical ``(dst, src)`` order so the delta patch and the full rebuild
produce byte-identical CSRs.

``apply`` also evicts cached views with version >= the incoming batch (a
snapshot cached for a not-yet-applied future version would silently go
stale otherwise).

On TPU the snapshot-mask resolution can route through the Pallas
``snapshot_resolve`` kernel (``use_kernel=True``): liveness is a 2-slot
multi-version resolve per edge ([created, deleted] -> [1, 0]).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.versioned import PACK_BITS, Version

MAXV = np.iinfo(np.int64).max

# Delta-patching a cached view wins while the delta is small relative to the
# live edge count; past this fraction a full mask-and-sort rebuild is cheaper.
DEFAULT_CHURN_THRESHOLD = 0.25

_I32MAX = np.iinfo(np.int32).max


def _pack64_to32(packed: np.ndarray) -> np.ndarray:
    """Re-pack 64-bit (epoch<<32|number) version stamps into the int32
    data-plane packing (versioned.PACK_BITS). MAXV (the 'never' sentinel)
    maps to int32 max."""
    epoch = packed >> 32
    number = packed & 0xFFFFFFFF
    real = packed != MAXV
    out = (epoch << PACK_BITS) | number
    # overflow would silently corrupt the int32 stamps and diverge the
    # kernel mask from the host mask; int32 max itself is reserved as the
    # 'never' sentinel
    if np.any(real & ((epoch >= 1 << (31 - PACK_BITS))
                      | (number >= 1 << PACK_BITS)
                      | (out >= _I32MAX))):
        raise ValueError("version stamp exceeds int32 data-plane packing "
                         f"(epoch < 2^{31 - PACK_BITS}, "
                         f"number < 2^{PACK_BITS}, int32 max reserved)")
    return np.where(real, out, _I32MAX).astype(np.int32)


@dataclasses.dataclass
class MutationBatch:
    """One epoch's worth of mutations (vectorized)."""
    version: Version
    add_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    add_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    del_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    del_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    add_vertices: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    vertex_types: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))

    def __post_init__(self):
        # every consumer (vectorized store, loop oracle, sharded encoder)
        # pairs add_vertices with vertex_types elementwise; a silent
        # truncation to the shorter of the two would drop vertex adds on
        # one path but not another, so the mismatch is resolved here once:
        # missing types default to 0 (untyped), surplus types are an error
        nv, nt = len(self.add_vertices), len(self.vertex_types)
        if nt > nv:
            raise ValueError(
                f"vertex_types has {nt} entries for {nv} add_vertices; "
                "a type without a vertex is meaningless")
        if nt < nv:
            self.vertex_types = np.concatenate(
                [np.asarray(self.vertex_types, np.int32),
                 np.zeros(nv - nt, np.int32)])

    @property
    def size(self) -> int:
        return (len(self.add_src) + len(self.del_src) + len(self.add_vertices))


@dataclasses.dataclass
class _BatchDelta:
    """Per-batch ingestion record: which store rows the batch touched.
    Lets ``join_view`` enumerate a version delta in O(|delta|)."""
    version: int                # packed
    row_start: int              # appended rows: [row_start, row_end)
    row_end: int
    del_rows: np.ndarray        # rows tombstoned by this batch


@dataclasses.dataclass
class JoinView:
    """CSR of one snapshot: dst-grouped in-edges (the join view).

    Rows are in canonical (dst, src) order. The trailing ``np_*`` fields are
    host-side state for O(delta) incremental maintenance.
    """
    version: Version
    n: int
    offsets: jnp.ndarray       # (n+1,)
    src: jnp.ndarray           # (m,) source vertex per in-edge
    dst: jnp.ndarray           # (m,)
    out_degree: jnp.ndarray    # (n,)
    in_degree: jnp.ndarray     # (n,)
    np_keys: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)    # (m,) int64 (dst<<32)|src, ascending
    np_src: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)
    np_dst: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)
    np_in_deg: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)    # (n,) int64
    np_out_deg: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)    # (n,) int64

    @property
    def m(self) -> int:
        return int(self.src.shape[0])


def _edge_keys(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    return (dst.astype(np.int64) << 32) | src.astype(np.int64)


def prune_views(views: dict, budget: int) -> int:
    """Drop cached views down to the :func:`ladder_keep` retention set,
    in place. Shared by the single store and the sharded stitched cache so
    the retention policy cannot diverge. Returns the number dropped."""
    if len(views) <= budget:
        return 0
    keep = set(ladder_keep(sorted(views, reverse=True), budget))
    drop = [k for k in views if k not in keep]
    for k in drop:
        del views[k]
    return len(drop)


def prune_retired(views: dict, floor: int) -> int:
    """Drop cached entries with version key < ``floor`` — but only once an
    entry at or above the floor exists, so the newest pre-floor entry keeps
    serving (and warm-starting) until the successor it waits on is cached.

    The sharded store uses this after a re-sharding migration: entries
    below the active routing plan's activation version were built under a
    retired plan and will never be served again once the first post-cutover
    snapshot exists. Returns the number dropped.
    """
    if floor <= 0 or not any(k >= floor for k in views):
        return 0
    drop = [k for k in views if k < floor]
    for k in drop:
        del views[k]
    return len(drop)


def build_join_view(version: Version, n: int, keys, src_s, dst_s,
                    in_deg, out_deg) -> JoinView:
    """Assemble a JoinView from canonical (dst, src)-ordered rows + degree
    arrays. Shared by the single store, the delta patcher, and the sharded
    stitcher so all three produce byte-identical CSRs."""
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum(in_deg, out=offsets[1:])
    return JoinView(version, n, jnp.asarray(offsets),
                    jnp.asarray(src_s), jnp.asarray(dst_s),
                    jnp.asarray(out_deg.astype(np.float32)),
                    jnp.asarray(in_deg.astype(np.float32)),
                    np_keys=keys, np_src=src_s, np_dst=dst_s,
                    np_in_deg=np.asarray(in_deg, np.int64),
                    np_out_deg=np.asarray(out_deg, np.int64))


def ladder_keep(keys_desc: list[int], budget: int) -> list[int]:
    """Pick which cached view versions to retain under a budget: a
    version-spaced ladder rather than the newest K.

    With delta maintenance the best rebuild base is the *nearest older*
    view, so newest-K retention leaves every pre-window version with no
    nearby base (ROADMAP: churn-adaptive view GC). Retention is an
    exponential histogram over distance-from-newest: bucket j spans
    distances [d·2^j, d·2^(j+1)) where d is the gap to the second-newest
    view, and the nearest view per bucket is kept, for at most
    ``budget - 1`` buckets. Any version inside the span then has a
    retained base within ~2x its distance from the frontier, and —
    crucially for repeated GC under a live stream — views beyond the last
    rung are dropped no matter what, so the retained set (and the
    ingestion delta log floored at its minimum) tracks the frontier
    instead of pinning the oldest view forever. ``budget`` is a cap (a
    bucket can swallow several views, so fewer may be retained).

    ``keys_desc`` must be sorted descending; returns the retained subset
    (descending). The two newest entries are always kept, so budget 2
    degenerates to newest-2 exactly.
    """
    n = len(keys_desc)
    if budget <= 0 or n == 0:
        return []
    if budget >= n:
        return list(keys_desc)
    newest = keys_desc[0]
    d_min = max(newest - keys_desc[1], 1)
    keep = [newest]
    last_bucket = -1
    for k in keys_desc[1:]:
        bucket = ((newest - k) // d_min).bit_length() - 1
        if bucket > budget - 2:
            break                      # beyond the last rung: drop the tail
        if bucket > last_bucket and len(keep) < budget:
            keep.append(k)
            last_bucket = bucket
    return keep


class DynamicGraph:
    """Capacity-bounded versioned edge store + vertex table."""

    def __init__(self, n_max: int, e_max: int,
                 churn_threshold: float = DEFAULT_CHURN_THRESHOLD):
        self.n_max = n_max
        self.e_max = e_max
        self.churn_threshold = churn_threshold
        self.src = np.zeros(e_max, np.int32)
        self.dst = np.zeros(e_max, np.int32)
        self.created = np.full(e_max, MAXV, np.int64)
        self.deleted = np.full(e_max, MAXV, np.int64)
        self.n_edges = 0
        self.v_created = np.full(n_max, MAXV, np.int64)
        self.v_type = np.zeros(n_max, np.int32)
        self.n_vertices = 0
        self.versions: list[Version] = []
        self._views: dict[int, JoinView] = {}
        # (src, dst) -> latest live row; _prev_live chains to the previous
        # live row with the same key (LIFO, matching "delete the newest
        # live duplicate" semantics).
        self._live_index: dict[int, int] = {}
        self._prev_live = np.full(e_max, -1, np.int64)
        self._batch_log: list[_BatchDelta] = []
        # records with version <= _log_floor have been trimmed (gc_views);
        # delta patching is only valid from bases at or above the floor
        self._log_floor = -1
        # telemetry for the delta-view path (benchmarks read these)
        self.view_full_builds = 0
        self.view_delta_patches = 0

    # -- ingestion ---------------------------------------------------------
    def apply(self, batch: MutationBatch) -> None:
        v = batch.version.pack()
        if self.versions and v <= self.versions[-1].pack():
            raise ValueError("mutation batches must have increasing versions")
        if self.n_edges + len(batch.add_src) > self.e_max:
            # checked before any state mutates so a failed apply is a no-op
            raise MemoryError("edge capacity exceeded")
        # a view cached for a future version is invalidated by this batch
        stale = [k for k in self._views if k >= v]
        for k in stale:
            del self._views[k]
        # vertex adds (typed): first occurrence per id wins within a batch
        # (lengths are normalized by MutationBatch.__post_init__)
        if len(batch.add_vertices):
            vids, first = np.unique(batch.add_vertices, return_index=True)
            new = self.v_created[vids] == MAXV
            vids, first = vids[new], first[new]
            self.v_created[vids] = v
            self.v_type[vids] = batch.vertex_types[first]
            self.n_vertices += len(vids)
        # edge adds: append rows
        k = len(batch.add_src)
        row_start = self.n_edges
        if k:
            sl = slice(self.n_edges, self.n_edges + k)
            self.src[sl] = batch.add_src
            self.dst[sl] = batch.add_dst
            self.created[sl] = v
            self.deleted[sl] = MAXV
            # auto-create endpoint vertices (untyped)
            ends = np.unique(np.concatenate([batch.add_src, batch.add_dst]))
            new = ends[self.v_created[ends] == MAXV]
            self.v_created[new] = v
            self.n_vertices += len(new)
            # push each new row onto its key's live stack
            index = self._live_index
            prev = self._prev_live
            for row, key in enumerate(
                    _edge_keys(batch.add_src, batch.add_dst).tolist(),
                    row_start):
                old = index.get(key, -1)
                prev[row] = old
                index[key] = row
            self.n_edges += k
        # edge deletes: pop the newest live row matching (src, dst)
        del_rows: list[int] = []
        if len(batch.del_src):
            index = self._live_index
            prev = self._prev_live
            deleted = self.deleted
            for key in _edge_keys(batch.del_src, batch.del_dst).tolist():
                row = index.get(key, -1)
                if row < 0:
                    continue            # no live row — ignore (seed semantics)
                deleted[row] = v
                del_rows.append(row)
                p = prev[row]
                if p >= 0:
                    index[key] = p
                else:
                    del index[key]
        self._batch_log.append(_BatchDelta(
            v, row_start, self.n_edges, np.asarray(del_rows, np.int64)))
        self.versions.append(batch.version)

    # -- snapshots -----------------------------------------------------------
    def snapshot_mask(self, version: Version,
                      use_kernel: bool = False) -> np.ndarray:
        """created <= v < deleted — the paper's snapshot rule on edges.

        ``use_kernel`` routes the resolve through the Pallas
        ``snapshot_resolve`` kernel (liveness as a 2-slot multi-version
        resolve); the NumPy path is the portable host fallback.
        """
        v = version.pack()
        e = self.n_edges
        if use_kernel:
            from repro.kernels import ops
            mask = ops.liveness_mask(_pack64_to32(self.created[:e]),
                                     _pack64_to32(self.deleted[:e]),
                                     int(_pack64_to32(np.asarray([v]))[0]))
            return np.asarray(mask)
        return (self.created[:e] <= v) & (v < self.deleted[:e])

    def num_vertices(self, version: Optional[Version] = None) -> int:
        if version is None:
            return self.n_vertices
        return int((self.v_created <= version.pack()).sum())

    def join_view(self, version: Version,
                  use_kernel: bool = False) -> JoinView:
        """Return (and cache) the dst-grouped CSR for a snapshot.

        Prefers patching the newest cached view at an earlier version with
        the mutation delta; falls back to a full rebuild when no usable base
        exists or the delta exceeds the churn threshold.
        """
        key = version.pack()
        if key in self._views:
            return self._views[key]
        view = self._delta_patch(key, version)
        if view is None:
            view = self._full_rebuild(version, use_kernel=use_kernel)
            self.view_full_builds += 1
        else:
            self.view_delta_patches += 1
        self._views[key] = view
        return view

    def _full_rebuild(self, version: Version,
                      use_kernel: bool = False) -> JoinView:
        mask = self.snapshot_mask(version, use_kernel=use_kernel)
        src = self.src[:self.n_edges][mask]
        dst = self.dst[:self.n_edges][mask]
        keys = _edge_keys(src, dst)
        order = np.argsort(keys, kind="stable")
        return self._make_view(version, keys[order], src[order], dst[order],
                               np.bincount(dst, minlength=self.n_max),
                               np.bincount(src, minlength=self.n_max))

    def _make_view(self, version: Version, keys, src_s, dst_s,
                   in_deg, out_deg) -> JoinView:
        return build_join_view(version, self.n_max, keys, src_s, dst_s,
                               in_deg, out_deg)

    def _delta_patch(self, key: int, version: Version) -> Optional[JoinView]:
        """Patch the newest cached view with version < key, or None if no
        base is usable / the churn threshold is exceeded."""
        bases = [k for k in self._views if self._log_floor <= k < key
                 and self._views[k].np_keys is not None]
        if not bases:
            return None
        base_key = max(bases)
        base = self._views[base_key]
        # edge delta between base_key and key: the log is version-sorted,
        # so the record range is found by bisection — O(|delta| + log B)
        lo = bisect.bisect_right(self._batch_log, base_key,
                                 key=lambda r: r.version)
        hi = bisect.bisect_right(self._batch_log, key,
                                 key=lambda r: r.version)
        add_rows: list[np.ndarray] = []
        del_rows: list[np.ndarray] = []
        for rec in self._batch_log[lo:hi]:
            add_rows.append(np.arange(rec.row_start, rec.row_end, dtype=np.int64))
            del_rows.append(rec.del_rows)
        adds = (np.concatenate(add_rows) if add_rows
                else np.zeros(0, np.int64))
        dels = (np.concatenate(del_rows) if del_rows
                else np.zeros(0, np.int64))
        # rows added in the delta count only if still live at `key`; rows
        # deleted in the delta count only if present in the base (a row both
        # added and deleted inside the delta cancels out of both sets)
        adds = adds[self.deleted[adds] > key]
        dels = dels[self.created[dels] <= base_key]
        churn = len(adds) + len(dels)
        if churn > self.churn_threshold * max(base.m, 1):
            return None
        if churn == 0:
            return self._make_view(version, base.np_keys, base.np_src,
                                   base.np_dst, base.np_in_deg.copy(),
                                   base.np_out_deg.copy())
        keys, src_s, dst_s = base.np_keys, base.np_src, base.np_dst
        in_deg = base.np_in_deg.copy()
        out_deg = base.np_out_deg.copy()
        if len(dels):
            dkeys = np.sort(_edge_keys(self.src[dels], self.dst[dels]))
            # multiset removal: j-th duplicate of a key removes the j-th of
            # its contiguous run in the (sorted) base rows
            left = np.searchsorted(keys, dkeys, side="left")
            occ = np.arange(len(dkeys)) - np.searchsorted(dkeys, dkeys,
                                                          side="left")
            keep = np.ones(len(keys), bool)
            keep[left + occ] = False
            keys, src_s, dst_s = keys[keep], src_s[keep], dst_s[keep]
            np.subtract.at(in_deg, self.dst[dels], 1)
            np.subtract.at(out_deg, self.src[dels], 1)
        if len(adds):
            asrc, adst = self.src[adds], self.dst[adds]
            akeys = _edge_keys(asrc, adst)
            order = np.argsort(akeys, kind="stable")
            akeys, asrc, adst = akeys[order], asrc[order], adst[order]
            pos = np.searchsorted(keys, akeys, side="left")
            keys = np.insert(keys, pos, akeys)
            src_s = np.insert(src_s, pos, asrc)
            dst_s = np.insert(dst_s, pos, adst)
            np.add.at(in_deg, adst, 1)
            np.add.at(out_deg, asrc, 1)
        return self._make_view(version, keys, src_s, dst_s, in_deg, out_deg)

    def gc_views(self, keep_latest: int = 4, *, retire_below: int = 0) -> int:
        """Collect obsolete join views (paper §2.2 obsolete-replica GC).

        Retention is churn-adaptive: instead of the newest ``keep_latest``
        views, a version-spaced *ladder* (:func:`ladder_keep`) is kept, so a
        request for any past version finds a delta-patch base within ~2x its
        distance from the frontier under the same budget.

        ``retire_below`` additionally drops every cached view below that
        packed version once a newer one is cached (:func:`prune_retired`) —
        the sharded store passes a re-sharding migration's activation
        version here so a shard involved in a split does not pin pre-split
        views (built under a retired routing plan) in its ladder.

        Also trims the ingestion delta log: records at or below the oldest
        retained view's version can never contribute to a future delta
        patch from a retained base, so the log stays bounded by the churn
        since the oldest view instead of growing with the whole stream.
        The trim runs even when no view is dropped (with no cached views
        at all, everything up to the newest applied version is trimmed —
        any later-cached old view is then below the floor and rebuilds
        from scratch, never from missing records).
        """
        dropped = prune_retired(self._views, retire_below)
        dropped += prune_views(self._views, keep_latest)
        if self._views:
            floor = min(self._views)
        elif self.versions:
            floor = self.versions[-1].pack()
        else:
            return 0
        self._batch_log = [r for r in self._batch_log if r.version > floor]
        self._log_floor = max(self._log_floor, floor)
        return dropped


# ----------------------------------------------------------- synthetic data
def _churn_batches(rng, n_epochs: int, sample_adds, *, delete_frac: float,
                   readd_frac: float) -> list[MutationBatch]:
    """Shared epoch loop for the synthetic stream generators: per-epoch
    ``(src, dst)`` adds from ``sample_adds(rng)``, live-set bookkeeping,
    ``delete_frac`` uniform deletes and ``readd_frac`` re-adds of
    previously deleted edges. One implementation of the delete/re-add
    bookkeeping keeps the uniform and skewed generators in lockstep."""
    live: list[tuple[int, int]] = []
    dead: list[tuple[int, int]] = []
    batches = []
    for e in range(n_epochs):
        src, dst = sample_adds(rng)
        adds_s, adds_d = list(src), list(dst)
        if readd_frac and dead:
            k = int(len(dead) * readd_frac)
            for i in rng.choice(len(dead), size=k, replace=False):
                s, d = dead[i]
                adds_s.append(s)
                adds_d.append(d)
        n_del = int(len(live) * delete_frac)
        if n_del:
            idx = rng.choice(len(live), size=n_del, replace=False)
            sel = set(idx.tolist())
            dels = [live[i] for i in idx]
            live = [x for i, x in enumerate(live) if i not in sel]
            dead.extend(dels)
            del_s = np.array([x[0] for x in dels], np.int32)
            del_d = np.array([x[1] for x in dels], np.int32)
        else:
            del_s = del_d = np.zeros(0, np.int32)
        live.extend(zip(adds_s, adds_d))
        batches.append(MutationBatch(
            Version(e, 0),
            add_src=np.array(adds_s, np.int32),
            add_dst=np.array(adds_d, np.int32),
            del_src=del_s, del_dst=del_d))
    return batches


def synthesize_churn_stream(n_vertices: int, n_epochs: int,
                            adds_per_epoch: int, *, seed: int = 0,
                            delete_frac: float = 0.0,
                            readd_frac: float = 0.0) -> list[MutationBatch]:
    """Uniform-random mutation batches with controllable churn: each epoch
    deletes ``delete_frac`` of the live edges and re-adds ``readd_frac`` of
    the previously deleted ones. Shared by the equivalence tests and the
    ingestion benchmark so both exercise identical stream semantics."""

    def sample_adds(rng):
        src = rng.integers(0, n_vertices, adds_per_epoch).astype(np.int32)
        dst = rng.integers(0, n_vertices, adds_per_epoch).astype(np.int32)
        return src, dst

    return _churn_batches(np.random.default_rng(seed), n_epochs, sample_adds,
                          delete_frac=delete_frac, readd_frac=readd_frac)


def synthesize_skewed_stream(n_vertices: int, n_epochs: int,
                             adds_per_epoch: int, *, seed: int = 0,
                             zipf_a: float = 1.2,
                             delete_frac: float = 0.0) -> list[MutationBatch]:
    """Zipf-skewed mutation batches: destination vertices are drawn from a
    Zipf(``zipf_a``) rank distribution mapped through a random permutation
    of the vertex ids, so a handful of (randomly placed) vertices receive
    most of the edges — the hot-shard regime the access-pattern-adaptive
    re-sharding planner exists for. Sources are uniform. ``delete_frac``
    deletes that fraction of the live edges each epoch (uniformly, so
    deletes of hot-destination edges exercise post-migration delete
    routing). Shared by the ``resharding`` benchmark axis, the demo, and
    the split-equivalence tests."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_vertices)

    def sample_adds(rng):
        ranks = rng.zipf(zipf_a, adds_per_epoch)
        dst = perm[(ranks - 1) % n_vertices].astype(np.int32)
        src = rng.integers(0, n_vertices, adds_per_epoch).astype(np.int32)
        return src, dst

    return _churn_batches(rng, n_epochs, sample_adds,
                          delete_frac=delete_frac, readd_frac=0.0)


def synthesize_stream(n_vertices: int, n_epochs: int, adds_per_epoch: int,
                      *, seed: int = 0, delete_frac: float = 0.05,
                      n_types: int = 3) -> tuple[DynamicGraph, list[MutationBatch]]:
    """Preferential-attachment mutation stream (citation-graph-like: papers
    cite earlier papers; new vertex types appear in later epochs — the
    paper's Fig 1 evolution). Vertices grown in each epoch arrive as typed
    ``add_vertices`` with the epoch's type."""
    rng = np.random.default_rng(seed)
    e_max = n_epochs * adds_per_epoch * 2 + 16
    g = DynamicGraph(n_vertices, e_max)
    batches = []
    deg = np.ones(n_vertices, np.float64)
    grown = 8
    live: list[tuple[int, int]] = []
    for epoch in range(n_epochs):
        prev_grown = grown
        grown = min(n_vertices, grown + max(1, n_vertices // (n_epochs + 1)))
        p = deg[:grown] / deg[:grown].sum()
        dsts = rng.choice(grown, size=adds_per_epoch, p=p).astype(np.int32)
        srcs = rng.integers(0, grown, size=adds_per_epoch).astype(np.int32)
        keep = srcs != dsts
        srcs, dsts = srcs[keep], dsts[keep]
        deg_update = np.bincount(dsts, minlength=n_vertices)
        deg += deg_update
        n_del = int(len(live) * delete_frac)
        if n_del:
            idx = rng.choice(len(live), size=n_del, replace=False)
            dels = [live[i] for i in idx]
            live = [e for i, e in enumerate(live) if i not in set(idx)]
            del_src = np.array([d[0] for d in dels], np.int32)
            del_dst = np.array([d[1] for d in dels], np.int32)
        else:
            del_src = del_dst = np.zeros(0, np.int32)
        live.extend(zip(srcs.tolist(), dsts.tolist()))
        # vertex type evolution: later epochs introduce new types; this
        # epoch's newly grown vertices carry the epoch's type (Fig 1)
        vtype = np.minimum(epoch * n_types // max(n_epochs, 1), n_types - 1)
        new_vertices = np.arange(0 if epoch == 0 else prev_grown, grown,
                                 dtype=np.int32)
        batch = MutationBatch(
            version=Version(epoch, 0),
            add_src=srcs, add_dst=dsts,
            del_src=del_src, del_dst=del_dst,
            add_vertices=new_vertices,
            vertex_types=np.full(len(new_vertices), vtype, np.int32))
        g.apply(batch)
        batches.append(batch)
    return g, batches
