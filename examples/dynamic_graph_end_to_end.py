"""END-TO-END DRIVER — the paper's system doing the paper's job.

A dynamic citation-style graph evolves through a stream of mutation epochs:

  1. schema evolution (§2.1): Author/Paper schema grows a new version +
     School nodes mid-stream;
  2. asynchronous ingestion (§2.3.1): ingest nodes dispatch mutations with
     the no-wait rule; the global snapshot frontier trails local frontiers;
  3. ONLINE computing: k-hop neighborhood + reachability queries answered
     on sealed snapshots while newer epochs are still ingesting;
  4. OFFLINE analytics: PageRank timeline (incremental, warm-started — the
     online/offline shared-data goal), WCC, emerging-vertex detection
     ("who made the most friends this month?");
  5. replica-coherence management (§2.2): hub-mirror placement from access
     stats, hit-rate before/after rebalancing;
  6. distributed views: the analytics table is a lineage-tracked view;
     we simulate a node failure and recover it by lineage replay.

    PYTHONPATH=src python examples/dynamic_graph_end_to_end.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.replica import ReplicaManager
from repro.core.versioned import Version
from repro.core.views import View
from repro.graph import compute as gc
from repro.graph.dyngraph import synthesize_stream
from repro.graph.partition import (comm_model, partition_graph,
                                   partition_graph_sharded)
from repro.graph.schema import citation_schema
from repro.graph.sharded import ShardedDynamicGraph

N, EPOCHS, ADDS = 256, 8, 300


def main():
    # 1) schema evolution ----------------------------------------------------
    reg = citation_schema()
    print("== schema (paper Fig 2) ==")
    print("  Author versions:", reg.versions_of("Author"),
          "| Author<2> fields:", reg.fields_of("Author", 2))

    # 2) async sharded ingestion ----------------------------------------------
    g, batches = synthesize_stream(N, EPOCHS, ADDS, seed=42)
    sg = ShardedDynamicGraph(4, N, EPOCHS * ADDS * 2 + 16)
    print("\n== sharded ingestion (dst-hash routing, no-wait dispatch) ==")
    for e, batch in enumerate(batches):
        sg.ingest(batch)              # no-wait dispatch to 4 DataNode shards
        if e == 0:                    # straggler demo: shard 0 seals late
            for shard in range(1, 4):
                sg.seal_shard(shard, e)
            print(f"  shard 0 lagging: global frontier = "
                  f"{sg.coordinator.global_frontier} (snapshot 0 not yet "
                  "queryable)")
        sg.seal_epoch(e)              # every shard sealed -> frontier moves
    print(f"  dispatched={sg.ingest_node.dispatched} mutations, "
          f"edges/shard={sg.shard_edge_counts()}, "
          f"global frontier={sg.coordinator.global_frontier}")
    stitched = sg.join_view(Version(EPOCHS - 1, 0))
    single = g.join_view(Version(EPOCHS - 1, 0))
    assert np.array_equal(np.asarray(stitched.src), np.asarray(single.src))
    assert np.array_equal(np.asarray(stitched.offsets),
                          np.asarray(single.offsets))
    print(f"  stitched join view == single-store view ({stitched.m} edges)")

    # 3) online queries on sealed snapshots -----------------------------------
    v_mid = Version(EPOCHS // 2, 0)
    v_last = Version(EPOCHS - 1, 0)
    view_mid = g.join_view(v_mid)
    hubs = np.argsort(-np.asarray(view_mid.in_degree))[:3]
    print("\n== online queries (snapshot isolation) ==")
    reach = np.asarray(gc.k_hop(view_mid, np.array([int(hubs[0])]), 2))
    print(f"  2-hop neighborhood of hub {hubs[0]}: {int(reach.sum())} vertices")
    print(f"  reach({hubs[0]} -> {hubs[1]}) @v_mid:",
          gc.reachability(view_mid, int(hubs[0]), int(hubs[1])))

    # 4) offline analytics (timeline, warm-started) ---------------------------
    versions = [Version(e, 0) for e in range(EPOCHS)]
    print("\n== offline analytics ==")
    cold = gc.pagerank(g.join_view(v_last), tol=1e-8, max_iter=300)
    prs = gc.pagerank_timeline(g, versions, incremental=True, tol=1e-8,
                               max_iter=300)
    print(f"  pagerank timeline: iters/epoch = "
          f"{[p.iterations for p in prs]} (cold last-epoch: {cold.iterations})")
    top = gc.emerging_vertices(g, versions[-3], versions[-1], top_k=5)
    print(f"  emerging vertices (most new in-links): {top.tolist()}")
    labels = np.asarray(gc.wcc(g.join_view(v_last)))
    print(f"  WCC components @last: {len(set(labels.tolist()))}")

    # 5) replica-coherence management -----------------------------------------
    print("\n== replica-coherence (access-driven placement) ==")
    rm = ReplicaManager(4, mirror_threshold=4)
    deg = np.asarray(g.join_view(v_last).in_degree)
    for vid in range(N):
        rm.add_item(vid, owner=vid % 4, value=float(deg[vid]))
    rng = np.random.default_rng(0)
    popular = np.argsort(-deg)[:16]
    def workload():
        for _ in range(2000):
            item = int(popular[rng.integers(0, 16)])  # hot reads of hubs
            rm.read(int(rng.integers(0, 4)), item)
    workload()
    before = rm.stats()["hit_rate"]
    rm.rebalance()
    rm.local_hits = rm.remote_misses = 0
    workload()
    after = rm.stats()["hit_rate"]
    print(f"  hit-rate before/after rebalance: {before:.2f} -> {after:.2f}")
    pg = partition_graph(g.join_view(v_last), 8, hub_k=8)
    cm = comm_model(pg)
    print(f"  comm bytes/superstep: allgather={cm['allgather']:.0f} "
          f"scatter={cm['scatter']:.0f} hub={cm['hub']:.0f}")
    pgs = partition_graph_sharded(sg.shard_views(v_last), hub_k=8)
    print(f"  sharded fast path: {pgs.n_parts} partitions consumed "
          f"pre-bucketed ({pgs.placement}-placed, no re-bucketing pass)")

    # 6) distributed views: failure + lineage recovery ------------------------
    print("\n== distributed views (lineage fault tolerance) ==")
    snap_view = View.source("graph@last", lambda: g.join_view(v_last))
    ranks = snap_view.map("pagerank", lambda v: gc.pagerank(v, tol=1e-8).ranks)
    table = ranks.map("top10", lambda r: np.argsort(-np.asarray(r))[:10])
    top10 = table.value()
    table.invalidate(recursive=True)
    recovered = table.recover()        # replay lineage
    assert np.array_equal(top10, recovered)
    print(f"  top-10 by pagerank: {top10.tolist()} "
          f"(recovered identically after simulated failure)")
    print("\nOK — end-to-end dynamic graph computing complete")


if __name__ == "__main__":
    main()
