"""Static analyzer for optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts each computation ONCE —
``while`` loops (every ``lax.scan``: our layer stack, kv-chunk scans, loss
chunks) are NOT multiplied by trip count, so its FLOPs/bytes undercount by
10-100x on scanned models. This analyzer parses the post-SPMD HLO text,
recovers trip counts from loop conditions, and propagates multiplicities
through ``while``/``fusion``/``call``/``conditional`` — yielding
per-device totals for:

  * flops (dot/convolution get exact shape math; elementwise counted 1/elem)
  * HBM traffic proxy (operand+result bytes of top-level ops, post-fusion)
  * collective traffic per kind, with ring-model link-byte estimates
  * op-instance counts (remat/redundancy diagnostics)

This is the profiling instrument the §Perf loop reads (no real TPU here).
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter, defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(|\.?\s*\()")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "and", "or", "xor", "not", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "compare", "select", "clamp",
    "convert", "remainder", "atan2", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "is-finite", "popcnt", "clz",
}
_TRANSCENDENTAL = {"exponential", "log", "log-plus-one", "expm1", "rsqrt",
                   "sqrt", "cbrt", "tanh", "sine", "cosine", "tan", "erf",
                   "logistic", "exponential-minus-one"}
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
         "opt-barrier", "custom-call", "get-dimension-size"}
_MOVERS = {"copy", "transpose", "reshape", "broadcast", "concatenate", "slice",
           "dynamic-slice", "dynamic-update-slice", "pad", "reverse", "gather",
           "scatter", "reduce", "reduce-window", "sort", "select-and-scatter",
           "copy-start", "copy-done"}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_text: str
    operand_names: list[str]
    attrs: str
    result_bytes: int
    result_elems: int
    raw: str = ""
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    params: dict[str, str]            # param name -> type text
    ops: list[Op]
    shapes: dict[str, str]            # value name -> result type text


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    transcendentals: float = 0.0
    hbm_bytes: float = 0.0
    collective_link_bytes: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"count": 0.0, "bytes": 0.0}))
    op_counts: Counter = dataclasses.field(default_factory=Counter)

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_link_bytes += other.collective_link_bytes * mult
        for k, v in other.collectives.items():
            self.collectives[k]["count"] += v["count"] * mult
            self.collectives[k]["bytes"] += v["bytes"] * mult
        for k, v in other.op_counts.items():
            self.op_counts[k] += int(v * mult)


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: dict[str, Totals] = {}

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str):
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and "{" in line and "->" in line:
                is_entry = line.lstrip().startswith("ENTRY")
                hdr = line.lstrip()
                if hdr.startswith("ENTRY"):
                    hdr = hdr[len("ENTRY"):].lstrip()
                name = hdr.split()[0].lstrip("%")
                params = {}
                pstart = hdr.find("(")
                pend = hdr.find(") ->")
                if 0 <= pstart < pend:
                    for part in hdr[pstart + 1:pend].split(","):
                        part = part.strip()
                        if ":" in part:
                            pname, ptype = part.split(":", 1)
                            params[pname.strip().lstrip("%")] = ptype.strip()
                cur = Computation(name, params, [], dict(params))
                self.computations[name] = cur
                if is_entry:
                    self.entry = name
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            res_name, result_text, kind, rest = m.groups()
            # operands = %refs inside the first paren group (up to matching
            # close; approximation: up to '), ' attr separator)
            close = rest.find(")")
            operand_text = rest[:close] if close >= 0 else rest
            attrs = rest[close + 1:] if close >= 0 else ""
            operands = _OPERAND_RE.findall(operand_text)
            op = Op(res_name, kind, result_text, operands, attrs,
                    _shape_bytes(result_text), _shape_elems(result_text),
                    raw=line, is_root=line.lstrip().startswith("ROOT"))
            cur.ops.append(op)
            cur.shapes[res_name] = result_text

    # -------------------------------------------------------- trip counts
    def trip_count(self, cond_name: str) -> float:
        """Recover the trip count from a jax-style loop condition.

        jax emits ``iter < N`` (possibly with the compare wrapped in a kLoop
        fusion), so the largest scalar integer constant in the condition
        computation is the trip count. Conditions carry no other integer
        constants in jax-lowered programs."""
        comp = self.computations.get(cond_name)
        if comp is None:
            return 1.0
        best = None
        for op in comp.ops:
            if op.kind != "constant" or "s32[]" not in op.result_text:
                continue
            mm = re.search(r"constant\((-?\d+)\)", op.raw)
            if mm:
                v = int(mm.group(1))
                best = v if best is None else max(best, v)
        if best is None or best <= 0:
            return 1.0
        return float(best)

    # ---------------------------------------------------- byte accounting
    # HBM-traffic proxy refinements: a dynamic-slice reads only its result-
    # sized window (NOT the whole operand — critical for scan-stacked
    # weights), and a dynamic-update-slice writes only the update window
    # (XLA aliases the rest in place).
    _SLICERS = ("dynamic-slice", "slice", "gather")

    def _param_uses(self, comp: Computation):
        """parameter index -> list of ops consuming that parameter."""
        idx_of = {}
        for op in comp.ops:
            if op.kind == "parameter":
                m = re.search(r"parameter\((\d+)\)", op.raw)
                if m:
                    idx_of[op.name] = int(m.group(1))
        uses: dict[int, list[Op]] = {}
        for op in comp.ops:
            for o in op.operand_names:
                if o in idx_of:
                    uses.setdefault(idx_of[o], []).append(op)
        return uses

    def _fusion_bytes(self, comp: Computation, op: Op) -> float:
        """Operand+result bytes of a fusion, discounting slice-only reads
        and update-slice writes."""
        called_m = re.search(r"calls=%?([\w.\-]+)", op.attrs or "")
        called = self.computations.get(called_m.group(1)) if called_m else None
        total = 0.0
        uses = self._param_uses(called) if called else {}
        dus_ops = [x for x in (called.ops if called else [])
                   if x.kind == "dynamic-update-slice"]
        for i, oname in enumerate(op.operand_names):
            full = _shape_bytes(comp.shapes.get(oname, ""))
            u = uses.get(i)
            if u and all(x.kind in self._SLICERS for x in u):
                total += sum(x.result_bytes for x in u)
            elif dus_ops and full == op.result_bytes:
                # in-place update target (possibly behind converts): jax scan
                # stacking donates/aliases the buffer; only the window moves
                pass
            else:
                total += full
        if dus_ops:
            # result write = the update window(s), not the whole buffer
            for upd in dus_ops:
                ub = min((_shape_bytes(called.shapes.get(o, ""))
                          for o in upd.operand_names[1:2]), default=0)
                total += 2 * ub
            return total
        total += op.result_bytes
        return total

    # ------------------------------------------------------------- costing
    def _group_size(self, op: Op, default: int) -> int:
        m = _GROUPS_RE.search(op.attrs or "")
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(op.attrs or "")
        if m:
            return len(m.group(1).split(","))
        return default

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        lhs = comp.shapes.get(op.operand_names[0], "") if op.operand_names else ""
        dims_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs or "")
        lhs_shapes = _SHAPE_RE.findall(lhs)
        if not dims_m or not lhs_shapes:
            return 2.0 * op.result_elems  # fallback
        dims = [int(d) for d in dims_m.group(1).split(",") if d]
        lhs_dims = [int(d) for d in lhs_shapes[0][1].split(",") if d]
        k = 1
        for d in dims:
            if d < len(lhs_dims):
                k *= lhs_dims[d]
        return 2.0 * op.result_elems * k

    def cost(self, comp_name: Optional[str] = None, *,
             default_group: int = 1) -> Totals:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.computations.get(comp_name)
        t = Totals()
        if comp is None:
            return t
        self._memo[comp_name] = t  # break cycles defensively
        for op in comp.ops:
            t.op_counts[op.kind] += 1
            kind = op.kind
            base = kind.replace("-start", "")
            if base in COLLECTIVES:
                n = self._group_size(op, default_group)
                out_b = op.result_bytes
                if base == "all-reduce":
                    link = 2.0 * out_b * (n - 1) / max(n, 1)
                elif base == "all-gather":
                    link = out_b * (n - 1) / max(n, 1)
                elif base == "reduce-scatter":
                    link = out_b * (n - 1)        # operand = out*n
                elif base == "all-to-all":
                    link = out_b * (n - 1) / max(n, 1)
                else:  # collective-permute
                    link = out_b
                t.collectives[base]["count"] += 1
                t.collectives[base]["bytes"] += out_b
                t.collective_link_bytes += link
                t.hbm_bytes += 2 * out_b
                continue
            if kind in ("all-gather-done", "all-reduce-done", "copy-done",
                        "collective-permute-done"):
                continue
            if kind == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.attrs or "")
                cond = re.search(r"condition=%?([\w.\-]+)", op.attrs or "")
                trip = self.trip_count(cond.group(1)) if cond else 1.0
                if body:
                    t.add(self.cost(body.group(1),
                                    default_group=default_group), trip)
                if cond:
                    t.add(self.cost(cond.group(1),
                                    default_group=default_group), trip)
                continue
            if kind == "fusion":
                called = re.search(r"calls=%?([\w.\-]+)", op.attrs or "")
                if called:
                    sub = self.cost(called.group(1), default_group=default_group)
                    # flops from inside the fusion; bytes at fusion boundary
                    t.flops += sub.flops
                    t.transcendentals += sub.transcendentals
                t.hbm_bytes += self._fusion_bytes(comp, op)
                continue
            if kind in ("call", "async-start"):
                called = re.search(r"(?:calls|called_computation)=%?([\w.\-]+)",
                                   op.attrs or "")
                if called:
                    t.add(self.cost(called.group(1),
                                    default_group=default_group), 1.0)
                continue
            if kind == "conditional":
                branches = re.findall(
                    r"(?:true_computation|false_computation|branch_computations=\{)"
                    r"=?%?([\w.\-]+)", op.attrs or "")
                if branches:
                    costs = [self.cost(b, default_group=default_group)
                             for b in branches]
                    best = max(costs, key=lambda c: c.flops)
                    t.add(best, 1.0)
                continue
            if kind in _FREE:
                continue
            if kind == "dot":
                t.flops += self._dot_flops(comp, op)
                operand_b = sum(_shape_bytes(comp.shapes.get(o, ""))
                                for o in op.operand_names)
                t.hbm_bytes += operand_b + op.result_bytes
                continue
            if kind == "convolution":
                t.flops += 2.0 * op.result_elems  # no convs in this codebase
                t.hbm_bytes += op.result_bytes * 2
                continue
            if kind in _TRANSCENDENTAL:
                t.transcendentals += op.result_elems
                t.flops += op.result_elems
                t.hbm_bytes += 2 * op.result_bytes
                continue
            if kind in _ELEMENTWISE:
                t.flops += op.result_elems
                operand_b = sum(_shape_bytes(comp.shapes.get(o, ""))
                                for o in op.operand_names)
                t.hbm_bytes += operand_b + op.result_bytes
                continue
            if kind in self._SLICERS:
                t.hbm_bytes += 2 * op.result_bytes
                continue
            if kind == "dynamic-update-slice":
                upd = min((_shape_bytes(comp.shapes.get(o, ""))
                           for o in op.operand_names[1:2]), default=0)
                t.hbm_bytes += 2 * upd
                continue
            if kind in _MOVERS:
                operand_b = sum(_shape_bytes(comp.shapes.get(o, ""))
                                for o in op.operand_names)
                t.hbm_bytes += operand_b + op.result_bytes
                continue
            # unknown op: count bytes conservatively
            t.hbm_bytes += op.result_bytes
        return t


def analyze(text: str, *, default_group: int = 1) -> dict:
    mod = HloModule(text)
    t = mod.cost(default_group=default_group)
    return {
        "entry": mod.entry,
        "flops": t.flops,
        "transcendentals": t.transcendentals,
        "hbm_bytes": t.hbm_bytes,
        "collective_link_bytes": t.collective_link_bytes,
        "collectives": {k: dict(v) for k, v in t.collectives.items()},
        "op_counts": dict(t.op_counts.most_common(30)),
    }
