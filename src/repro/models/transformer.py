"""One composable decoder covering all ten assigned architectures.

The block *pattern* (repeating unit of mixer kinds) is scanned over with
stacked params (`num_units` leading dim) so HLO size is ~O(len(pattern)),
not O(num_layers); a non-scanned *tail* covers ``num_layers % len(pattern)``.

Forward paths:
  * ``forward``       — training / prefill body: (B,S) tokens or (B,S,D)
                        frames -> (B,S,D) hidden (+ MoE aux loss).
  * ``prefill``       — forward + returns decode caches filled at seq end.
  * ``decode_step``   — one token with per-layer caches (KV / recurrent).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN_KINDS, ModelConfig
from repro.launch.sharding import constrain
from repro.nn import attention as attn
from repro.nn import moe as moe_mod
from repro.nn import recurrent as rec
from repro.nn.layers import (Init, apply_norm, compute_dtype, dense, init_norm,
                             mlp, init_mlp, sinusoidal_positions_dynamic)


# ----------------------------------------------------------------- block init
def _init_block(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    p = {"norm1": init_norm(ks[0], cfg.d_model, cfg.norm)}
    if kind in ATTN_KINDS:
        p["mixer"] = attn.init_attn(ks[1], cfg)
    elif kind == "rglru":
        p["mixer"] = rec.init_rglru_block(ks[1], cfg)
    elif kind == "mlstm":
        p["mixer"] = rec.init_mlstm_block(ks[1], cfg)
    elif kind == "slstm":
        p["mixer"] = rec.init_slstm_block(ks[1], cfg)
    else:
        raise ValueError(kind)
    if cfg.sandwich_norm:
        p["post1"] = init_norm(ks[2], cfg.d_model, cfg.norm)
    if _has_ffn(cfg, kind):
        p["norm2"] = init_norm(ks[2], cfg.d_model, cfg.norm)
        if cfg.ffn == "moe":
            p["ffn"] = moe_mod.init_moe(ks[3], cfg)
        else:
            p["ffn"] = init_mlp(ks[3], cfg)
        if cfg.sandwich_norm:
            p["post2"] = init_norm(ks[1], cfg.d_model, cfg.norm)
    return p


def _has_ffn(cfg: ModelConfig, kind: str) -> bool:
    return cfg.ffn != "none" and (kind in ATTN_KINDS or kind == "rglru")


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4 + len(cfg.tail_pattern))
    params = {}
    if cfg.embed_mode == "tokens":
        params["embed"] = Init(ks[0], (cfg.vocab_size, cfg.d_model), cfg.param_dtype)
    params["lm_head"] = Init(ks[1], (cfg.d_model, cfg.vocab_size), cfg.param_dtype)
    params["final_norm"] = init_norm(ks[2], cfg.d_model, cfg.norm)

    def unit_init(k):
        kk = jax.random.split(k, len(cfg.pattern))
        return {f"b{i}": _init_block(kk[i], cfg, kind)
                for i, kind in enumerate(cfg.pattern)}

    unit_keys = jax.random.split(ks[3], cfg.num_units)
    params["units"] = jax.vmap(unit_init)(unit_keys)  # stacked on axis 0
    for i, kind in enumerate(cfg.tail_pattern):
        params[f"tail{i}"] = _init_block(ks[4 + i], cfg, kind)
    return params


def param_shapes(cfg: ModelConfig):
    """ShapeDtypeStructs of the params without allocating (for dry-run)."""
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


# -------------------------------------------------------------- block forward
def _apply_block(p, x, cfg: ModelConfig, kind: str, positions):
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind in ATTN_KINDS:
        h = attn.attn_forward(p["mixer"], h, cfg, kind, positions)
    elif kind == "rglru":
        h = rec.rglru_forward(p["mixer"], h, cfg)
    elif kind == "mlstm":
        h = rec.mlstm_forward(p["mixer"], h, cfg)
    elif kind == "slstm":
        h = rec.slstm_forward(p["mixer"], h, cfg)
    if cfg.sandwich_norm:
        h = apply_norm(p["post1"], h, cfg.norm)
    x = x + h
    x = constrain(x, ("batch", "seq", "dmodel"))
    aux = jnp.zeros((), jnp.float32)
    if _has_ffn(cfg, kind):
        h = apply_norm(p["norm2"], x, cfg.norm)
        if cfg.ffn == "moe":
            h, aux = moe_mod.moe_forward(p["ffn"], h, cfg)
        else:
            h = mlp(p["ffn"], h, cfg)
        if cfg.sandwich_norm:
            h = apply_norm(p["post2"], h, cfg.norm)
        x = x + h
        x = constrain(x, ("batch", "seq", "dmodel"))
    return x, aux


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def embed_inputs(params, cfg: ModelConfig, inputs, positions):
    dt = compute_dtype(cfg.dtype)
    if cfg.embed_mode == "tokens":
        x = jnp.take(params["embed"], inputs, axis=0).astype(dt)
    else:
        x = inputs.astype(dt)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    if cfg.pos_emb == "sinusoidal":
        B, S = positions.shape
        pe = sinusoidal_positions_dynamic(positions.reshape(-1), cfg.d_model)
        x = x + pe.reshape(B, S, cfg.d_model).astype(cfg.dtype)
    return constrain(x, ("batch", "seq", "dmodel"))


def forward(params, cfg: ModelConfig, inputs, positions):
    """Body -> (hidden (B,S,D), moe_aux_mean)."""
    x = embed_inputs(params, cfg, inputs, positions)

    def unit_step(carry, unit_params):
        x, aux = carry
        for i, kind in enumerate(cfg.pattern):
            x, a = _apply_block(unit_params[f"b{i}"], x, cfg, kind, positions)
            aux = aux + a
        return (x, aux), None

    step = _remat_wrap(unit_step, cfg)
    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                               params["units"])
    for i, kind in enumerate(cfg.tail_pattern):
        x, a = _apply_block(params[f"tail{i}"], x, cfg, kind, positions)
        aux = aux + a
    x = apply_norm(params["final_norm"], x, cfg.norm)
    n_ffn = sum(_has_ffn(cfg, k) for k in
                list(cfg.pattern) * cfg.num_units + list(cfg.tail_pattern))
    return x, aux / max(n_ffn, 1)


def logits_fn(params, cfg: ModelConfig, hidden):
    logits = dense(hidden, params["lm_head"]).astype(jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


# ------------------------------------------------------------------- caches
def _block_cache(cfg: ModelConfig, kind: str, batch, capacity):
    if kind in ATTN_KINDS:
        return attn.init_kv_cache(cfg, batch, capacity)
    if kind == "rglru":
        return rec.init_rglru_cache(cfg, batch)
    if kind == "mlstm":
        return rec.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return rec.init_slstm_cache(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch, capacity):
    """Stacked caches: units caches have leading num_units dim."""
    def unit_cache(_):
        return {f"b{i}": _block_cache(cfg, kind, batch, capacity)
                for i, kind in enumerate(cfg.pattern)}
    cache = {"units": jax.vmap(unit_cache)(jnp.arange(cfg.num_units))}
    for i, kind in enumerate(cfg.tail_pattern):
        cache[f"tail{i}"] = _block_cache(cfg, kind, batch, capacity)
    return cache


def cache_shapes(cfg: ModelConfig, batch, capacity):
    return jax.eval_shape(lambda: init_cache(cfg, batch, capacity))


def _prefill_block(p, x, cfg: ModelConfig, kind: str, positions, capacity):
    """Like _apply_block but also returns the block's decode cache."""
    B, S = x.shape[:2]
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind in ATTN_KINDS:
        h, kv = attn.attn_forward(p["mixer"], h, cfg, kind, positions,
                                  return_kv=True)
        pad = capacity - S
        cache = {
            "k": jnp.pad(kv["k"], ((0, 0), (0, 0), (0, pad), (0, 0))),
            "v": jnp.pad(kv["v"], ((0, 0), (0, 0), (0, pad), (0, 0))),
        }
    elif kind == "rglru":
        h, cache = rec.rglru_forward(p["mixer"], h, cfg, return_state=True)
    elif kind == "mlstm":
        h, cache = rec.mlstm_forward(p["mixer"], h, cfg, return_state=True)
    elif kind == "slstm":
        h, cache = rec.slstm_forward(p["mixer"], h, cfg, return_state=True)
    if cfg.sandwich_norm:
        h = apply_norm(p["post1"], h, cfg.norm)
    x = x + h
    x = constrain(x, ("batch", "seq", "dmodel"))
    if _has_ffn(cfg, kind):
        h = apply_norm(p["norm2"], x, cfg.norm)
        if cfg.ffn == "moe":
            h, _ = moe_mod.moe_forward(p["ffn"], h, cfg)
        else:
            h = mlp(p["ffn"], h, cfg)
        if cfg.sandwich_norm:
            h = apply_norm(p["post2"], h, cfg.norm)
        x = x + h
        x = constrain(x, ("batch", "seq", "dmodel"))
    return x, cache


def prefill(params, cfg: ModelConfig, inputs, capacity=None):
    """Run the full prompt, return (last-position logits, decode cache)."""
    B, S = inputs.shape[:2]
    capacity = capacity or S
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = embed_inputs(params, cfg, inputs, positions)

    def unit_step(x, unit_params):
        caches = {}
        for i, kind in enumerate(cfg.pattern):
            x, caches[f"b{i}"] = _prefill_block(
                unit_params[f"b{i}"], x, cfg, kind, positions, capacity)
        return x, caches

    x, unit_caches = jax.lax.scan(unit_step, x, params["units"])
    cache = {"units": unit_caches}
    for i, kind in enumerate(cfg.tail_pattern):
        x, cache[f"tail{i}"] = _prefill_block(
            params[f"tail{i}"], x, cfg, kind, positions, capacity)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    last = x[:, -1:]
    return logits_fn(params, cfg, last), cache


def _decode_block(p, c, x, cfg: ModelConfig, kind: str, pos):
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind in ATTN_KINDS:
        h, c = attn.attn_decode(p["mixer"], h, cfg, kind, c, pos)
    elif kind == "rglru":
        h, c = rec.rglru_decode(p["mixer"], h, cfg, c)
    elif kind == "mlstm":
        h, c = rec.mlstm_decode(p["mixer"], h, cfg, c)
    elif kind == "slstm":
        h, c = rec.slstm_decode(p["mixer"], h, cfg, c)
    if cfg.sandwich_norm:
        h = apply_norm(p["post1"], h, cfg.norm)
    x = x + h
    if _has_ffn(cfg, kind):
        h = apply_norm(p["norm2"], x, cfg.norm)
        if cfg.ffn == "moe":
            h, _ = moe_mod.moe_forward(p["ffn"], h, cfg)
        else:
            h = mlp(p["ffn"], h, cfg)
        if cfg.sandwich_norm:
            h = apply_norm(p["post2"], h, cfg.norm)
        x = x + h
    return x, c


def decode_step(params, cfg: ModelConfig, cache, inputs, pos):
    """One decode step. inputs: (B,1) tokens or (B,1,D) frames; pos scalar.
    Returns (logits (B,1,V), new_cache)."""
    B = inputs.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    x = embed_inputs(params, cfg, inputs, positions)

    def unit_step(x, scanned):
        unit_params, unit_cache = scanned
        new_cache = {}
        for i, kind in enumerate(cfg.pattern):
            x, new_cache[f"b{i}"] = _decode_block(
                unit_params[f"b{i}"], unit_cache[f"b{i}"], x, cfg, kind, pos)
        return x, new_cache

    x, new_unit_caches = jax.lax.scan(
        unit_step, x, (params["units"], cache["units"]))
    new_cache = {"units": new_unit_caches}
    for i, kind in enumerate(cfg.tail_pattern):
        x, new_cache[f"tail{i}"] = _decode_block(
            params[f"tail{i}"], cache[f"tail{i}"], x, cfg, kind, pos)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return logits_fn(params, cfg, x), new_cache
