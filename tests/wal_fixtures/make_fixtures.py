"""Regenerate the committed WAL fixture corpus (deterministic bytes).

Usage::

    PYTHONPATH=src python tests/wal_fixtures/make_fixtures.py [out_dir]

The fixtures pin the on-disk record framing: if ``encode_record`` ever
changes shape, ``test_fixture_corpus_matches_generator`` fails loudly
instead of silently re-blessing new bytes. Each file exercises one
failure class of ``scan_segment``:

* ``interleaved.wal``  — four valid records (epoch 2 empty): clean scan.
* ``torn_tail.wal``    — two valid records + one cut mid-body: torn
  write, truncate-and-warn territory.
* ``truncated_prefix.wal`` — one valid record + 7 bytes of a header:
  torn mid-header (warns as a tail; corruption for a closed segment).
* ``bad_crc.wal``      — valid / bit-flipped body / valid: mid-segment
  corruption, always a typed error naming segment + offset.
* ``bad_length.wal``   — valid record + a length prefix beyond the
  framing bound: unframeable, always a typed error.
"""
import pathlib
import sys

import numpy as np

from repro.core.versioned import Version
from repro.graph.wal import encode_record, rows_to_body


def _rows(epoch: int, n: int) -> np.ndarray:
    """Deterministic payload rows: kind cycles 0..2, ids walk a ramp."""
    base = np.arange(n, dtype=np.int32)
    return np.stack([base % 3, base * 7 + epoch, base * 11 + 1,
                     np.full(n, epoch * 4096, np.int32)], axis=1)


def _record(epoch: int, n: int) -> bytes:
    return encode_record(Version(epoch, 0).pack(), rows_to_body(_rows(epoch, n)))


def write_fixtures(out_dir) -> dict[str, bytes]:
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    good = [_record(0, 5), _record(1, 3), _record(2, 0), _record(3, 8)]
    files = {
        "interleaved.wal": b"".join(good),
        # third record loses its last 10 bytes: torn mid-body
        "torn_tail.wal": good[0] + good[1] + _record(2, 4)[:-10],
        # 7 bytes cannot even hold the 16-byte header: torn mid-header
        "truncated_prefix.wal": good[0] + good[1][:7],
        # flip one body byte of the middle record: CRC must catch it
        "bad_crc.wal": good[0]
        + bytes(b ^ (0x40 if i == len(good[1]) - 1 else 0)
                for i, b in enumerate(good[1]))
        + good[2],
        # length prefix far beyond MAX_BODY: unframeable corruption
        "bad_length.wal": good[0]
        + (1 << 31).to_bytes(4, "big") + bytes(12),
    }
    for name, data in files.items():
        (out / name).write_bytes(data)
    return files


if __name__ == "__main__":
    target = sys.argv[1] if len(sys.argv) > 1 else \
        pathlib.Path(__file__).parent
    for name in sorted(write_fixtures(target)):
        print("wrote", pathlib.Path(target) / name)
