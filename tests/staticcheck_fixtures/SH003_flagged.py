"""SH003 fixture: raw '>> 32' version unpack outside core/versioned.py."""


def epoch_of(packed: int) -> int:
    return packed >> 32                      # SH003: raw unpack


def is_sealed(log, frontier):
    return [(v >> 32) <= frontier for v in log]   # SH003: raw unpack
