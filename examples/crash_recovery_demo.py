"""Crash-recovery walkthrough: kill -9 a serving process, recover, audit.

The durable graph plane's whole claim in one script (the chaos job runs
it in CI):

1. launch ``python -m repro.launch.serve_graph --rpc-port 0 --wal-dir …``
   as a subprocess — a real serving process appending every sealed epoch
   to its write-ahead log and dropping a graph checkpoint every 4 epochs,
2. poll its RPC stats until the stream is several epochs in, then
   ``SIGKILL`` it mid-stream — no atexit, no flush, no goodbye,
3. recover the store in-process from the WAL directory alone and audit
   it byte-identical against an *uncrashed oracle* (the same stream
   replayed into a fresh store) at every epoch up to the durable
   frontier — torn tails are truncated, never guessed at,
4. relaunch the server with ``--recover``: it resumes the stream after
   the durable frontier, drains the remaining epochs, and answers
   queries at the full final version.

The durable frontier is the *minimum* over the control log's commit
records and every shard's intact WAL records, so whatever the kill tore
off the end costs recovery depth, never correctness (``docs/
ARCHITECTURE.md`` "Durability & recovery" has the argument).

    PYTHONPATH=src python examples/crash_recovery_demo.py
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.core.versioned import Version
from repro.graph.dyngraph import synthesize_churn_stream
from repro.graph.query import KHop
from repro.graph.sharded import ShardedDynamicGraph
from repro.launch.rpc import GraphRPCClient

VERTICES = 600
EPOCHS = 10
ADDS = 400
SHARDS = 2
SEED = 7
CKPT_EVERY = 4
KILL_AFTER_EPOCH = 5          # past the epoch-3 checkpoint + WAL sync


def launch(wal_dir: str, *, recover: bool) -> subprocess.Popen:
    env = dict(os.environ, PYTHONPATH="src")
    cmd = [sys.executable, "-m", "repro.launch.serve_graph",
           "--rpc-port", "0", "--wal-dir", wal_dir,
           "--checkpoint-every", str(CKPT_EVERY),
           "--vertices", str(VERTICES), "--epochs", str(EPOCHS),
           "--adds-per-epoch", str(ADDS), "--shards", str(SHARDS),
           "--seed", str(SEED), "--ingest-delay-s", "0.05"]
    if recover:
        cmd.append("--recover")
    return subprocess.Popen(cmd, stdin=subprocess.PIPE,
                            stdout=subprocess.PIPE, text=True, env=env)


def read_until(proc: subprocess.Popen, pattern: str) -> re.Match:
    while True:
        line = proc.stdout.readline()
        if not line:
            raise RuntimeError(f"server exited before printing "
                               f"{pattern!r}")
        m = re.match(pattern, line)
        if m:
            return m


def serving_epoch(cli: GraphRPCClient) -> int:
    packed = cli.stats()["serving_version"]
    return -1 if packed is None else Version.unpack(packed).epoch


def main() -> None:
    wal_dir = tempfile.mkdtemp(prefix="crash_demo_wal_")

    # 1-2: serve with the WAL on, kill -9 mid-stream -----------------------
    proc = launch(wal_dir, recover=False)
    m = read_until(proc, r"RPC listening on (\S+):(\d+)")
    host, port = m.group(1), int(m.group(2))
    print(f"serving subprocess up at {host}:{port}, WAL in {wal_dir}")
    deadline = time.monotonic() + 30.0
    with GraphRPCClient(host, port) as cli:
        while serving_epoch(cli) < KILL_AFTER_EPOCH:
            if time.monotonic() > deadline:
                raise RuntimeError("stream never reached the kill epoch")
            time.sleep(0.02)
        seen = serving_epoch(cli)
    proc.kill()                                   # SIGKILL: no cleanup
    proc.wait(timeout=30)
    print(f"killed -9 while serving epoch {seen} (of {EPOCHS})")

    # 3: recover from the log alone, audit against an uncrashed oracle ----
    rec = ShardedDynamicGraph.recover(wal_dir)
    frontier = rec.coordinator.global_frontier
    assert CKPT_EVERY - 1 <= frontier < EPOCHS, frontier
    print(f"recovered at durable frontier {frontier} "
          f"(whatever the kill tore off was truncated, not guessed)")

    batches = synthesize_churn_stream(VERTICES, EPOCHS, ADDS, seed=SEED,
                                      delete_frac=0.2)
    e_max = sum(len(b.add_src) for b in batches) + 16
    oracle = ShardedDynamicGraph(SHARDS, VERTICES, e_max)
    audited = 0
    for b in batches[:frontier + 1]:
        oracle.ingest(b)
        oracle.seal_epoch(b.version.epoch)
        got = rec.join_view(b.version)
        want = oracle.join_view(b.version)
        for field in ("offsets", "src", "dst"):
            assert np.array_equal(getattr(got, field),
                                  getattr(want, field)), \
                f"epoch {b.version.epoch}: {field} diverged"
        audited += 1
    print(f"audit: {audited} recovered views byte-identical to the "
          f"uncrashed oracle")
    for w in rec.wal_shards:                      # release the log before
        if w is not None:                         # the relaunch reopens it
            w.close()
    rec.wal.close()

    # 4: relaunch with --recover and drain the rest of the stream ---------
    proc = launch(wal_dir, recover=True)
    try:
        m = read_until(proc, r"recovered at durable frontier (\d+); "
                             r"resuming (\d+) remaining epochs")
        assert int(m.group(1)) == frontier, m.group(1)
        print(f"relaunched: resuming {m.group(2)} epochs after "
              f"frontier {m.group(1)}")
        m = read_until(proc, r"RPC listening on (\S+):(\d+)")
        host, port = m.group(1), int(m.group(2))
        read_until(proc, r"stream drained")
        with GraphRPCClient(host, port) as cli:
            final = serving_epoch(cli)
            assert final == EPOCHS - 1, final
            r = cli.query(KHop(source=0, k=2), deadline_s=30.0)
            assert r.ok and r.version.epoch == EPOCHS - 1
        print(f"resumed server drained the stream and answers at "
              f"epoch {final}")
    finally:
        proc.stdin.close()                        # the shutdown signal
        proc.wait(timeout=30)
    print("OK: kill -9 lost nothing the log had; recovery matched the "
          "oracle and serving resumed")


if __name__ == "__main__":
    main()
