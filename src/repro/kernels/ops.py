"""jit'd public wrappers for the Pallas kernels.

On the CPU container the kernels execute in interpret mode (the kernel body
runs in Python op-by-op — bit-accurate control flow, no Mosaic); on TPU they
compile natively. ``repro.nn``/``repro.graph`` call through this module so
the switch is one place.
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import lru_scan as _lru
from repro.kernels import segment_sum as _ss
from repro.kernels import snapshot_resolve as _sr


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def segment_sum(values, segment_ids, num_segments, **kw):
    kw.setdefault("interpret", _interpret())
    return _ss.segment_sum(values, segment_ids, num_segments, **kw)


def snapshot_resolve(versions, values, query_version, **kw):
    kw.setdefault("interpret", _interpret())
    return _sr.snapshot_resolve(versions, values, query_version, **kw)


def liveness_mask(created, deleted, query_version, **kw):
    """Snapshot-mask hot path: expects the int32 data-plane stamp packing
    the graph store uses natively (sentinel = int32 max), so the stored
    ``created``/``deleted`` arrays feed the kernel without conversion."""
    kw.setdefault("interpret", _interpret())
    return _sr.liveness_mask(created, deleted, query_version, **kw)


def flash_attention(q, k, v, *, causal=True, window=None, **kw):
    kw.setdefault("interpret", _interpret())
    return _fa.flash_attention(q, k, v, causal=causal, window=window, **kw)


def lru_scan(a, b, h0=None, **kw):
    kw.setdefault("interpret", _interpret())
    return _lru.lru_scan(a, b, h0, **kw)
