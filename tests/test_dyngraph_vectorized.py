"""Vectorized-ingestion equivalence tests.

The vectorized ``DynamicGraph`` (hash-indexed deletes, delta-patched join
views, Pallas-routed snapshot masks) must be observationally identical to
the loop-based reference (``repro.graph.reference.LoopDynamicGraph``):
byte-identical CSRs (offsets/src/dst/degrees) on add-heavy, delete-heavy,
and re-add-after-delete streams, with the delta-patch path exercised
explicitly against full rebuilds.
"""
import numpy as np
import pytest

from repro.core.versioned import Version
from repro.graph import compute as gc
from repro.graph.dyngraph import (MAXV, DynamicGraph, MutationBatch,
                                  synthesize_churn_stream, synthesize_stream)
from repro.graph.reference import LoopDynamicGraph


def _assert_views_equal(g: DynamicGraph, ref: LoopDynamicGraph, version):
    view = g.join_view(version)
    offsets, src, dst, out_deg, in_deg = ref.join_view_arrays(version)
    np.testing.assert_array_equal(np.asarray(view.offsets), offsets)
    np.testing.assert_array_equal(np.asarray(view.src), src)
    np.testing.assert_array_equal(np.asarray(view.dst), dst)
    np.testing.assert_array_equal(np.asarray(view.out_degree),
                                  out_deg.astype(np.float32))
    np.testing.assert_array_equal(np.asarray(view.in_degree),
                                  in_deg.astype(np.float32))


@pytest.mark.parametrize("delete_frac,readd_frac", [
    (0.0, 0.0),     # add-heavy
    (0.4, 0.0),     # delete-heavy
    (0.3, 0.5),     # re-add-after-delete
])
def test_vectorized_apply_matches_loop_reference(delete_frac, readd_frac):
    n, epochs, adds = 32, 6, 50
    batches = synthesize_churn_stream(n, epochs, adds, seed=11,
                                      delete_frac=delete_frac,
                                      readd_frac=readd_frac)
    g = DynamicGraph(n, 4096)
    ref = LoopDynamicGraph(n, 4096)
    for b in batches:
        g.apply(b)
        ref.apply(b)
        np.testing.assert_array_equal(g.snapshot_mask(b.version),
                                      ref.snapshot_mask(b.version))
    for e in range(epochs):
        _assert_views_equal(g, ref, Version(e, 0))
    assert g.n_vertices == ref.n_vertices
    np.testing.assert_array_equal(g.v_created, ref.v_created)


@pytest.mark.parametrize("delete_frac", [0.0, 0.3])
def test_delta_patch_matches_full_rebuild(delete_frac):
    """Sequential snapshots hit the delta path; a fresh graph replaying the
    same batches with cold caches does full rebuilds — CSRs must match."""
    n, epochs, adds = 48, 8, 40
    batches = synthesize_churn_stream(n, epochs, adds, seed=5,
                                      delete_frac=delete_frac,
                                      readd_frac=0.25)
    # high churn threshold forces the delta-patch path on every epoch
    g = DynamicGraph(n, 4096, churn_threshold=10.0)
    cold = DynamicGraph(n, 4096)
    for b in batches:
        g.apply(b)
        cold.apply(b)
        g.join_view(b.version)    # incremental: patch previous epoch's view
    assert g.view_delta_patches > 0
    for e in range(epochs):
        v = Version(e, 0)
        warm = g._views[v.pack()]
        full = cold._full_rebuild(v)
        np.testing.assert_array_equal(np.asarray(warm.offsets),
                                      np.asarray(full.offsets))
        np.testing.assert_array_equal(np.asarray(warm.src),
                                      np.asarray(full.src))
        np.testing.assert_array_equal(np.asarray(warm.dst),
                                      np.asarray(full.dst))
        np.testing.assert_array_equal(warm.np_in_deg, full.np_in_deg)
        np.testing.assert_array_equal(warm.np_out_deg, full.np_out_deg)


def test_churn_threshold_falls_back_to_rebuild():
    g = DynamicGraph(16, 1024, churn_threshold=0.25)
    g.apply(MutationBatch(Version(0, 0),
                          add_src=np.arange(8, dtype=np.int32),
                          add_dst=np.arange(1, 9, dtype=np.int32) % 16))
    g.join_view(Version(0, 0))
    # delta (16 adds) is 2x the base's 8 rows — must take the rebuild path
    g.apply(MutationBatch(Version(1, 0),
                          add_src=np.arange(16, dtype=np.int32) % 16,
                          add_dst=(np.arange(16, dtype=np.int32) + 3) % 16))
    g.join_view(Version(1, 0))
    assert g.view_delta_patches == 0
    assert g.view_full_builds == 2


def test_gc_views_trims_batch_log_safely():
    """gc_views bounds the ingestion delta log; views requested below the
    trim floor must full-rebuild (never patch from missing records)."""
    batches = synthesize_churn_stream(32, 10, 30, seed=7, delete_frac=0.2)
    g = DynamicGraph(32, 4096, churn_threshold=10.0)
    ref = LoopDynamicGraph(32, 4096)
    for b in batches:
        g.apply(b)
        ref.apply(b)
        g.join_view(b.version)
    assert len(g._batch_log) == 10
    g.gc_views(keep_latest=2)
    # views 8,9 kept -> floor is 8; only the version-9 record lies above it
    assert len(g._batch_log) == 1
    # a pre-floor snapshot is still addressable and byte-identical
    _assert_views_equal(g, ref, Version(3, 0))
    # and it must not serve as a delta base for later versions (records
    # between it and the floor are gone) — results stay correct
    _assert_views_equal(g, ref, Version(4, 0))


def test_gc_views_keeps_version_spaced_ladder():
    """Churn-adaptive GC: retention is a doubling-gap ladder (newest,
    newest-1, newest-3, newest-7, ...) instead of newest-K, so any past
    version keeps a nearby delta-patch base; patched results stay
    byte-identical to full rebuilds from a cold store."""
    batches = synthesize_churn_stream(32, 12, 30, seed=13, delete_frac=0.2)
    g = DynamicGraph(32, 4096, churn_threshold=10.0)
    ref = LoopDynamicGraph(32, 4096)
    for b in batches:
        g.apply(b)
        ref.apply(b)
        g.join_view(b.version)
    g.gc_views(keep_latest=4)
    kept = sorted(Version.unpack(k).epoch for k in g._views)
    assert kept == [7, 9, 10, 11]   # one per doubling-distance bucket
    # a version near an old ladder rung patches from it (not a rebuild)
    before = g.view_delta_patches
    _assert_views_equal(g, ref, Version(8, 0))
    assert g.view_delta_patches == before + 1
    # every epoch stays addressable and byte-identical
    for e in range(12):
        _assert_views_equal(g, ref, Version(e, 0))


def test_gc_views_ladder_converges_under_live_stream():
    """Regression: repeated per-epoch GC must not pin the oldest views —
    the retained span (and therefore the ingestion delta log) has to track
    the frontier, staying bounded by ~2^(budget-1) epochs of churn instead
    of growing with the whole stream."""
    budget = 4
    n_epochs = 40
    batches = synthesize_churn_stream(32, n_epochs, 20, seed=17,
                                      delete_frac=0.2)
    g = DynamicGraph(32, 8192, churn_threshold=10.0)
    for b in batches:
        g.apply(b)
        g.join_view(b.version)
        g.gc_views(keep_latest=budget)
    span = 1 << (budget - 1)
    oldest_kept = Version.unpack(min(g._views)).epoch
    assert oldest_kept >= n_epochs - 1 - span
    assert len(g._batch_log) <= span
    assert Version.unpack(max(g._views)).epoch == n_epochs - 1


def test_gc_views_trims_log_even_without_dropping_views():
    """Regression: a stream that caches few views (<= keep_latest) must
    still get its delta log trimmed by gc_views — the log otherwise grows
    with the whole stream."""
    batches = synthesize_churn_stream(32, 20, 10, seed=3, delete_frac=0.2)
    g = DynamicGraph(32, 4096)
    ref = LoopDynamicGraph(32, 4096)
    for b in batches:
        g.apply(b)
        ref.apply(b)
    g.join_view(batches[-1].version)          # one cached view
    assert len(g._batch_log) == 20
    assert g.gc_views(keep_latest=4) == 0     # nothing to drop...
    assert len(g._batch_log) == 0             # ...but the log still trims
    # with NO cached views the log trims to the newest applied version
    g2 = DynamicGraph(32, 4096)
    for b in batches:
        g2.apply(b)
    g2.gc_views()
    assert len(g2._batch_log) == 0
    # late queries below the floor stay correct (full rebuild, no patch)
    _assert_views_equal(g2, ref, Version(10, 0))
    _assert_views_equal(g2, ref, Version(19, 0))


def test_gc_retired_floor_trims_batch_log_without_successor_view():
    """Regression: the log floor must track ``retire_below`` even when
    ``prune_retired`` cannot fire yet (no post-cutover view cached) —
    previously the still-cached retired views pinned the batch log via
    ``min(views)``, so a serving path that stalls right after a
    re-sharding split kept the log growing with the stream. Retired views
    stay addressable (they just rebuild instead of delta-patching), and
    patching resumes above the floor."""
    batches = synthesize_churn_stream(32, 8, 30, seed=21, delete_frac=0.2)
    g = DynamicGraph(32, 4096, churn_threshold=10.0)
    ref = LoopDynamicGraph(32, 4096)
    for b in batches:
        g.apply(b)
        ref.apply(b)
        g.join_view(b.version)
    floor = Version(8, 0).pack()            # cutover at epoch 8, unsealed
    assert len(g._batch_log) == 8
    dropped = g.gc_views(keep_latest=8, retire_below=floor)
    assert dropped == 0                     # no successor view: none drop
    assert len(g._views) == 8               # retired views keep serving...
    assert len(g._batch_log) == 0           # ...but the log is not pinned
    assert g._log_floor >= floor - 1
    # every epoch stays addressable and byte-identical (full rebuilds —
    # the retired views are no longer usable as delta bases)
    for e in range(8):
        _assert_views_equal(g, ref, Version(e, 0))
    # post-cutover stream: patching resumes above the floor
    for e in (8, 9):
        b = MutationBatch(Version(e, 0),
                          add_src=np.array([e % 5], np.int32),
                          add_dst=np.array([(e + 1) % 7], np.int32))
        g.apply(b)
        ref.apply(b)
        g.join_view(b.version)
    before = g.view_delta_patches
    g.gc_views(keep_latest=8, retire_below=floor)   # successor exists now
    assert all(k >= floor for k in g._views)
    _assert_views_equal(g, ref, Version(9, 0))
    assert g.view_delta_patches >= before


def test_apply_evicts_stale_future_views():
    """Regression: a view cached for a not-yet-applied version must be
    evicted when a batch at or before that version lands."""
    g = DynamicGraph(8, 64)
    g.apply(MutationBatch(Version(0, 0),
                          add_src=np.array([0], np.int32),
                          add_dst=np.array([1], np.int32)))
    future = Version(5, 0)
    assert g.join_view(future).m == 1         # cached beyond the frontier
    g.apply(MutationBatch(Version(2, 0),
                          add_src=np.array([1], np.int32),
                          add_dst=np.array([2], np.int32)))
    assert g.join_view(future).m == 2         # stale cache was evicted
    # views strictly before the new batch stay cached and valid
    assert g.join_view(Version(0, 0)).m == 1


def test_duplicate_edges_and_double_delete():
    """Multi-edges: each delete removes exactly one (the newest) live row."""
    g = DynamicGraph(4, 64)
    ref = LoopDynamicGraph(4, 64)
    b0 = MutationBatch(Version(0, 0),
                       add_src=np.array([0, 0, 0], np.int32),
                       add_dst=np.array([1, 1, 1], np.int32))
    b1 = MutationBatch(Version(1, 0),
                       del_src=np.array([0, 0], np.int32),
                       del_dst=np.array([1, 1], np.int32))
    b2 = MutationBatch(Version(2, 0),    # delete last copy + one no-op delete
                       del_src=np.array([0, 0], np.int32),
                       del_dst=np.array([1, 1], np.int32))
    for b in (b0, b1, b2):
        g.apply(b)
        ref.apply(b)
    for e in range(3):
        _assert_views_equal(g, ref, Version(e, 0))
    assert g.join_view(Version(2, 0)).m == 0


def test_apply_is_atomic_on_capacity_overflow():
    """A batch that exceeds edge capacity must leave the store untouched
    (no vertices created, no views evicted, no version recorded)."""
    g = DynamicGraph(8, 2)
    g.apply(MutationBatch(Version(0, 0),
                          add_src=np.array([0], np.int32),
                          add_dst=np.array([1], np.int32)))
    g.join_view(Version(5, 0))                  # cached future view
    with pytest.raises(MemoryError):
        g.apply(MutationBatch(Version(1, 0),
                              add_src=np.array([2, 3], np.int32),
                              add_dst=np.array([3, 4], np.int32),
                              add_vertices=np.array([7], np.int32),
                              vertex_types=np.array([1], np.int32)))
    assert g.n_vertices == 2 and g.n_edges == 1
    assert g.v_created[7] == MAXV
    assert Version(5, 0).pack() in g._views     # eviction didn't run
    assert len(g.versions) == 1


def test_synthesize_stream_emits_typed_vertices():
    """Fig 1 type evolution: later epochs must add vertices carrying new
    types (the seed emitted empty arrays — dead code)."""
    g, batches = synthesize_stream(60, 6, 20, seed=3, n_types=3)
    assert any(len(b.add_vertices) > 0 for b in batches)
    assert any(len(b.vertex_types) and b.vertex_types.max() > 0
               for b in batches)
    # the store recorded the per-epoch types
    assert set(np.unique(g.v_type[g.v_created < MAXV])) >= {0, 1, 2}
    # vertex counts per snapshot are monotone in version
    counts = [g.num_vertices(Version(e, 0)) for e in range(6)]
    assert counts == sorted(counts)
    assert counts[-1] > counts[0]


def test_snapshot_mask_kernel_path_matches_numpy():
    g, _ = synthesize_stream(32, 4, 30, seed=9, delete_frac=0.2)
    for e in range(4):
        v = Version(e, 0)
        np.testing.assert_array_equal(g.snapshot_mask(v, use_kernel=True),
                                      g.snapshot_mask(v))


def test_join_group_by_kernel_path_matches_xla():
    import jax.numpy as jnp
    g, _ = synthesize_stream(24, 3, 30, seed=2)
    view = g.join_view(Version(2, 0))
    vals1 = jnp.arange(view.n, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(gc.join_group_by(view, vals1, use_kernel=True)),
        np.asarray(gc.join_group_by(view, vals1)), atol=1e-5)
    vals2 = jnp.stack([vals1, 2 * vals1], axis=1)
    np.testing.assert_allclose(
        np.asarray(gc.join_group_by(view, vals2, use_kernel=True)),
        np.asarray(gc.join_group_by(view, vals2)), atol=1e-5)


def test_kernel_paths_handle_empty_snapshot():
    """Zero live edges (pre-history or fully-deleted snapshots) must not
    crash the kernel-routed reductions or masks."""
    import jax.numpy as jnp
    g = DynamicGraph(8, 16)
    g.apply(MutationBatch(Version(0, 0)))        # empty batch
    view = g.join_view(Version(0, 0))
    assert view.m == 0
    assert g.snapshot_mask(Version(0, 0), use_kernel=True).shape == (0,)
    vals = jnp.ones(8, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(gc.join_group_by(view, vals, use_kernel=True)),
        np.zeros(8, np.float32))
    res = gc.pagerank(view, use_kernel=True, max_iter=5)
    np.testing.assert_allclose(float(np.asarray(res.ranks).sum()), 1.0,
                               atol=1e-6)


def test_dispatch_batch_matches_scalar_dispatch():
    from repro.core.snapshotter import DataNode, IngestNode, Mutation

    rng = np.random.default_rng(0)
    keys = rng.integers(0, 1000, 200)
    epochs = np.sort(rng.integers(0, 3, 200))

    def run(batched):
        nodes = [DataNode(i) for i in range(4)]
        ingest = IngestNode(nodes, route=lambda k: k % 4)
        for e in range(3):
            sel = epochs == e
            if batched:
                ingest.dispatch_batch(keys[sel], epochs[sel])
            else:
                for k in keys[sel]:
                    ingest.dispatch(Mutation(int(k), e))
            for node in nodes:
                node.seal_epoch(e)
            if batched:
                ingest.retry_blocked_batches()
            else:
                ingest.retry_blocked()
        per_node = [n.applied_count for n in nodes]
        return ingest.dispatched, per_node

    assert run(batched=True) == run(batched=False)
