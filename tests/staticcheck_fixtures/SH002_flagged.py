"""SH002 fixture: int64 dtype escapes into the stamp plane."""
import numpy as np


def liveness_mask(created, deleted, q):
    return (created <= q) & (q < deleted)


class Store:
    def __init__(self, e_max):
        self.created = np.zeros(e_max, np.int32)
        self.deleted = np.zeros(e_max, np.int32)

    def widen(self):
        return self.created.astype(np.int64)     # SH002: stamp cast to int64

    def poison(self, rows):
        self.deleted[rows] = np.int64(7)         # SH002: int64 store

    def query(self, q):
        return liveness_mask(self.created.astype(np.int64),   # SH002: kernel
                             self.deleted, q)                 # arg escape
