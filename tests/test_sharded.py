"""Sharded-ingestion equivalence tests.

``ShardedDynamicGraph`` (N dst-hash-routed DynamicGraph shards behind
DataNodes + IngestNode + SnapshotCoordinator) must be observationally
identical to the loop-based single-store reference: byte-identical stitched
CSRs (offsets/src/dst/degrees) for synthesized churn streams at shard
counts {1, 2, 4}, identical vertex tables, frontier-gated snapshot
visibility, and no-wait semantics under straggler shards.
"""
import numpy as np
import pytest

from repro.core.versioned import Version
from repro.graph.dyngraph import (DynamicGraph, MutationBatch,
                                  synthesize_churn_stream, synthesize_stream)
from repro.graph.partition import (distributed_join_group_by,
                                   partition_graph_sharded)
from repro.graph.reference import LoopDynamicGraph
from repro.graph.sharded import (ShardedDynamicGraph, decode_payloads,
                                 encode_mutations)


def _assert_stitched_equal(sg: ShardedDynamicGraph, ref: LoopDynamicGraph,
                           version: Version) -> None:
    view = sg.join_view(version)
    offsets, src, dst, out_deg, in_deg = ref.join_view_arrays(version)
    np.testing.assert_array_equal(np.asarray(view.offsets), offsets)
    np.testing.assert_array_equal(np.asarray(view.src), src)
    np.testing.assert_array_equal(np.asarray(view.dst), dst)
    np.testing.assert_array_equal(view.np_out_deg, out_deg)
    np.testing.assert_array_equal(view.np_in_deg, in_deg)


def _run_equivalence(n_shards, delete_frac, readd_frac, parallel_apply=0):
    n, epochs, adds = 40, 6, 50
    batches = synthesize_churn_stream(n, epochs, adds, seed=11,
                                      delete_frac=delete_frac,
                                      readd_frac=readd_frac)
    sg = ShardedDynamicGraph(n_shards, n, 4096,
                             parallel_apply=parallel_apply)
    ref = LoopDynamicGraph(n, 4096)
    for b in batches:
        sg.apply(b)
        ref.apply(b)
    for e in range(epochs):
        _assert_stitched_equal(sg, ref, Version(e, 0))
    np.testing.assert_array_equal(sg.v_created, ref.v_created)
    assert sg.n_vertices == ref.n_vertices
    assert sg.n_edges == ref.n_edges
    sg.shutdown()


@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("delete_frac,readd_frac", [
    (0.0, 0.0),     # add-heavy
    (0.4, 0.0),     # delete-heavy
    (0.3, 0.5),     # re-add-after-delete
])
def test_sharded_matches_loop_reference(n_shards, delete_frac, readd_frac):
    _run_equivalence(n_shards, delete_frac, readd_frac)


@pytest.mark.threaded
@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("delete_frac,readd_frac", [
    (0.0, 0.0),
    (0.4, 0.0),
    (0.3, 0.5),
])
def test_sharded_matches_loop_reference_parallel(n_shards, delete_frac,
                                                 readd_frac):
    """The same equivalence suite with per-shard applies running on the
    parallel apply plane (thread pool): stitched views, vertex tables and
    row counts must stay byte-identical — shard state is disjoint per
    worker, so any divergence here means the threading model leaked."""
    _run_equivalence(n_shards, delete_frac, readd_frac,
                     parallel_apply=n_shards)


@pytest.mark.threaded
def test_parallel_seal_capacity_error_leaves_epoch_pending():
    """A shard hitting capacity on the parallel plane must fail the seal
    exactly like the serial plane: error propagated to the caller, the
    failing shard's epoch pending and re-sealable, the frontier held."""
    sg = ShardedDynamicGraph(2, 8, 2, parallel_apply=2)
    sg.apply(MutationBatch(Version(0, 0),
                           add_src=np.array([0, 0], np.int32),
                           add_dst=np.array([1, 3], np.int32)))
    with pytest.raises(MemoryError):
        sg.apply(MutationBatch(Version(1, 0),
                               add_src=np.array([0, 0], np.int32),
                               add_dst=np.array([5, 7], np.int32)))
    assert sg.shards[1].n_edges == 2          # overflow applied nothing
    assert sg.nodes[1].local_frontier == 0    # seal did not commit
    assert 1 in sg.nodes[1].pending_payloads  # mutations retained
    assert sg.coordinator.global_frontier == 0
    with pytest.raises(MemoryError):
        sg.seal_epoch(1)                      # re-seal reproduces the error
    sg.shutdown()


def test_sharded_typed_vertices_match_reference():
    """Typed vertex adds route to their home shard; endpoint auto-creation
    can land anywhere — the merged v_type must still match the single
    store's first-creation-wins semantics."""
    _, batches = synthesize_stream(60, 6, 40, seed=3, n_types=3)
    sg = ShardedDynamicGraph(4, 60, 4096)
    ref = LoopDynamicGraph(60, 4096)
    for b in batches:
        sg.apply(b)
        ref.apply(b)
    np.testing.assert_array_equal(sg.v_created, ref.v_created)
    np.testing.assert_array_equal(sg.v_type, ref.v_type)
    counts = [sg.num_vertices(Version(e, 0)) for e in range(6)]
    assert counts == sorted(counts)


def test_join_view_gated_by_global_frontier():
    """A snapshot is only queryable once EVERY shard sealed its epoch —
    the coordinator's global-frontier rule."""
    batches = synthesize_churn_stream(16, 2, 20, seed=0)
    sg = ShardedDynamicGraph(2, 16, 1024)
    sg.ingest(batches[0])
    with pytest.raises(ValueError, match="not globally sealed"):
        sg.join_view(Version(0, 0))
    with pytest.raises(ValueError, match="not globally sealed"):
        sg.shard_views(Version(0, 0))
    sg.seal_epoch(0)
    assert sg.join_view(Version(0, 0)).m == len(batches[0].add_src)


def test_straggler_shard_holds_frontier_and_catches_up():
    """No-wait dispatch keeps healthy shards ingesting while a straggler
    parks its slice; the global frontier (and join_view) hold back until
    the straggler seals, then the stitched view is byte-identical."""
    batches = synthesize_churn_stream(32, 3, 40, seed=7, delete_frac=0.3)
    sg = ShardedDynamicGraph(2, 32, 4096)
    ref = LoopDynamicGraph(32, 4096)
    for b in batches:
        ref.apply(b)
    sg.ingest(batches[0])
    sg.seal_shard(1, 0)                   # healthy shard seals epoch 0
    assert sg.coordinator.global_frontier == -1
    sg.ingest(batches[1])                 # shard 0's slice parks (no-wait)
    assert sg.ingest_node.blocked_batches
    sg.seal_shard(1, 1)
    assert sg.coordinator.global_frontier == -1
    with pytest.raises(ValueError, match="not globally sealed"):
        sg.join_view(Version(0, 0))
    sg.seal_shard(0, 1)                   # straggler catches up; parked
    assert sg.coordinator.global_frontier == 1   # slices applied in order
    sg.ingest(batches[2])
    sg.seal_epoch(2)
    for e in range(3):
        _assert_stitched_equal(sg, ref, Version(e, 0))
    assert not sg.ingest_node.blocked_batches


def test_encode_decode_roundtrip_preserves_order():
    b = MutationBatch(Version(3, 1),
                      add_src=np.array([5, 1, 5], np.int32),
                      add_dst=np.array([2, 2, 2], np.int32),
                      del_src=np.array([5], np.int32),
                      del_dst=np.array([2], np.int32),
                      add_vertices=np.array([7, 3], np.int32),
                      vertex_types=np.array([1, 2], np.int32))
    keys, epochs, payload = encode_mutations(b)
    assert keys.tolist() == [7, 3, 2, 2, 2, 2]   # vids, add dsts, del dsts
    assert (epochs == 3).all()
    [decoded] = decode_payloads([payload])
    assert decoded.version == b.version
    np.testing.assert_array_equal(decoded.add_src, b.add_src)
    np.testing.assert_array_equal(decoded.add_dst, b.add_dst)
    np.testing.assert_array_equal(decoded.del_src, b.del_src)
    np.testing.assert_array_equal(decoded.del_dst, b.del_dst)
    np.testing.assert_array_equal(decoded.add_vertices, b.add_vertices)
    np.testing.assert_array_equal(decoded.vertex_types, b.vertex_types)
    # two versions in one seal decode into two ordered batches
    b2 = MutationBatch(Version(3, 2), add_src=np.array([0], np.int32),
                       add_dst=np.array([1], np.int32))
    _, _, payload2 = encode_mutations(b2)
    d1, d2 = decode_payloads([payload, payload2])
    assert (d1.version, d2.version) == (b.version, b2.version)


def test_partition_graph_sharded_fast_path():
    """The fast path consumes pre-sharded views without re-bucketing:
    partition p's rows are exactly shard p's rows, degrees sum to the
    stitched view's, and only allgather mode accepts the placement."""
    import jax
    import jax.numpy as jnp

    _, batches = synthesize_stream(48, 4, 60, seed=5)
    sg = ShardedDynamicGraph(4, 48, 4096)
    for b in batches:
        sg.apply(b)
    v = Version(3, 0)
    views = sg.shard_views(v)
    pg = partition_graph_sharded(views, hub_k=4)
    assert pg.placement == "dst_hash"
    assert pg.n_parts == 4
    full = sg.join_view(v)
    assert int(np.asarray(pg.mask).sum()) == full.m
    for p, view in enumerate(views):
        m = view.m
        np.testing.assert_array_equal(np.asarray(pg.src[p, :m]), view.np_src)
        np.testing.assert_array_equal(np.asarray(pg.dst[p, :m]), view.np_dst)
        assert not np.asarray(pg.mask[p, m:]).any()
    np.testing.assert_array_equal(np.asarray(pg.out_degree)[:48],
                                  np.asarray(full.np_out_deg))
    mesh = jax.make_mesh((1,), ("data",))
    sg1 = ShardedDynamicGraph(1, 48, 4096)
    for b in batches:
        sg1.apply(b)
    pg1 = partition_graph_sharded(sg1.shard_views(v), hub_k=4)
    vals = jnp.arange(pg1.n, dtype=jnp.float32)
    got = distributed_join_group_by(pg1, vals, mesh, mode="allgather")
    expect = jax.ops.segment_sum(vals[full.src], full.dst,
                                 num_segments=pg1.n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-6)
    for mode in ("scatter", "hub"):
        with pytest.raises(ValueError, match="src-placed"):
            distributed_join_group_by(pg1, vals, mesh, mode=mode)
    # an undersized pad_to must fail loudly, not silently drop edges
    with pytest.raises(ValueError, match="drop edges"):
        partition_graph_sharded(views, pad_to=1)


def test_sharded_gc_views_prunes_caches():
    batches = synthesize_churn_stream(32, 10, 30, seed=9, delete_frac=0.2)
    sg = ShardedDynamicGraph(2, 32, 4096)
    ref = LoopDynamicGraph(32, 4096)
    for b in batches:
        sg.apply(b)
        ref.apply(b)
        sg.join_view(b.version)
    assert len(sg._views) == 10
    dropped = sg.gc_views(keep_latest=4)
    assert dropped > 0
    # ladder retention: one view per doubling-distance bucket
    kept_epochs = sorted(Version.unpack(k).epoch for k in sg._views)
    assert kept_epochs == [5, 7, 8, 9]
    # dropped snapshots remain addressable and byte-identical (rebuilt
    # from a nearby ladder base or from scratch)
    for e in range(10):
        _assert_stitched_equal(sg, ref, Version(e, 0))


def test_sharded_capacity_overflow_leaves_epoch_pending():
    """A shard hitting edge capacity fails the seal as a no-op: the shard
    store is untouched, the epoch's mutations stay pending (not silently
    destroyed), the local frontier does not advance, and other shards are
    unaffected."""
    sg = ShardedDynamicGraph(2, 8, 2)
    sg.apply(MutationBatch(Version(0, 0),
                           add_src=np.array([0, 0], np.int32),
                           add_dst=np.array([1, 3], np.int32)))
    with pytest.raises(MemoryError):
        # two more edges to shard 1 (dst odd) exceed its capacity of 2
        sg.apply(MutationBatch(Version(1, 0),
                               add_src=np.array([0, 0], np.int32),
                               add_dst=np.array([5, 7], np.int32)))
    assert sg.shards[1].n_edges == 2          # overflow applied nothing
    assert sg.nodes[1].local_frontier == 0    # seal did not commit
    assert 1 in sg.nodes[1].pending_payloads  # mutations retained
    # re-sealing reproduces the error (no silent empty-epoch seal)
    with pytest.raises(MemoryError):
        sg.seal_shard(1, 1)
    assert sg.nodes[1].local_frontier == 0


def test_ingest_into_sealed_epoch_is_rejected():
    """A slice dispatched to an already-sealed local snapshot could never
    be applied — ingest refuses it loudly instead of losing it."""
    sg = ShardedDynamicGraph(2, 8, 64)
    sg.apply(MutationBatch(Version(0, 0),
                           add_src=np.array([0], np.int32),
                           add_dst=np.array([1], np.int32)))
    with pytest.raises(ValueError, match="already sealed"):
        sg.ingest(MutationBatch(Version(0, 1),
                                add_src=np.array([2], np.int32),
                                add_dst=np.array([3], np.int32)))
    with pytest.raises(ValueError, match="increasing versions"):
        sg.ingest(MutationBatch(Version(0, 0),
                                add_src=np.array([2], np.int32),
                                add_dst=np.array([3], np.int32)))


def test_mismatched_vertex_types_pad_or_raise():
    """Fewer types than vertex adds means 'untyped' (padded with 0) — the
    old behavior silently DROPPED the excess adds on whichever path
    truncated first; surplus types are an error."""
    b = MutationBatch(Version(0, 0),
                      add_vertices=np.array([4, 5, 6], np.int32),
                      vertex_types=np.array([2], np.int32))
    assert b.vertex_types.tolist() == [2, 0, 0]
    with pytest.raises(ValueError, match="meaningless"):
        MutationBatch(Version(0, 0),
                      add_vertices=np.array([4], np.int32),
                      vertex_types=np.array([1, 2], np.int32))
    # a batch mutated after construction (bypassing __post_init__) must
    # fail loudly in the encoder, not silently drop vertex adds
    b2 = MutationBatch(Version(0, 0),
                       add_vertices=np.array([1, 2], np.int32),
                       vertex_types=np.array([3, 3], np.int32))
    b2.vertex_types = np.array([3], np.int32)
    with pytest.raises(ValueError, match="disagree in length"):
        encode_mutations(b2)
    # a malformed batch rejected by ingest() leaves NO version bookkeeping:
    # the corrected batch retries at the same version
    sg = ShardedDynamicGraph(2, 8, 64)
    with pytest.raises(ValueError, match="disagree in length"):
        sg.ingest(b2)
    assert sg._ingested_packed == []
    b2.vertex_types = np.array([3, 3], np.int32)
    sg.ingest(b2)
    sg.seal_epoch(0)
    assert sg.latest_sealed() == b2.version


def test_padded_vertex_types_sharded_matches_reference():
    """A padded batch must produce identical vertex tables on the sharded
    and single-store paths (the divergence the truncation bug allowed)."""
    batches = [
        MutationBatch(Version(0, 0),
                      add_vertices=np.array([0, 1, 2, 3], np.int32),
                      vertex_types=np.array([2, 1], np.int32)),
        MutationBatch(Version(1, 0),
                      add_src=np.array([0, 2], np.int32),
                      add_dst=np.array([3, 5], np.int32)),
    ]
    sg = ShardedDynamicGraph(2, 8, 64)
    ref = LoopDynamicGraph(8, 64)
    for b in batches:
        sg.apply(b)
        ref.apply(b)
    np.testing.assert_array_equal(sg.v_created, ref.v_created)
    np.testing.assert_array_equal(sg.v_type, ref.v_type)
    assert sg.v_type[:4].tolist() == [2, 1, 0, 0]


def test_passthrough_overflow_rejected_before_bookkeeping():
    """Regression: the single-shard passthrough must apply the stamp
    overflow check BEFORE version bookkeeping, like the other ingest
    paths — otherwise the bad version is recorded, the seal wedges on
    pack32 overflow, and no corrected batch can ever retry."""
    sg = ShardedDynamicGraph(1, 8, 64)
    with pytest.raises(ValueError, match="int32 data-plane packing"):
        sg.ingest(MutationBatch(Version(1 << 12, 0),
                                add_src=np.array([0], np.int32),
                                add_dst=np.array([1], np.int32)))
    assert sg._ingested_packed == []          # nothing recorded
    sg.ingest(MutationBatch(Version(0, 0),
                            add_src=np.array([0], np.int32),
                            add_dst=np.array([1], np.int32)))
    sg.seal_epoch(0)
    assert sg.latest_sealed() == Version(0, 0)


def test_decode_payloads_interleaved_replay_is_order_robust():
    """A replay can deliver one version's rows split around another's (the
    straggler-replay interleave): grouping must key on the packed version,
    not trust endpoint rows. The old fast path saw rows[0] == rows[-1] and
    collapsed ALL rows into one batch."""
    b1 = MutationBatch(Version(2, 0),
                       add_src=np.array([0, 1], np.int32),
                       add_dst=np.array([1, 2], np.int32))
    b2 = MutationBatch(Version(2, 1),
                       del_src=np.array([0], np.int32),
                       del_dst=np.array([1], np.int32))
    _, _, p1 = encode_mutations(b1)
    _, _, p2 = encode_mutations(b2)
    out = decode_payloads([p1[:1], p2, p1[1:]])
    assert [d.version for d in out] == [b1.version, b2.version]
    np.testing.assert_array_equal(out[0].add_src, b1.add_src)
    np.testing.assert_array_equal(out[0].add_dst, b1.add_dst)
    assert len(out[0].del_src) == 0
    np.testing.assert_array_equal(out[1].del_src, b2.del_src)


def test_straggler_replays_parked_epochs_out_of_order():
    """Straggler-replay regression: two parked slices delivered in REVERSED
    order must still apply in version order and stitch byte-identically."""
    b1 = MutationBatch(Version(1, 0),
                       add_src=np.array([0, 2], np.int32),
                       add_dst=np.array([1, 3], np.int32))
    b2 = MutationBatch(Version(1, 1),
                       add_src=np.array([4], np.int32),
                       add_dst=np.array([1], np.int32),
                       del_src=np.array([0], np.int32),
                       del_dst=np.array([1], np.int32))
    sg = ShardedDynamicGraph(1, 8, 64)     # one shard: everything parks on it
    ref = LoopDynamicGraph(8, 64)
    sg.apply(MutationBatch(Version(0, 0),
                           add_src=np.array([6], np.int32),
                           add_dst=np.array([7], np.int32)))
    ref.apply(MutationBatch(Version(0, 0),
                            add_src=np.array([6], np.int32),
                            add_dst=np.array([7], np.int32)))
    # both epoch-1 slices sit pending on the node; scramble their arrival
    # order before the seal replays them (what an out-of-order straggler
    # replay delivers)
    node = sg.nodes[0]
    sg.ingest(b1)
    sg.ingest(b2)
    pending = node.pending_payloads[1]
    assert len(pending) == 2
    node.pending_payloads[1] = pending[::-1]      # adversarial arrival order
    sg.seal_epoch(1)
    ref.apply(b1)
    ref.apply(b2)
    for v in (Version(1, 0), Version(1, 1)):
        _assert_stitched_equal(sg, ref, v)


def test_latest_sealed_and_frontier_subscription():
    """latest_sealed() tracks the newest globally-sealed ingested version;
    subscribers fire exactly when the global frontier moves."""
    sg = ShardedDynamicGraph(2, 16, 64)
    fired = []
    sg.on_frontier_advance(fired.append)
    assert sg.latest_sealed() is None
    sg.ingest(MutationBatch(Version(0, 0),
                            add_src=np.array([0], np.int32),
                            add_dst=np.array([1], np.int32)))
    assert sg.latest_sealed() is None             # ingested, not sealed
    sg.seal_epoch(0)
    assert sg.latest_sealed() == Version(0, 0)
    assert fired == [0]
    # straggler: shard 0 lags epoch 1 — the newest SEALED snapshot stays 0
    sg.ingest(MutationBatch(Version(1, 0),
                            add_src=np.array([2], np.int32),
                            add_dst=np.array([3], np.int32)))
    sg.seal_shard(1, 1)
    assert sg.latest_sealed() == Version(0, 0)
    assert fired == [0]
    sg.seal_shard(0, 1)
    assert sg.latest_sealed() == Version(1, 0)
    assert fired == [0, 1]
    # an empty sealed epoch advances the frontier but not the version
    sg.seal_epoch(2)
    assert sg.latest_sealed() == Version(1, 0)
    assert fired == [0, 1, 2]


def test_multiple_batches_per_epoch_before_seal():
    """Several version-numbered batches within one epoch, sealed once —
    must match the single store applying them in sequence."""
    sg = ShardedDynamicGraph(2, 16, 64)
    ref = LoopDynamicGraph(16, 64)
    b1 = MutationBatch(Version(0, 0),
                       add_src=np.array([0, 1], np.int32),
                       add_dst=np.array([1, 2], np.int32))
    b2 = MutationBatch(Version(0, 1),
                       add_src=np.array([2], np.int32),
                       add_dst=np.array([3], np.int32),
                       del_src=np.array([0], np.int32),
                       del_dst=np.array([1], np.int32))
    sg.ingest(b1)
    sg.ingest(b2)
    sg.seal_epoch(0)
    for b in (b1, b2):
        ref.apply(b)
    for v in (Version(0, 0), Version(0, 1)):
        _assert_stitched_equal(sg, ref, v)


def test_latest_sealed_and_quiescence_with_multi_version_epochs():
    """Regression for the raw '>> 32' unpacks reprolint flagged (SH003):
    epoch extraction now goes through Version.unpack. Epochs holding
    several versions — where epoch != packed value — exercise exactly
    that extraction in latest_sealed() and is_quiescent()."""
    sg = ShardedDynamicGraph(2, 32, 256)
    sg.ingest(MutationBatch(Version(0, 1), add_src=np.array([0], np.int32),
                            add_dst=np.array([1], np.int32)))
    sg.ingest(MutationBatch(Version(0, 5), add_src=np.array([2], np.int32),
                            add_dst=np.array([3], np.int32)))
    assert not sg.is_quiescent()                  # epoch 0 still unsealed
    sg.seal_epoch(0)
    assert sg.latest_sealed() == Version(0, 5)    # newest sealed VERSION
    assert sg.is_quiescent()
    sg.ingest(MutationBatch(Version(1, 2), add_src=np.array([4], np.int32),
                            add_dst=np.array([5], np.int32)))
    assert sg.latest_sealed() == Version(0, 5)    # epoch 1 not sealed yet
    assert not sg.is_quiescent()
    sg.seal_epoch(1)
    assert sg.latest_sealed() == Version(1, 2)
    assert sg.is_quiescent()
