"""Batched serving example: prefill + KV-cache decode for several archs,
including a recurrent-state arch (no KV growth) — the long-context serving
path that motivates the long_500k cell.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import all_configs, reduced
from repro.launch.serve import Server
from repro.models import transformer as tf


def main():
    for arch in ("qwen2.5-14b", "mixtral-8x22b", "recurrentgemma-2b",
                 "xlstm-1.3b"):
        cfg = reduced(all_configs()[arch])
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        server = Server(cfg, params)
        rng = np.random.default_rng(1)
        prompts = rng.integers(0, cfg.vocab_size, (4, 16)).astype(np.int32)
        t0 = time.time()
        out = server.generate(prompts, 8)
        dt = time.time() - t0
        print(f"{arch:22s} 4 req x 8 tok: {dt:5.2f}s "
              f"({4*8/dt:6.1f} tok/s) sample={out[0][:4].tolist()}")


if __name__ == "__main__":
    main()
