"""MusicGen-medium [arXiv:2306.05284]: decoder-only over EnCodec tokens.
48L, d_model=1536, 24 heads MHA (kv=24), d_ff=6144 plain GELU, vocab 2048.
Backbone only: the EnCodec frontend is a stub; input_specs() supplies
precomputed frame embeddings (B, S, d_model). Full attention => long_500k skip."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    pattern=("attn",),
    ffn="gelu_mlp",
    norm="ln",
    rope=False,
    pos_emb="sinusoidal",
    embed_mode="frames",
    subquadratic=False,
))
