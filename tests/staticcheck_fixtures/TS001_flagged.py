"""TS001 fixture: Python control flow on traced values inside jit."""
import jax
import jax.numpy as jnp


@jax.jit
def relu_or_neg(x):
    if x > 0:                    # TS001: 'if' on a tracer
        return x
    return -x


@jax.jit
def drain(x):
    while x.sum() > 0:           # TS001: 'while' on a tracer
        x = x - 1
    return x


@jax.jit
def clamp(x):
    assert jnp.all(x >= 0)       # TS001: assert on a tracer
    return x
