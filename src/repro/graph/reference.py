"""Loop-based reference implementation of the dynamic-graph store.

This is the seed's per-element ingestion semantics, kept as an executable
oracle: ``apply`` walks mutations one by one (deletes scan all live rows,
O(E) each) and ``join_view`` builds the CSR with explicit per-vertex
buckets. The vectorized ``DynamicGraph`` must produce byte-identical CSRs
(offsets/src/dst/degrees) — see ``tests/test_dyngraph_vectorized.py`` —
and the ingestion benchmark measures its speedup against this path.

Rows are emitted in canonical (dst, src) order, matching
``DynamicGraph.join_view``.
"""
from __future__ import annotations

import numpy as np

from repro.core.versioned import (Version, pack32_checked, pack32_clamped)
from repro.graph.dyngraph import MAXV, MutationBatch


class LoopDynamicGraph:
    """Seed-semantics store: per-element loops, O(E) delete scans.

    Stamps use the same int32 data-plane packing as the vectorized store
    (``MAXV`` = int32 max = 'never'), so equivalence tests can compare the
    stamp/vertex tables of the two stores byte-for-byte.
    """

    def __init__(self, n_max: int, e_max: int):
        self.n_max = n_max
        self.e_max = e_max
        self.src = np.zeros(e_max, np.int32)
        self.dst = np.zeros(e_max, np.int32)
        self.created = np.full(e_max, MAXV, np.int32)
        self.deleted = np.full(e_max, MAXV, np.int32)
        self.n_edges = 0
        self.v_created = np.full(n_max, MAXV, np.int32)
        self.v_type = np.zeros(n_max, np.int32)
        self.n_vertices = 0
        self.versions: list[Version] = []

    def apply(self, batch: MutationBatch) -> None:
        if self.versions \
                and batch.version.pack() <= self.versions[-1].pack():
            raise ValueError("mutation batches must have increasing versions")
        v = pack32_checked(batch.version)
        for vid, vt in zip(batch.add_vertices, batch.vertex_types, strict=True):
            if self.v_created[vid] == MAXV:
                self.v_created[vid] = v
                self.v_type[vid] = vt
                self.n_vertices += 1
        k = len(batch.add_src)
        if k:
            if self.n_edges + k > self.e_max:
                raise MemoryError("edge capacity exceeded")
            sl = slice(self.n_edges, self.n_edges + k)
            self.src[sl] = batch.add_src
            self.dst[sl] = batch.add_dst
            self.created[sl] = v
            self.deleted[sl] = MAXV
            for vid in np.concatenate([batch.add_src, batch.add_dst]):
                if self.v_created[vid] == MAXV:
                    self.v_created[vid] = v
                    self.n_vertices += 1
            self.n_edges += k
        for s, d in zip(batch.del_src, batch.del_dst, strict=True):
            live = np.flatnonzero(
                (self.src[:self.n_edges] == s) & (self.dst[:self.n_edges] == d)
                & (self.deleted[:self.n_edges] == MAXV))
            if live.size:
                self.deleted[live[-1]] = v
        self.versions.append(batch.version)

    def snapshot_mask(self, version: Version) -> np.ndarray:
        v = pack32_clamped(version)
        e = self.n_edges
        return (self.created[:e] <= v) & (v < self.deleted[:e])

    def join_view_arrays(self, version: Version):
        """CSR arrays (offsets, src, dst, out_deg, in_deg) via explicit
        per-destination buckets — the equivalence oracle."""
        mask = self.snapshot_mask(version)
        src = self.src[:self.n_edges][mask]
        dst = self.dst[:self.n_edges][mask]
        n = self.n_max
        buckets: list[list[int]] = [[] for _ in range(n)]
        out_deg = np.zeros(n, np.int64)
        for s, d in zip(src.tolist(), dst.tolist(), strict=True):
            buckets[d].append(s)
            out_deg[s] += 1
        offsets = np.zeros(n + 1, np.int64)
        src_rows: list[int] = []
        dst_rows: list[int] = []
        in_deg = np.zeros(n, np.int64)
        for d, bucket in enumerate(buckets):
            bucket.sort()
            src_rows.extend(bucket)
            dst_rows.extend([d] * len(bucket))
            in_deg[d] = len(bucket)
            offsets[d + 1] = offsets[d] + len(bucket)
        return (offsets, np.asarray(src_rows, np.int32),
                np.asarray(dst_rows, np.int32), out_deg, in_deg)
