"""Serving driver: batched prefill + decode with KV caches.

The online half of the paper's online/offline integration: the server reads
model weights from the newest checkpoint *snapshot* (never blocking the
offline trainer that produces them) and answers batched generation requests.

Usage (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --requests 4 --prompt-len 16 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_configs, reduced
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import transformer as tf
from repro.train.checkpoint import CheckpointManager, CheckpointStructureError


class Server:
    def __init__(self, cfg, params):
        self.cfg = cfg
        self.params = params
        self.prefill = jax.jit(make_prefill_step(cfg))
        self.decode = jax.jit(make_decode_step(cfg))

    def generate(self, prompts, max_new: int, *, greedy=True, seed=0):
        """prompts: (B, P) int32 (tokens mode). Returns (B, max_new)."""
        cfg = self.cfg
        B, P = prompts.shape
        capacity = P + max_new
        logits, cache = tf.prefill(self.params, cfg, jnp.asarray(prompts),
                                   capacity=capacity)
        out = np.zeros((B, max_new), np.int32)
        key = jax.random.PRNGKey(seed)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for t in range(max_new):
            out[:, t] = np.asarray(tok)
            logits, cache = self.decode(self.params, cache, tok[:, None],
                                        P + t)
            if greedy:
                tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits[:, 0]).astype(jnp.int32)
        return out

    @classmethod
    def from_checkpoint(cls, cfg, ckpt_dir, version=None):
        """Read the newest snapshot (paper rule) — online side never blocks
        on the trainer."""
        mgr = CheckpointManager(ckpt_dir)
        like = {"params": tf.param_shapes(cfg)}
        params_like = jax.tree.map(
            lambda s: np.zeros(s.shape, s.dtype), like["params"])
        # checkpoints store the full train state; restore params subtree.
        # Only a STRUCTURE mismatch (params-only checkpoint lacking the
        # optimizer leaves) falls back to the narrower shape — a corrupt
        # checkpoint, bad dtype, or IO error must surface as itself, not
        # masquerade as a shape probe.
        state_like = {"params": params_like}
        try:
            state = mgr.restore({"params": params_like,
                                 **_opt_like(params_like)}, version)
            return cls(cfg, state["params"])
        except CheckpointStructureError:
            state = mgr.restore(state_like, version)
            return cls(cfg, state["params"])


def _opt_like(params_like):
    import numpy as _np
    zeros = jax.tree.map(lambda a: _np.zeros_like(a), params_like)
    return {"opt": {"m": zeros, "v": jax.tree.map(_np.zeros_like, params_like),
                    "count": _np.zeros((), _np.int32)},
            "step": _np.zeros((), _np.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = reduced(all_configs()[args.arch])
    if args.ckpt_dir:
        server = Server.from_checkpoint(cfg, args.ckpt_dir)
    else:
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        server = Server(cfg, params)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = server.generate(prompts, args.gen)
    dt = time.time() - t0
    print(f"served {args.requests} requests x {args.gen} tokens "
          f"in {dt:.2f}s ({args.requests*args.gen/dt:.1f} tok/s)")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
