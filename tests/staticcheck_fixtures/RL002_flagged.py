"""RL002 fixture: the two locks are acquired in both nesting orders."""
import threading


class TwoLocks:
    def __init__(self):
        self._lock = threading.Lock()
        self._rank_lock = threading.Lock()
        self.a = 0
        self.b = 0

    def forward(self):
        with self._lock:
            self.a += 1
            with self._rank_lock:        # RL002: _rank_lock inside _lock...
                self.b += 1

    def backward(self):
        with self._rank_lock:
            self.b += 1
            with self._lock:             # RL002: ...and _lock inside _rank_lock
                self.a += 1
