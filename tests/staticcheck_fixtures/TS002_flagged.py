"""TS002 fixture: concretizing traced values inside jit."""
import jax
import numpy as np


@jax.jit
def to_scalar(x):
    return float(x.sum())        # TS002: float() on a tracer


@jax.jit
def to_host(x):
    y = x * 2
    return np.asarray(y)         # TS002: np pulls the tracer to host


@jax.jit
def item_of(x):
    return x.max().item()        # TS002: .item() on a tracer
