"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lru_scan import lru_scan
from repro.kernels.segment_sum import segment_sum


# ---------------------------------------------------------------- segment_sum
@pytest.mark.parametrize("m,F,n", [(16, 8, 4), (100, 16, 10), (512, 128, 64),
                                   (33, 7, 5), (1, 4, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_sum_sweep(m, F, n, dtype):
    key = jax.random.PRNGKey(m * 1000 + F)
    vals = jax.random.normal(key, (m, F), dtype)
    segs = jnp.sort(jax.random.randint(key, (m,), 0, n))
    got = segment_sum(vals, segs, n, edge_block=64, feat_block=32,
                      interpret=True)
    want = ref.segment_sum(vals.astype(jnp.float32), segs, n)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


def test_segment_sum_empty_segments():
    vals = jnp.ones((8, 4), jnp.float32)
    segs = jnp.array([0, 0, 0, 0, 5, 5, 5, 5])   # segments 1-4 empty
    got = segment_sum(vals, segs, 7, interpret=True)
    assert np.asarray(got)[1:5].sum() == 0
    assert np.asarray(got)[0].sum() == 16
    assert np.asarray(got)[6].sum() == 0


# ------------------------------------------------------------ flash_attention
@pytest.mark.parametrize("B,Hq,Hkv,S,hd", [
    (1, 2, 2, 128, 32), (2, 4, 2, 256, 64), (1, 8, 1, 128, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal_sweep(B, Hq, Hkv, S, hd, dtype):
    keys = jax.random.split(jax.random.PRNGKey(S + Hq), 3)
    q = jax.random.normal(keys[0], (B, Hq, S, hd), dtype)
    k = jax.random.normal(keys[1], (B, Hkv, S, hd), dtype)
    v = jax.random.normal(keys[2], (B, Hkv, S, hd), dtype)
    got = flash_attention(q, k, v, causal=True, q_block=64, kv_block=64,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_windowed(window):
    B, H, S, hd = 1, 2, 256, 32
    keys = jax.random.split(jax.random.PRNGKey(window), 3)
    q = jax.random.normal(keys[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(keys[1], (B, H, S, hd), jnp.float32)
    v = jax.random.normal(keys[2], (B, H, S, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          q_block=32, kv_block=32, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_flash_matches_model_attention_path():
    """Kernel agrees with the portable chunked path used by the models."""
    from repro.nn.attention import _causal_blocked, _gqa_shape
    from repro.configs import all_configs, reduced
    cfg = reduced(all_configs()["qwen2.5-14b"], kv_chunk=32)
    B, Hq, Hkv, S, hd = 1, 4, 2, 128, 16
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(keys[0], (B, Hq, S, hd), jnp.float32)
    k = jax.random.normal(keys[1], (B, Hkv, S, hd), jnp.float32)
    v = jax.random.normal(keys[2], (B, Hkv, S, hd), jnp.float32)
    kern = flash_attention(q, k, v, causal=True, q_block=32, kv_block=32,
                           interpret=True)
    port = _causal_blocked(_gqa_shape(q, Hkv), k, v, cfg)
    port = port.reshape(B, Hq, S, hd)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(port),
                               atol=2e-4, rtol=2e-4)


# ------------------------------------------------------------------ lru_scan
@pytest.mark.parametrize("B,S,C", [(1, 64, 32), (2, 256, 64), (1, 100, 16),
                                   (3, 8, 8)])
def test_lru_scan_sweep(B, S, C):
    keys = jax.random.split(jax.random.PRNGKey(B * S + C), 2)
    a = jax.random.uniform(keys[0], (B, S, C), jnp.float32, 0.5, 0.999)
    b = jax.random.normal(keys[1], (B, S, C), jnp.float32)
    got = lru_scan(a, b, channel_block=16, time_chunk=32, interpret=True)
    want = ref.lru_scan(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


def test_lru_scan_time_tiling_carry():
    """Wrapper time-tiling (S > MAX_RESIDENT_S) chains carries correctly."""
    import repro.kernels.lru_scan as mod
    old = mod.MAX_RESIDENT_S
    mod.MAX_RESIDENT_S = 64
    try:
        B, S, C = 1, 200, 8
        keys = jax.random.split(jax.random.PRNGKey(0), 2)
        a = jax.random.uniform(keys[0], (B, S, C), jnp.float32, 0.5, 0.999)
        b = jax.random.normal(keys[1], (B, S, C), jnp.float32)
        got = lru_scan(a, b, channel_block=8, time_chunk=32, interpret=True)
        want = ref.lru_scan(a, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-4)
    finally:
        mod.MAX_RESIDENT_S = old


def test_lru_matches_rglru_block_path():
    """graph/nn integration: rglru_forward(use_kernel=True) == default path."""
    from repro.configs import all_configs, reduced
    from repro.nn.recurrent import init_rglru_block, rglru_forward
    cfg = reduced(all_configs()["recurrentgemma-2b"])
    p = init_rglru_block(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model),
                          jnp.float32)
    y0 = rglru_forward(p, x, cfg, use_kernel=False)
    y1 = rglru_forward(p, x, cfg, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------- snapshot_resolve
@pytest.mark.parametrize("N,K", [(16, 4), (100, 8), (1024, 3), (3, 1)])
def test_snapshot_resolve_matches_versioned_array(N, K):
    """The Pallas kernel implements the paper's snapshot rule exactly
    (oracle: repro.core.versioned.resolve_versions)."""
    from repro.core.versioned import resolve_versions
    from repro.kernels.snapshot_resolve import snapshot_resolve
    rng = np.random.default_rng(N + K)
    maxv = np.iinfo(np.int32).max
    vers = np.sort(rng.integers(0, 1000, (N, K)), axis=1).astype(np.int32)
    # pad a random suffix per row
    fill = rng.integers(0, K + 1, N)
    for i in range(N):
        vers[i, fill[i]:] = maxv
    vals = rng.standard_normal((N, K)).astype(np.float32)
    q = 500
    out, idx = snapshot_resolve(jnp.asarray(vers), jnp.asarray(vals), q,
                                item_block=32, interpret=True)
    oracle_idx = np.asarray(resolve_versions(vers, q))
    np.testing.assert_array_equal(np.asarray(idx), oracle_idx)
    for i in range(N):
        if oracle_idx[i] >= 0:
            assert np.asarray(out)[i] == vals[i, oracle_idx[i]]
        else:
            assert np.asarray(out)[i] == 0.0
