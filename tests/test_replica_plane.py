"""Replica-plane tests: cold-shard merge coherence, hot-vertex mirror
coherence (invariant I10), replica-first routing byte-identity, and the
src-placement partition path that unlocks scatter/hub modes for
pre-sharded views.

The merge tests mirror ``test_resharding.py``'s split/oracle discipline:
a mid-stream split followed by a merge must leave every sealed snapshot
byte-identical to the loop-based single-store oracle — including
pre-cutover snapshots re-queried afterwards, which must keep resolving
from the retired shard's tombstoned rows. The mirror-coherence test
asserts the I10 rule directly: at every published epoch, the serving
``ReplicaPlan``'s mirror rows are byte-for-byte rows of that epoch's
global view (a mirror can never serve pre-invalidation rows, because it
is rebuilt from the sealed snapshot it serves).

The hypothesis property tests (routing determinism given (plan, ledger);
routed-answer equivalence) self-skip when hypothesis is absent, like
``tests/test_resharding.py``; deterministic variants always run.
"""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:        # pragma: no cover - exercised in offline envs
    class _StrategyStub:
        """Stands in for hypothesis.strategies at decoration time only."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn

from repro.core.replica import MirrorPlanner, ShardPlanner
from repro.core.versioned import Version
from repro.graph import compute as gc
from repro.graph.dyngraph import synthesize_churn_stream
from repro.graph.query import (KHop, Reachability, RoutedSnapshot,
                               SnapshotQueryEngine, _SubView)
from repro.graph.reference import LoopDynamicGraph
from repro.graph.sharded import (RoutingPlan, ShardedDynamicGraph,
                                 replica_route)
from repro.launch.serve_graph import GraphQueryServer


def _assert_stitched_equal(sg, ref, version):
    view = sg.join_view(version)
    offsets, src, dst, out_deg, in_deg = ref.join_view_arrays(version)
    np.testing.assert_array_equal(np.asarray(view.offsets), offsets)
    np.testing.assert_array_equal(np.asarray(view.src), src)
    np.testing.assert_array_equal(np.asarray(view.dst), dst)
    np.testing.assert_array_equal(view.np_out_deg, out_deg)
    np.testing.assert_array_equal(view.np_in_deg, in_deg)


# ------------------------------------------------- merge/oracle equivalence
@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("delete_frac,readd_frac", [
    (0.0, 0.0),     # add-heavy
    (0.35, 0.4),    # churny: deletes + re-adds cross the migrated range
])
def test_split_then_merge_matches_oracle(n_shards, delete_frac, readd_frac):
    """A mid-stream split followed by a merge of the split pair: stitched
    CSRs stay byte-identical to the single-store oracle at EVERY version
    — pre-split, between the cutovers, and post-merge — and pre-cutover
    snapshots re-queried afterwards keep resolving from the retired
    shard's tombstoned rows."""
    n, epochs, adds = 48, 8, 60
    batches = synthesize_churn_stream(n, epochs, adds, seed=23,
                                      delete_frac=delete_frac,
                                      readd_frac=readd_frac)
    sg = ShardedDynamicGraph(n_shards, n, 8192)
    ref = LoopDynamicGraph(n, 8192)
    for e, b in enumerate(batches):
        sg.apply(b)
        ref.apply(b)
        if e == 2:
            split = sg.split_shard(0)
            assert split["kind"] == "split"
        elif e == 5:
            merge = sg.merge_shards(split["target"])
            assert merge["kind"] == "merge"
            assert merge["target"] == 0
    assert sg.retired == {split["target"]}
    assert sg.n_shards == n_shards + 1          # physical never shrinks
    assert sg.plan.n_shards == n_shards         # live leaves coarsened back
    assert sg.live_shards() == [i for i in range(n_shards + 1)
                                if i != split["target"]]
    for e in range(epochs):
        _assert_stitched_equal(sg, ref, Version(e, 0))
    # the retired shard is fully drained at post-merge snapshots
    assert sg.shard_views(Version(epochs - 1, 0))[split["target"]].m == 0
    # the merged plan routes nothing to the retired shard
    keys = np.random.default_rng(0).integers(0, 1 << 40, 2048)
    assert not (sg.plan.assign(keys) == split["target"]).any()
    # replaying the op-tagged history reproduces the assignment
    np.testing.assert_array_equal(
        RoutingPlan.replay(n_shards, sg.plan.history).assign(keys),
        sg.plan.assign(keys))


def test_split_after_merge_allocates_fresh_shard_id():
    """The plan's physical-allocation counter never reuses a retired id:
    a split after a merge must create the NEXT physical shard, aligned
    with the store's positional lists."""
    n = 32
    batches = synthesize_churn_stream(n, 6, 50, seed=7, delete_frac=0.1)
    sg = ShardedDynamicGraph(2, n, 8192)
    for e, b in enumerate(batches):
        sg.apply(b)
        if e == 1:
            s1 = sg.split_shard(1)       # creates shard 2
        elif e == 3:
            sg.merge_shards(s1["target"])
        elif e == 4:
            s2 = sg.split_shard(0)       # must create shard 3, not reuse 2
    assert (s1["target"], s2["target"]) == (2, 3)
    assert sg.n_shards == 4 and sg.retired == {2}
    assert sg.plan.n_total == 4 and sg.plan.n_shards == 3


def test_merge_requires_split_sibling():
    sg = ShardedDynamicGraph(2, 16, 256)
    sg.apply(synthesize_churn_stream(16, 1, 10, seed=1)[0])
    with pytest.raises(ValueError, match="sibling"):
        sg.merge_shards(0)               # depth-0 base leaf: never merges
    with pytest.raises(ValueError, match="retired|unknown|sibling"):
        sg.merge_shards(5)


def test_merge_rejects_retired_and_double_merge():
    n = 32
    batches = synthesize_churn_stream(n, 5, 40, seed=3)
    sg = ShardedDynamicGraph(2, n, 4096)
    for e, b in enumerate(batches):
        sg.apply(b)
        if e == 1:
            s = sg.split_shard(0)
        elif e == 3:
            sg.merge_shards(s["target"])
    with pytest.raises(ValueError, match="retired"):
        sg.merge_shards(s["target"])
    with pytest.raises(ValueError, match="retired"):
        sg.split_shard(s["target"])


# --------------------------------------------------------- planner policy
def test_planner_proposes_merge_for_cold_siblings():
    p = ShardPlanner(min_load=10.0, min_epochs=2, merge_threshold=0.4)
    pairs = [(0, 2)]
    # pair well below 0.4x mean -> merge
    d = p.propose_merge([5.0, 100.0, 5.0], epochs_observed=3, pairs=pairs)
    assert d is not None and (d.survivor, d.removed) == (0, 2)
    assert "siblings" in d.reason
    # hysteresis: combined load at/above the threshold band -> no merge
    assert p.propose_merge([20.0, 100.0, 20.0], epochs_observed=3,
                           pairs=pairs) is None
    # guards: cooldown, idle store, no legal pairs
    assert p.propose_merge([5.0, 100.0, 5.0], epochs_observed=1,
                           pairs=pairs) is None
    assert p.propose_merge([0.1, 0.5, 0.1], epochs_observed=3,
                           pairs=pairs) is None
    assert p.propose_merge([5.0, 100.0, 5.0], epochs_observed=3,
                           pairs=[]) is None


def test_planner_live_mask_excludes_retired():
    p = ShardPlanner(imbalance_threshold=1.5, min_load=10.0, min_epochs=0)
    # a retired shard's zero load would drag the mean to 50 and make
    # shard 1 look hot; with the mask the two live shards are balanced
    loads = [100.0, 110.0, 0.0]
    live = [True, True, False]
    assert p.propose(loads, epochs_observed=3, live=live) is None
    # and a retired pair never merges
    assert p.propose_merge(loads, epochs_observed=3,
                           pairs=[(0, 2)], live=live) is None


def test_mirror_planner_nomination():
    mp = MirrorPlanner(mirror_k=3, min_heat=2.0)
    heat = np.array([0.0, 5.0, 1.0, 9.0, 5.0, 3.0])
    hot = mp.nominate(heat)
    # top-3 by heat, ties broken toward the lower id, below min_heat cut
    np.testing.assert_array_equal(hot, [1, 3, 4])
    # pure function: identical input -> identical output
    np.testing.assert_array_equal(hot, mp.nominate(heat))
    assert mp.nominate(np.zeros(6)).size == 0
    assert MirrorPlanner(mirror_k=0).nominate(heat).size == 0


# ------------------------------------------- routed execution equivalence
def _routed_store(seed, n=40, n_shards=4, epochs=5):
    batches = synthesize_churn_stream(n, epochs, 60, seed=seed,
                                      delete_frac=0.25, readd_frac=0.3)
    sg = ShardedDynamicGraph(n_shards, n, 8192)
    for b in batches:
        sg.apply(b)
    return sg, sg.latest_sealed()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_replica_route_byte_identical(seed):
    """Frontier kernels on the routed edge subset answer byte-identically
    to the stitched global view, for every mirror-set size from nothing
    (pure locality routing) to everything (pure mirror serving)."""
    sg, v = _routed_store(seed)
    g = sg.join_view(v)
    views = sg.shard_views(v)
    rng = np.random.default_rng(seed)
    for k_hot in (0, 4, 40):
        hot = rng.choice(40, size=k_hot, replace=False) if k_hot else \
            np.zeros(0, np.int64)
        rp = sg.build_replica_plan(v, hot)
        assert rp.n_mirrored == k_hot
        # I10 at rest: mirror rows ARE the snapshot's rows for the
        # mirrored vertices, in canonical order
        sel = rp.mirrored[g.np_src]
        np.testing.assert_array_equal(rp.mirror_src, g.np_src[sel])
        np.testing.assert_array_equal(rp.mirror_dst, g.np_dst[sel])
        anchors = rng.integers(0, 40, 6).astype(np.int32)
        for k in (1, 2, 3):
            sub_src, sub_dst, fanout, hits, misses = replica_route(
                rp, views, anchors, k)
            sub = _SubView(g.n, sub_src, sub_dst)
            np.testing.assert_array_equal(
                np.asarray(gc.batched_k_hop(sub, anchors, k)),
                np.asarray(gc.batched_k_hop(g, anchors, k)))
            assert 0 <= fanout <= len(views)
        # reachability, bounded and unbounded
        srcs = anchors[:3]
        dsts = rng.integers(0, 40, 3).astype(np.int32)
        for hops in (2, None):
            sub_src, sub_dst, *_ = replica_route(rp, views, srcs, hops)
            sub = _SubView(g.n, sub_src, sub_dst)
            np.testing.assert_array_equal(
                np.asarray(gc.batched_reachability(sub, srcs, dsts, hops)),
                np.asarray(gc.batched_reachability(g, srcs, dsts, hops)))
    # all-mirrored anchors with k=1 resolve without touching any shard
    rp = sg.build_replica_plan(v, np.arange(40))
    _, _, fanout, hits, misses = replica_route(
        rp, views, np.array([1, 2, 3]), 1)
    assert fanout == 0 and misses == 0 and hits == 3


def test_engine_routed_execution_and_telemetry():
    """The engine consults the RoutedSnapshot only at its exact version,
    answers byte-identically, and accounts mirror hits / fan-out under
    its own lock."""
    sg, v = _routed_store(3)
    g = sg.join_view(v)
    rp = sg.build_replica_plan(v, np.arange(10))
    routed = RoutedSnapshot(rp, sg.shard_views(v))
    eng, oracle = SnapshotQueryEngine(), SnapshotQueryEngine()
    qs = [KHop(2, k=1), KHop(5, k=1), Reachability(1, 30, max_hops=3)]
    got = eng.execute(g, qs, routed=routed)
    want = oracle.execute(g, qs)
    for a, b in zip(got, want, strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rs = eng.replica_stats()
    assert rs["routed_windows"] == 2          # one k-hop + one reach group
    assert rs["mirror_hits"] + rs["mirror_misses"] > 0
    assert sum(rs["fanout_hist"].values()) == 2
    # a version-mismatched RoutedSnapshot is ignored, not misapplied
    older = sg.join_view(Version(0, 0))
    eng2 = SnapshotQueryEngine()
    got2 = eng2.execute(older, [KHop(2, k=1)], routed=routed)
    np.testing.assert_array_equal(
        np.asarray(got2[0]),
        np.asarray(oracle.execute(older, [KHop(2, k=1)])[0]))
    assert eng2.replica_stats()["routed_windows"] == 0


# ---------------------------------------------- I10 across plan churn
def test_mirror_coherence_across_split_and_merge():
    """The satellite's coherence bar: a mid-stream split, then a merge,
    with hot-vertex mirrors refreshing at every publish. At every sealed
    epoch the published plan's mirrors are byte-identical to that
    epoch's global view (never pre-invalidation rows), and every routed
    answer replays byte-identically on a no-replica oracle server."""
    n, epochs = 48, 8
    batches = synthesize_churn_stream(n, epochs, 60, seed=11,
                                      delete_frac=0.3, readd_frac=0.4)
    sg = ShardedDynamicGraph(2, n, 8192)
    srv = GraphQueryServer(sg, auto_reshard=False, mirror_k=16,
                           mirror_min_heat=0.5)
    sg_ref = ShardedDynamicGraph(2, n, 8192)
    srv_ref = GraphQueryServer(sg_ref, replicate_hot=False,
                               auto_reshard=False)
    rng = np.random.default_rng(5)
    hot_pool = rng.integers(0, 12, 6)
    split = None
    for e, b in enumerate(batches):
        srv.step(b)
        srv_ref.step(b)
        if e == 2:
            split = sg.split_shard(0)
        elif e == 5:
            sg.merge_shards(split["target"])
        with srv._serve_lock:
            v, _, routed = srv._serving
        if routed is not None:
            # I10: mirrors at version v == the v snapshot's own rows
            assert routed.plan.version.pack() == v.pack()
            gv = sg.join_view(v)
            sel = routed.plan.mirrored[gv.np_src]
            np.testing.assert_array_equal(routed.plan.mirror_src,
                                          gv.np_src[sel])
            np.testing.assert_array_equal(routed.plan.mirror_dst,
                                          gv.np_dst[sel])
        queries = [KHop(int(hot_pool[i % len(hot_pool)]), k=1 + i % 2)
                   for i in range(6)]
        queries.append(Reachability(int(hot_pool[0]),
                                    int(rng.integers(0, n)), max_hops=4))
        for q in queries:
            got = srv.query(q)
            want = srv_ref.query(q)
            assert got.version.pack() == want.version.pack()
            np.testing.assert_array_equal(np.asarray(got.value),
                                          np.asarray(want.value))
    s = srv.stats()
    assert s.routed_windows > 0
    assert s.split_events == 1 and s.merge_events == 1
    assert 0.0 <= s.mirror_hit_rate <= 1.0
    assert s.mirror_hits > 0                   # the hot pool got mirrored
    assert all(isinstance(k, str) for k in s.fanout_hist)
    assert s.mean_fanout < sg.n_shards         # routing beat full fan-out


# --------------------------------------------------- routing determinism
def _route_fingerprint(sg, v, heat, anchors, mirror_k=8):
    hot = MirrorPlanner(mirror_k=mirror_k, min_heat=0.5).nominate(heat)
    rp = sg.build_replica_plan(v, hot)
    out = replica_route(rp, sg.shard_views(v), anchors, 2)
    return (hot.tobytes(), out[0].tobytes(), out[1].tobytes(), *out[2:])


def test_routing_deterministic_fixed_ledgers():
    """Deterministic variant of the property test (always runs)."""
    sg, v = _routed_store(9)
    rng = np.random.default_rng(9)
    for _ in range(5):
        heat = rng.random(40) * 10
        anchors = rng.integers(0, 40, 5)
        assert _route_fingerprint(sg, v, heat, anchors) == \
            _route_fingerprint(sg, v, heat, anchors)


_PROP_STORE = {}


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.0, 100.0), min_size=40, max_size=40),
       st.lists(st.integers(0, 39), min_size=1, max_size=6))
def test_routing_deterministic_property(heat, anchors):
    """Property: replica-first routing is a pure function of (plan,
    ledger) — same heat vector and anchors, same mirrors, same routed
    edge set, same fan-out/hit telemetry."""
    if "sg" not in _PROP_STORE:
        _PROP_STORE["sg"], _PROP_STORE["v"] = _routed_store(13)
    sg, v = _PROP_STORE["sg"], _PROP_STORE["v"]
    heat = np.asarray(heat)
    anchors = np.asarray(anchors, np.int64)
    assert _route_fingerprint(sg, v, heat, anchors) == \
        _route_fingerprint(sg, v, heat, anchors)


# ------------------------------------------ src placement for shard views
def test_partition_sharded_src_placement_unlocks_scatter_and_hub():
    """The satellite's equivalence bar: re-bucketing pre-sharded views by
    source range produces a genuinely src-placed PartitionedGraph —
    scatter and hub modes run (previously rejected) and agree with the
    allgather answer on the dst-hash layout and with the segment-sum
    oracle."""
    import jax
    import jax.numpy as jnp
    from repro.graph.partition import (distributed_join_group_by,
                                       partition_graph_sharded)

    sg, v = _routed_store(21, n=48)
    views = sg.shard_views(v)
    full = sg.join_view(v)
    pg = partition_graph_sharded(views, hub_k=4, placement="src")
    assert pg.placement == "src"
    # every masked edge sits at its source's partition, none dropped
    ps, pm = np.asarray(pg.src), np.asarray(pg.mask)
    n_local = pg.n_local
    for p in range(pg.n_parts):
        assert (ps[p][pm[p]] // n_local == p).all()
    assert int(pm.sum()) == full.m
    # same edge multiset as the store's views
    pd = np.asarray(pg.dst)
    got_edges = np.sort((ps[pm].astype(np.int64) << 32) | pd[pm])
    want_edges = np.sort((full.np_src.astype(np.int64) << 32)
                         | full.np_dst)
    np.testing.assert_array_equal(got_edges, want_edges)

    # compute equivalence on the 1-device mesh: scatter/hub (src
    # placement) == allgather (dst_hash placement) == oracle
    sg1, v1 = _routed_store(21, n=48, n_shards=1)
    full1 = sg1.join_view(v1)
    mesh = jax.make_mesh((1,), ("data",))
    vals = None
    pg_src = partition_graph_sharded(sg1.shard_views(v1), hub_k=4,
                                     placement="src")
    pg_dst = partition_graph_sharded(sg1.shard_views(v1), hub_k=4)
    vals = jnp.arange(pg_src.n, dtype=jnp.float32)
    base = distributed_join_group_by(pg_dst, vals, mesh, mode="allgather")
    oracle = jax.ops.segment_sum(vals[full1.src], full1.dst,
                                 num_segments=pg_src.n)
    np.testing.assert_allclose(np.asarray(base), np.asarray(oracle),
                               rtol=1e-6)
    for mode in ("scatter", "hub"):
        got = distributed_join_group_by(pg_src, vals, mesh, mode=mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   rtol=1e-6)
    # the dst-hash fast path still rejects what it cannot serve
    with pytest.raises(ValueError, match="src-placed"):
        distributed_join_group_by(pg_dst, vals, mesh, mode="scatter")
    with pytest.raises(ValueError, match="placement"):
        partition_graph_sharded(views, placement="bogus")
