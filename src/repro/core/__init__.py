"""The paper's primary contribution: versioned datasets + snapshots,
protocol dataflow, replica-coherence data management, distributed views,
Lamport-clock event delivery."""
from repro.core.clock import Event, EventLog, LamportClock, Stamp  # noqa: F401
from repro.core.protocol_dataflow import (  # noqa: F401
    CoalescingOutput, Dataflow, Egress, FIFOScheduler, Ingress, Message,
    PriorityScheduler, Protocol, Vertex)
from repro.core.replica import ReplicaManager, SharedTensorPolicy  # noqa: F401
from repro.core.snapshotter import (DataNode, IngestNode, Mutation,  # noqa: F401
                                    SnapshotCoordinator)
from repro.core.versioned import Version, VersionedArray, VersionedStore  # noqa: F401
from repro.core.views import View  # noqa: F401
