"""RL001 fixture: guarded attribute accessed without its lock.

The class is NOT in the lockcheck registry — the guarded-by relation is
inferred: ``pending`` and ``count`` are written under ``with self._lock``
in ``add``, so every other access must hold the lock too.
"""
import threading


class WindowQueue:
    def __init__(self):
        self._lock = threading.Lock()
        self.pending = []
        self.count = 0

    def add(self, item):
        with self._lock:
            self.pending.append(item)
            self.count += 1

    def drain(self):
        items, self.pending = self.pending, []   # RL001: unguarded swap
        return items

    def size(self):
        return self.count                        # RL001: unguarded read
