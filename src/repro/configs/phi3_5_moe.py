"""Phi-3.5-MoE (42B total, 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct]:
32L, d_model=4096, 32 heads GQA kv=8, 16 experts top-2 with d_ff=6400 each,
vocab 32064, SwiGLU experts, RMSNorm, RoPE. Full attention => long_500k skip."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    pattern=("attn",),
    ffn="moe",
    norm="rms",
    rope=True,
    rope_theta=10_000.0,
    n_experts=16,
    top_k=2,
    d_ff_expert=6400,
    expert_sharding="expert",   # 16 experts % 16 == 0 -> expert parallel on model axis
    subquadratic=False,
))
