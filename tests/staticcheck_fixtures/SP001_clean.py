"""SP001 clean twin: the closure touches only its shard's slots."""
import time


class Sharded:
    def __init__(self, n_shards):
        self.shards = [object() for _ in range(n_shards)]
        self.shard_apply_seconds = [0.0] * n_shards

    def _on_seal(self, shard_id):
        def on_seal(epoch, payloads):
            t0 = time.perf_counter()
            shard = self.shards[shard_id]            # read: fine
            for p in payloads:
                shard.apply(p)                       # shard-local mutation
            self.shard_apply_seconds[shard_id] += (  # own slot: fine
                time.perf_counter() - t0)
        return on_seal
