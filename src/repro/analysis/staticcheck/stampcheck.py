"""SH0xx — int32 stamp hygiene.

The data plane stores versions as int32 ``PACK_BITS`` stamps
(``epoch << 20 | number``; ``core/versioned.py``), while the API plane
uses 64-bit ``Version.pack()`` keys (``epoch << 32 | number``). Mixing
the two is silent corruption: a 64-bit pack compared against an int32
stamp column is just *wrong* (different bit layout), and an int64 array
reaching a Pallas kernel breaks the kernels' int32 contract. The rules:

* SH001: a 64-bit packed version (a ``.pack()`` result, a local tainted
  by one, or an int literal >= 2**31) compared against or stored into a
  stamp column (``created`` / ``deleted`` / ``v_created``). The only
  sanctioned bridges are ``pack32_checked`` (stores — raises on
  overflow) and ``pack32_clamped`` (queries — order-preserving clamp).
* SH002: an int64 dtype escape into the stamp plane — ``astype``/
  ``np.int64`` applied to a stamp column, an int64-cast value stored
  into one, or an int64-cast argument handed to the stamp-consuming
  kernels (``liveness_mask`` / ``snapshot_resolve``).
* SH003: a raw ``>> 32`` unpack outside ``core/versioned.py`` — version
  bit layout is that module's private business; everyone else goes
  through ``Version.unpack`` / ``unpack32``. (Left shifts are not
  flagged: ``dst << 32 | src`` edge keys and node/epoch grouping keys
  are legitimate and structurally distinct.)

Taint is one level deep and intra-function: ``v = version.pack()``
marks ``v``; flow through containers or across calls is not chased —
the repo convention keeps pack/compare adjacent, and fixtures pin the
shapes that matter.
"""
from __future__ import annotations

import ast

from repro.analysis.staticcheck.core import (FileContext, Finding,
                                             register_checker, register_rule)

SH001 = register_rule(
    "SH001", "64-bit packed version meets an int32 stamp column")
SH002 = register_rule(
    "SH002", "int64 dtype escape into the stamp plane")
SH003 = register_rule(
    "SH003", "raw '>> 32' version unpack outside core/versioned.py")

SCOPE = ("graph", "core", "kernels", "launch")

STAMP_ATTRS = frozenset({"created", "deleted", "v_created"})
STAMP_KERNELS = frozenset({"liveness_mask", "snapshot_resolve"})
_INT64_NAMES = frozenset({"int64"})
_BIG = 1 << 31


def _is_stamp(node: ast.AST) -> bool:
    """``x.created`` or ``x.created[...]`` for any base ``x``."""
    if isinstance(node, ast.Subscript):
        return _is_stamp(node.value)
    return isinstance(node, ast.Attribute) and node.attr in STAMP_ATTRS


def _mentions_int64(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _INT64_NAMES:
            return True
        if isinstance(sub, ast.Name) and sub.id in _INT64_NAMES:
            return True
    return False


def _is_pack64(node: ast.AST, tainted: set[str]) -> bool:
    """A value that is (or came from) a 64-bit version pack."""
    if isinstance(node, ast.Call):
        fn = node.func
        return isinstance(fn, ast.Attribute) and fn.attr == "pack"
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Constant):
        return isinstance(node.value, int) and node.value >= _BIG
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.LShift):
        return (isinstance(node.right, ast.Constant)
                and isinstance(node.right.value, int)
                and node.right.value >= 31)
    return False


def _pack64_taint(fn: ast.FunctionDef) -> set[str]:
    tainted: set[str] = set()
    for st in ast.walk(fn):
        if isinstance(st, ast.Assign) and _is_pack64(st.value, tainted):
            for tgt in st.targets:
                if isinstance(tgt, ast.Name):
                    tainted.add(tgt.id)
    return tainted


@register_checker(scope=SCOPE)
def check_stamp_hygiene(ctx: FileContext):
    if ctx.rel.endswith("core/versioned.py"):
        return []    # the bit layout's owner module
    findings: list[Finding] = []

    # SH003 is position-independent: any raw >>32 in the file
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.BinOp) and isinstance(node.op, ast.RShift)
                and isinstance(node.right, ast.Constant)
                and node.right.value == 32):
            findings.append(ctx.finding(
                node, SH003,
                "raw '>> 32' unpack — use Version.unpack()/unpack32 "
                "(bit layout belongs to core/versioned.py)"))

    funcs = [n for n in ast.walk(ctx.tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in funcs:
        tainted = _pack64_taint(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.Compare):
                sides = [node.left, *node.comparators]
                if (any(_is_stamp(s) for s in sides)
                        and any(_is_pack64(s, tainted) for s in sides)):
                    findings.append(ctx.finding(
                        node, SH001,
                        "64-bit packed version compared against an int32 "
                        "stamp column — use pack32_clamped()"))
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    if not _is_stamp(tgt):
                        continue
                    if _is_pack64(node.value, tainted):
                        findings.append(ctx.finding(
                            node, SH001,
                            "64-bit packed version stored into an int32 "
                            "stamp column — use pack32_checked()"))
                    elif _mentions_int64(node.value):
                        findings.append(ctx.finding(
                            node, SH002,
                            "int64 value stored into an int32 stamp "
                            "column"))
            elif isinstance(node, ast.Call):
                fname = ""
                if isinstance(node.func, ast.Name):
                    fname = node.func.id
                elif isinstance(node.func, ast.Attribute):
                    fname = node.func.attr
                if fname == "astype" and isinstance(node.func, ast.Attribute):
                    if (_is_stamp(node.func.value)
                            and any(_mentions_int64(a) for a in node.args)):
                        findings.append(ctx.finding(
                            node, SH002,
                            "stamp column cast to int64 — stamps are "
                            "int32 by contract"))
                elif fname in STAMP_KERNELS:
                    for a in node.args:
                        if (_mentions_int64(a)
                                or _is_pack64(a, tainted)):
                            findings.append(ctx.finding(
                                a, SH002,
                                f"int64/64-bit-packed argument to "
                                f"'{fname}' — the kernel's stamp "
                                "contract is int32"))
    return findings
