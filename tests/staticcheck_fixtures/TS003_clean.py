"""TS003 clean twin: loop bounds from shapes/statics, or lax loops."""
import jax


@jax.jit
def accumulate(xs):
    total = 0.0
    for i in range(xs.shape[0]):     # shape-derived bound: fine
        total = total + xs[i].sum()
    return total


@jax.jit
def accumulate_scan(xs):
    def step(acc, row):
        return acc + row.sum(), None
    total, _ = jax.lax.scan(step, 0.0, xs)
    return total
