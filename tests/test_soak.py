"""Fault-injection soak: the serving tier under everything at once.

Many RPC clients with randomized deadlines hammer the two-lane front
while the ingest thread streams churn AND performs a mid-run shard split
followed by a merge of the split pair, with slow-query stalls injected
into expensive windows (the exact convoy shape the cheap lane exists to
dodge). The contract: typed errors are the only failure surface, every
client gets exactly its own responses back (id-complete, in order), and
every successful non-PageRank answer is byte-identical to a single-store
replay oracle at its served version — zero mismatches. (PageRank is
excluded from the audit, not the workload: its warm-start chain is
serving-history-dependent, which a stateless oracle cannot replay.)

The full-scale run is ``pytest -m soak`` (its own CI leg; the tier-1
legs exclude the marker); the quick-scale variant below runs unmarked in
tier-1 so every push exercises the same failure surface in seconds.
"""
import threading
import time

import numpy as np
import pytest

from repro.graph import compute as gc
from repro.graph.dyngraph import DynamicGraph, synthesize_churn_stream
from repro.graph.query import (ERR_BAD_PIN, ERR_DEADLINE, ERR_OVERLOADED,
                               DegreeTopK, KHop, PageRankQuery,
                               Reachability)
from repro.graph.sharded import ShardedDynamicGraph
from repro.launch import rpc
from repro.launch.serve_graph import GraphQueryServer

TYPED_ERRORS = (ERR_BAD_PIN, ERR_DEADLINE, ERR_OVERLOADED)


def _client_queries(ci: int, per_client: int, n: int):
    """Regenerable per-client workload: (query, deadline_s, pin_slot)
    triples — pin_slot j means 'pin the version the j-th answer of this
    client was served at' (resolved live, replayed in the audit)."""
    rng = np.random.default_rng(1000 + ci)
    out = []
    for j in range(per_client):
        roll = rng.random()
        if roll < 0.45:
            q = KHop(int(rng.integers(0, n)), k=2)
        elif roll < 0.7:
            q = Reachability(int(rng.integers(0, n)),
                             int(rng.integers(0, n)), max_hops=6)
        elif roll < 0.85:
            q = DegreeTopK(5)
        else:
            q = PageRankQuery(top_k=4)
        droll = rng.random()
        if droll < 0.3:
            deadline = None                       # no budget
        elif droll < 0.9:
            deadline = 30.0                       # generous
        else:
            deadline = float(rng.uniform(0.02, 0.1))   # may expire
        pin = 0 if (j % 6 == 5 and j > 0) else None
        out.append((q, deadline, pin))
    return out


def _run_soak(*, n, epochs, adds, n_clients, per_client,
              stall_s, ingest_delay_s):
    batches = synthesize_churn_stream(n, epochs, adds, seed=29,
                                      delete_frac=0.25, readd_frac=0.3)
    e_max = sum(len(b.add_src) for b in batches) + 16
    sg = ShardedDynamicGraph(2, n, e_max)
    server = GraphQueryServer(sg, auto_reshard=False, tol=1e-6,
                              max_iter=100)
    server.step(batches[0])

    # fault injection: every expensive window stalls before executing —
    # the convoy generator the two-lane scheduler must absorb
    real_execute = server.engine.execute

    def stalling_execute(view, queries, **kw):
        if any(isinstance(q, PageRankQuery) for q in queries):
            time.sleep(stall_s)
        return real_execute(view, queries, **kw)

    server.engine.execute = stalling_execute

    front = rpc.GraphRPCServer(server, port=0).start()
    host, port = front.address
    results: dict[int, list] = {}
    errors: list[BaseException] = []

    def client(ci: int):
        mine = []
        try:
            with rpc.GraphRPCClient(host, port) as c:
                pinned = None
                for j, (q, deadline, pin) in enumerate(
                        _client_queries(ci, per_client, n)):
                    r = c.query(q, deadline_s=deadline,
                                pin_version=(pinned if pin is not None
                                             else None))
                    assert r.request_id == j + 1, "response misrouted"
                    mine.append(r)
                    if r.ok and pinned is None:
                        pinned = r.version
        except BaseException as e:               # pragma: no cover
            errors.append(e)
        results[ci] = mine

    # ingest pump with the reshard events at its quiescent points: a
    # split a third of the way in, the sibling merged two thirds in
    split = {}

    def pump():
        for e, b in enumerate(batches[1:], start=1):
            server.step(b)
            with server._ingest_lock:
                if e == max(2, epochs // 3):
                    split.update(sg.split_shard(0))
                elif e == max(3, (2 * epochs) // 3) and split:
                    sg.merge_shards(split["target"])
            time.sleep(ingest_delay_s)

    ingest = threading.Thread(target=pump, name="soak-ingest")
    ingest.start()
    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ingest.join()
    stats = server.stats()
    front.stop()

    assert not errors
    assert split and sg.retired, "split+merge must both have happened"

    # per-client id completeness: nothing lost, nothing duplicated,
    # responses delivered to the submitting connection in order
    for ci in range(n_clients):
        ids = [r.request_id for r in results[ci]]
        assert ids == list(range(1, per_client + 1)), f"client {ci}"
    flat = [r for rs in results.values() for r in rs]
    bad = [r for r in flat if not r.ok]
    assert all(r.error.code in TYPED_ERRORS for r in bad), \
        {r.error.code for r in bad}
    ok = [r for r in flat if r.ok]
    assert len(ok) >= len(flat) * 0.5

    # replay oracle: single non-sharded store, same stream; every
    # successful non-PageRank answer byte-identical at its version
    g = DynamicGraph(n, e_max)
    for b in batches:
        g.apply(b)
    mismatches, audited = 0, 0
    for ci in range(n_clients):
        for (q, _dl, _pin), r in zip(_client_queries(ci, per_client, n),
                                     results[ci], strict=True):
            if not r.ok or isinstance(q, PageRankQuery):
                continue
            view = g.join_view(r.version)
            if isinstance(q, KHop):
                want = np.asarray(
                    gc.k_hop(view, np.array([q.source]), q.k))
                same = np.asarray(r.value).tobytes() == want.tobytes()
            elif isinstance(q, Reachability):
                same = r.value == gc.reachability(view, q.src, q.dst,
                                                  q.max_hops)
            else:
                ids, degs = r.value
                want_ids, want_degs = gc.degree_topk(view, q.k)
                same = (np.asarray(ids).tobytes()
                        == np.asarray(want_ids).tobytes()
                        and np.asarray(degs).tobytes()
                        == np.asarray(want_degs).tobytes())
            audited += 1
            mismatches += 0 if same else 1
    assert audited > 0
    assert mismatches == 0, f"{mismatches}/{audited} audited answers wrong"
    return stats


def test_soak_quick_scale():
    """Tier-1 variant: same failure surface, seconds not minutes."""
    stats = _run_soak(n=64, epochs=6, adds=80, n_clients=4, per_client=12,
                      stall_s=0.02, ingest_delay_s=0.01)
    assert stats.served > 0
    assert stats.split_events == 1 and stats.merge_events == 1


@pytest.mark.soak
def test_soak_full_scale():
    """The acceptance soak: 8 clients, a longer churn stream, heavier
    stalls and tighter deadline pressure."""
    stats = _run_soak(n=128, epochs=12, adds=200, n_clients=8,
                      per_client=40, stall_s=0.08, ingest_delay_s=0.02)
    assert stats.served > 0
    assert stats.split_events == 1 and stats.merge_events == 1
    assert stats.result_cache_hits > 0
    assert stats.prewarm_runs > 0
