"""RL003 clean twin: block first, lock only for the state touch."""
import threading
import time


class Applier:
    def __init__(self):
        self._lock = threading.Lock()
        self.done = 0

    def seal(self, futures):
        for f in futures:
            f.result()                   # barrier outside the lock
        with self._lock:
            self.done += 1

    def throttle(self):
        time.sleep(0.1)
        with self._lock:
            self.done += 1
