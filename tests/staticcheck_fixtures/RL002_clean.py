"""RL002 clean twin: one global acquisition order."""
import threading


class TwoLocks:
    def __init__(self):
        self._lock = threading.Lock()
        self._rank_lock = threading.Lock()
        self.a = 0
        self.b = 0

    def forward(self):
        with self._lock:
            self.a += 1
            with self._rank_lock:
                self.b += 1

    def also_forward(self):
        with self._lock:
            with self._rank_lock:
                self.a += 1
                self.b += 1
