"""Vectorized live-edge index tests.

``LiveEdgeIndex`` (the NumPy open-addressing hash table behind
``DynamicGraph.apply``'s delete resolution) against a plain-dict oracle:
batched push/pop semantics, LIFO order under duplicate (src, dst) rows,
hash-collision stress with a deliberately tiny table (forcing long probe
chains and growth rehashes), and add→delete→re-add interleavings through
the full store against the loop reference.
"""
import numpy as np
import pytest

from repro.core.versioned import Version
from repro.graph.dyngraph import (MAXV, DynamicGraph, LiveEdgeIndex,
                                  MutationBatch, synthesize_churn_stream)
from repro.graph.reference import LoopDynamicGraph

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False


def test_push_returns_previous_row_and_lookup_agrees():
    idx = LiveEdgeIndex(capacity=8)
    keys = np.array([10, 20, 30], np.int64)
    old = idx.push(keys, np.array([0, 1, 2]))
    assert old.tolist() == [-1, -1, -1]
    # update in place: previous rows come back, newest rows stored
    old = idx.push(keys, np.array([5, 6, 7]))
    assert old.tolist() == [0, 1, 2]
    assert idx.lookup(keys).tolist() == [5, 6, 7]
    assert idx.lookup(np.array([99], np.int64)).tolist() == [-1]


def test_store_row_minus_one_marks_emptied():
    idx = LiveEdgeIndex(capacity=8)
    idx.store(np.array([7], np.int64), np.array([3]))
    assert idx.lookup(np.array([7], np.int64)).tolist() == [3]
    slots = idx.slots_of(np.array([7], np.int64))
    idx.set_rows(slots, np.array([-1]))
    assert idx.lookup(np.array([7], np.int64)).tolist() == [-1]
    # pushing the key again revives it and reports 'absent'
    assert idx.push(np.array([7], np.int64),
                    np.array([9])).tolist() == [-1]
    assert idx.lookup(np.array([7], np.int64)).tolist() == [9]


def test_collision_stress_tiny_table_growth_and_probing():
    """Hundreds of keys through a 16-slot table: every insert round hits
    probe conflicts and the table must grow several times, dropping
    emptied keys on each rehash, with dict-identical results."""
    rng = np.random.default_rng(0)
    idx = LiveEdgeIndex(capacity=16)
    oracle: dict[int, int] = {}
    all_keys = rng.choice(10_000, size=400, replace=False).astype(np.int64)
    for step in range(20):
        ins = rng.choice(all_keys, size=40, replace=False)
        rows = rng.integers(0, 1 << 20, size=40)
        got_old = idx.push(ins, rows)
        for k, r, o in zip(ins.tolist(), rows.tolist(), got_old.tolist(), strict=True):
            assert oracle.get(k, -1) == o
            oracle[k] = int(r)
        # empty a random live subset through the slot API
        live = np.array([k for k, r in oracle.items() if r >= 0], np.int64)
        if live.size:
            kill = rng.choice(live, size=min(10, live.size), replace=False)
            slots = idx.slots_of(kill)
            assert (slots >= 0).all()
            idx.set_rows(slots, np.full(len(kill), -1))
            for k in kill.tolist():
                oracle[k] = -1
        probe = rng.choice(all_keys, size=100, replace=False)
        expect = [oracle.get(k, -1) for k in probe.tolist()]
        assert idx.lookup(probe).tolist() == expect
    assert idx.capacity > 16                      # growth actually happened


def test_rehash_drops_emptied_keys():
    idx = LiveEdgeIndex(capacity=16)
    keys = np.arange(8, dtype=np.int64)
    idx.push(keys, np.arange(8))
    idx.set_rows(idx.slots_of(keys), np.full(8, -1))   # all emptied
    used_before = idx._used
    # force a growth: occupancy must reset to the live key count (0) + new
    idx.push(np.arange(100, 140, dtype=np.int64), np.arange(40))
    assert idx._used <= 40 < used_before + 40
    assert idx.lookup(keys).tolist() == [-1] * 8


def test_duplicate_adds_chain_lifo_within_and_across_batches():
    """3 duplicate rows in one batch + 1 in the next: deletes must pop
    rows newest-first (row ids descending), matching the oracle."""
    g = DynamicGraph(4, 64)
    g.apply(MutationBatch(Version(0, 0),
                          add_src=np.array([1, 1, 1], np.int32),
                          add_dst=np.array([2, 2, 2], np.int32)))
    g.apply(MutationBatch(Version(1, 0),
                          add_src=np.array([1], np.int32),
                          add_dst=np.array([2], np.int32)))
    # pop order: row 3 (newest), then 2, then 1, then 0
    for e, expect_row in zip(range(2, 6), (3, 2, 1, 0), strict=True):
        g.apply(MutationBatch(Version(e, 0),
                              del_src=np.array([1], np.int32),
                              del_dst=np.array([2], np.int32)))
        assert g.deleted[expect_row] != MAXV, f"row {expect_row} not popped"
        assert (g.deleted[:expect_row] == MAXV).all()


def test_batch_with_more_deletes_than_live_duplicates():
    """Duplicate delete keys beyond the live stack depth are ignored (seed
    semantics), including when interleaved with other keys."""
    g = DynamicGraph(8, 64)
    ref = LoopDynamicGraph(8, 64)
    b0 = MutationBatch(Version(0, 0),
                       add_src=np.array([1, 1, 3], np.int32),
                       add_dst=np.array([2, 2, 4], np.int32))
    b1 = MutationBatch(Version(1, 0),
                       del_src=np.array([1, 3, 1, 1, 5], np.int32),
                       del_dst=np.array([2, 4, 2, 2, 6], np.int32))
    for b in (b0, b1):
        g.apply(b)
        ref.apply(b)
    np.testing.assert_array_equal(g.snapshot_mask(Version(1, 0)),
                                  ref.snapshot_mask(Version(1, 0)))
    assert g.join_view(Version(1, 0)).m == 0


@pytest.mark.parametrize("seed", [3, 5])
def test_add_delete_readd_interleavings_match_oracle(seed):
    """Dup-heavy randomized interleavings (tiny vertex space, heavy churn)
    through a deliberately tiny index so probing and growth are exercised
    mid-stream."""
    n = 8                                   # tiny space -> many duplicates
    batches = synthesize_churn_stream(n, 10, 25, seed=seed,
                                      delete_frac=0.5, readd_frac=0.5)
    g = DynamicGraph(n, 4096)
    g._index = LiveEdgeIndex(capacity=8)    # stress probing + rehashing
    ref = LoopDynamicGraph(n, 4096)
    for b in batches:
        g.apply(b)
        ref.apply(b)
        np.testing.assert_array_equal(g.snapshot_mask(b.version),
                                      ref.snapshot_mask(b.version))
    np.testing.assert_array_equal(g.created[:g.n_edges],
                                  ref.created[:ref.n_edges])
    np.testing.assert_array_equal(g.deleted[:g.n_edges],
                                  ref.deleted[:ref.n_edges])


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.tuples(st.booleans(),
                              st.integers(0, 5), st.integers(0, 5)),
                    min_size=1, max_size=60),
           st.integers(0, 3))
    def test_property_store_matches_oracle(ops, group):
        """Random add/delete streams over a 6x6 key space (maximum
        duplication) applied in groups-of-N batches: masks byte-identical
        to the loop oracle at every version."""
        per_batch = group + 1
        g = DynamicGraph(6, 4096)
        g._index = LiveEdgeIndex(capacity=8)
        ref = LoopDynamicGraph(6, 4096)
        for e in range(0, len(ops), per_batch):
            chunk = ops[e:e + per_batch]
            adds = [(s, d) for is_add, s, d in chunk if is_add]
            dels = [(s, d) for is_add, s, d in chunk if not is_add]
            b = MutationBatch(
                Version(e, 0),
                add_src=np.array([a[0] for a in adds], np.int32),
                add_dst=np.array([a[1] for a in adds], np.int32),
                del_src=np.array([d[0] for d in dels], np.int32),
                del_dst=np.array([d[1] for d in dels], np.int32))
            g.apply(b)
            ref.apply(b)
            np.testing.assert_array_equal(g.snapshot_mask(b.version),
                                          ref.snapshot_mask(b.version))
