"""SH001 fixture: 64-bit packed versions meeting int32 stamp columns."""
import numpy as np


class Store:
    def __init__(self, e_max):
        self.created = np.zeros(e_max, np.int32)
        self.deleted = np.zeros(e_max, np.int32)
        self.n_edges = 0

    def live_mask(self, version):
        v = version.pack()                       # 64-bit API key
        return self.created[: self.n_edges] <= v     # SH001: 64-bit compare

    def mark(self, rows, version):
        self.deleted[rows] = version.pack()          # SH001: 64-bit store

    def mark_sentinel(self, rows):
        self.deleted[rows] = 1 << 62                 # SH001: huge literal
