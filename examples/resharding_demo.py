"""ADAPTIVE RE-SHARDING WALKTHROUGH — a hot shard splits itself.

A zipf-skewed mutation stream (a few hot destination vertices take most
of the edges) is served by a ``GraphQueryServer`` whose
``ShardedDynamicGraph`` carries a ``ShardPlanner``. Static dst-hash
routing would leave one shard carrying well over its share forever; here
the access ledger (mutation routing counts + query touches) trips the
planner, the hot shard's key range is split at a seal boundary, and the
migrating half-range rides as ordinary mutation payloads — while every
answer stays byte-identical to a single-store replay, audited at the end.

    PYTHONPATH=src python examples/resharding_demo.py          # full demo
    PYTHONPATH=src python examples/resharding_demo.py --smoke  # CI-sized

See docs/ARCHITECTURE.md ("Dynamic re-sharding") for why the cutover at a
seal boundary preserves byte-identical views.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.replica import ShardPlanner
from repro.core.versioned import Version
from repro.graph import compute as gc
from repro.graph.dyngraph import DynamicGraph, synthesize_skewed_stream
from repro.graph.query import KHop, PageRankQuery
from repro.graph.sharded import ShardedDynamicGraph
from repro.launch.serve_graph import GraphQueryServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny config for CI")
    args = ap.parse_args()
    n = 400 if args.smoke else 4_000
    epochs = 6 if args.smoke else 10
    adds = 400 if args.smoke else 4_000

    batches = synthesize_skewed_stream(n, epochs, adds, seed=0,
                                       zipf_a=1.2, delete_frac=0.1)
    e_max = sum(len(b.add_src) for b in batches) + 16
    planner = ShardPlanner(imbalance_threshold=1.2, min_load=adds / 4.0,
                           min_epochs=1, max_shards=8)
    sg = ShardedDynamicGraph(4, n, e_max, planner=planner)
    server = GraphQueryServer(sg, tol=1e-6, max_iter=200)

    print(f"== zipf-skewed stream ({epochs} epochs x {adds} adds) into "
          "4 shards + ShardPlanner ==")
    rng = np.random.default_rng(1)
    answered = []
    t0 = time.perf_counter()
    for b in batches:
        n_events = len(server.reshard_events)
        server.step(b)                 # planner tick + ingest + seal
        for _ in range(4):
            server.submit(KHop(int(rng.integers(0, n)), k=2))
        server.submit(PageRankQuery(top_k=5))
        answered.extend(server.flush())
        # live edges per shard at the served snapshot (edge ROWS would
        # still count the migration-tombstoned rows on the source shard)
        counts = [v.m for v in sg.shard_views(b.version)]
        marker = ""
        if len(server.reshard_events) > n_events:
            ev = server.reshard_events[-1]
            marker = (f"   <- SPLIT shard {ev['source']} -> {ev['target']} "
                      f"(plan {ev['plan_id']}, {ev['migrated_edges']} edges "
                      f"migrated inside epoch "
                      f"{ev['activation_epoch']}'s seal)")
        # the critical path tracks the hottest shard's absolute share of
        # the work, so that is the number to watch shrink across splits
        share = max(counts) / max(sum(counts), 1)
        print(f"  epoch {b.version.epoch}: live edges/shard {counts} "
              f"(hottest holds {share:.0%}){marker}")
    wall = time.perf_counter() - t0

    s = server.stats()
    print(f"\n{len(server.reshard_events)} splits fired; "
          f"{s.n_shards} shards under routing plan "
          f"{s.routing_plan_id}; served {s.served} queries "
          f"in {wall:.2f}s")

    # audit: replay on a single store; every k-hop answer and the final
    # stitched view must be byte-identical despite the migrations
    g = DynamicGraph(n, e_max)
    for b in batches:
        g.apply(b)
    checked = 0
    for r in answered:
        if isinstance(r.query, KHop):
            expect = np.asarray(gc.k_hop(g.join_view(r.version),
                                         np.array([r.query.source]),
                                         r.query.k))
            assert np.array_equal(r.value, expect), \
                f"divergence at {r.version} for {r.query}"
            checked += 1
    v_last = Version(epochs - 1, 0)
    sv, gv = sg.join_view(v_last), g.join_view(v_last)
    assert np.array_equal(np.asarray(sv.src), np.asarray(gv.src))
    assert np.array_equal(np.asarray(sv.offsets), np.asarray(gv.offsets))
    if not server.reshard_events:
        raise SystemExit("expected at least one split on the skewed stream")
    print(f"{checked} k-hop answers + final stitched CSR audited "
          "byte-identical against a single-store replay")
    print("\nOK — hot shard split itself; queries never noticed")


if __name__ == "__main__":
    main()
