"""SP0xx — seal-plane disjointness (I6 mechanized).

With ``parallel_apply > 1``, per-shard seal closures run concurrently on
a thread pool with NO lock: correctness rests entirely on the
architecture's disjointness argument — a plane closure may touch only
state owned by *its* shard (``shards[shard_id]`` / ``nodes[shard_id]`` /
``shard_apply_seconds[shard_id]``), while the serial seams (coordinator,
ingest node, routing plan, access ledger, migration records, view cache)
belong to the calling thread between rounds. This checker makes that
argument mechanical:

* SP001: inside a seal-plane closure — a ``def``/``lambda`` nested in a
  function that takes a shard id (``shard_id`` / ``shard`` / ``sid``
  parameter) — flag any write to a plain ``self`` attribute, any
  subscript write not indexed by the shard id (or into a non-shard-owned
  attribute), any structural mutator (``append``/``update``/...) on a
  shard-owned container (growing ``shards`` is a cutover, never a plane
  action), and any method call through a serial-seam attribute.
* SP002: a closure handed directly to ``executor.submit(...)`` that
  writes ``self`` state — the pool must receive shard-owned bound
  methods (``n.seal_epoch``), not ad-hoc closures with coordinator
  access.

Reads are not flagged: the plane legitimately reads shared config, and
read races are the coordinator's contract (frontier visibility), not
this rule's.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.staticcheck.core import (FileContext, Finding,
                                             register_checker, register_rule)

SP001 = register_rule(
    "SP001", "seal-plane closure mutates state not owned by its shard")
SP002 = register_rule(
    "SP002", "closure submitted to the apply pool writes shared state")

SCOPE = ("graph", "core", "launch")

SHARD_ID_PARAMS = frozenset({"shard_id", "shard", "sid"})
# containers indexed by shard id; the plane owns exactly its slot —
# wal_shards holds each shard's append-only WAL writer (one writer per
# shard, touched only by that shard's seal closure)
SHARD_OWNED = frozenset({"shards", "nodes", "shard_apply_seconds",
                         "wal_shards"})
# coordinator-plane state: serial seams between seal rounds — including
# the replica plane's guarded state (the retired-shard set mutates only
# at merge cutovers, and mirror refresh state only at the publish
# boundary; a per-shard seal closure touching either breaks I10), and
# the trace-prewarm worker handoff (spawned/fed only from the publish
# path, which the write lock serializes — never from a shard closure)
# ... and the durability plane: the store-level WAL (control log +
# commit records write on the serial thread inside coordinator.advance),
# the fault injector (a seal closure READS it via a local at entry, but
# arming/healing faults is operator-thread work), and the serving tier's
# degraded-mode backlog (write-plane state under _ingest_lock)
SERIAL_SEAM = frozenset({"coordinator", "ingest_node", "plan", "route",
                         "access_stats", "migrations", "_views", "planner",
                         "retired", "_serving", "_mirror_planner",
                         "_prewarm_thread", "_prewarm_wake",
                         "_prewarm_target",
                         "wal", "fault_injector", "_seal_backlog"})
MUTATORS = frozenset({"append", "extend", "insert", "pop", "popitem",
                      "remove", "clear", "update", "add", "discard",
                      "setdefault", "sort"})


def _self_attr(node: ast.AST) -> Optional[str]:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _plane_violations(ctx: FileContext, body: list[ast.stmt],
                      id_names: frozenset[str], rule: str,
                      where: str) -> list[Finding]:
    findings: list[Finding] = []
    for node in ast.walk(ast.Module(body=body, type_ignores=[])):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for tgt in targets:
                attr = _self_attr(tgt)
                if attr is not None:
                    findings.append(ctx.finding(
                        tgt, rule,
                        f"{where} rebinds 'self.{attr}' — coordinator "
                        "state is off-limits on the apply plane (I6)"))
                    continue
                if isinstance(tgt, ast.Subscript):
                    attr = _self_attr(tgt.value)
                    if attr is None:
                        continue
                    if attr not in SHARD_OWNED:
                        findings.append(ctx.finding(
                            tgt, rule,
                            f"{where} writes 'self.{attr}[...]' which is "
                            "not shard-owned state (I6)"))
                    elif not (id_names & _names_in(tgt.slice)):
                        findings.append(ctx.finding(
                            tgt, rule,
                            f"{where} writes 'self.{attr}[...]' at an "
                            "index that is not the shard id — slots "
                            "other than the closure's own are another "
                            "thread's (I6)"))
        elif isinstance(node, ast.Call):
            fn = node.func
            if not isinstance(fn, ast.Attribute):
                continue
            attr = _self_attr(fn.value)
            if attr is None:
                continue
            if attr in SERIAL_SEAM:
                findings.append(ctx.finding(
                    node, rule,
                    f"{where} calls 'self.{attr}.{fn.attr}()' — serial-"
                    "seam state belongs to the calling thread (I6)"))
            elif fn.attr in MUTATORS:
                findings.append(ctx.finding(
                    node, rule,
                    f"{where} structurally mutates 'self.{attr}' "
                    f"(.{fn.attr}) — container shape changes are "
                    "cutovers, never plane actions (I6)"))
    return findings


@register_checker(scope=SCOPE)
def check_seal_plane(ctx: FileContext):
    findings: list[Finding] = []
    for fn in [n for n in ast.walk(ctx.tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        id_names = frozenset(
            p for p in (a.arg for a in fn.args.posonlyargs + fn.args.args)
            if p in SHARD_ID_PARAMS)
        if id_names:
            # nested defs/lambdas in a shard-id factory are plane closures
            for st in fn.body:
                for sub in ast.walk(st):
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        findings.extend(_plane_violations(
                            ctx, sub.body, id_names, SP001,
                            f"seal closure '{sub.name}'"))
                    elif isinstance(sub, ast.Lambda):
                        findings.extend(_plane_violations(
                            ctx, [ast.Expr(value=sub.body)], id_names,
                            SP001, "seal lambda"))
        # SP002: closures handed straight to executor.submit(...)
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "submit" and node.args):
                continue
            task = node.args[0]
            if isinstance(task, ast.Lambda):
                # no shard-id binding is knowable for an ad-hoc lambda, so
                # every self write (even into shard-owned slots) flags
                findings.extend(_plane_violations(
                    ctx, [ast.Expr(value=task.body)], frozenset(),
                    SP002, "submitted lambda"))
            elif isinstance(task, ast.Name):
                target = _local_def(fn, task.id)
                if target is not None:
                    findings.extend(_plane_violations(
                        ctx, target.body, frozenset(), SP002,
                        f"submitted closure '{target.name}'"))
    return findings


def _local_def(fn: ast.AST, name: str) -> Optional[ast.FunctionDef]:
    for sub in ast.walk(fn):
        if isinstance(sub, (ast.FunctionDef,
                            ast.AsyncFunctionDef)) and sub.name == name:
            return sub
    return None
