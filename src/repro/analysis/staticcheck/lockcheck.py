"""RL0xx — lock discipline.

Mechanizes the locking contracts written in prose in
``docs/ARCHITECTURE.md`` (I2 atomic apply, I4 no-wait dispatch):

* RL001: a guarded attribute is read or written on a path that does not
  (lexically) hold its lock. Guarded-by relations come from two sources:
  the declarative :data:`SPEC` registry for the classes whose contracts
  are part of the architecture (``GraphQueryServer._ingest_lock`` /
  ``GraphQueryServer._serve_lock`` — the serving tier's seal-swap planes —
  ``GraphRPCServer._conn_lock``, ``SnapshotQueryEngine._rank_lock``), and
  inference for everything else —
  any attribute *written* under ``with self.<lock>`` somewhere in a class
  is treated as guarded by that lock everywhere in the class.
* RL002: inconsistent nested acquisition order — the same class acquires
  lock B inside lock A on one path and A inside B on another (a deadlock
  seed the moment two threads take the two paths).
* RL003: a blocking call (``.result()``, ``.block_until_ready()``,
  ``.join()``, ``.wait()``, ``sleep``) made while holding a lock — the
  exact shape that serializes the apply plane the paper's no-wait
  dispatch rule exists to avoid.

Scope and honesty: the analysis is lexical and intra-method. ``with
self._lock:`` blocks are the only acquisition form tracked (the repo has
no bare ``.acquire()`` calls); calls into other methods are not followed,
so a helper that *requires* the lock held is the caller's responsibility —
exactly the contract the registry documents. ``__init__`` is exempt
(objects under construction are unshared). Closures defined inside a
method are checked with an *empty* held-set: they execute later, on
whatever thread calls them, so a definition site inside a ``with`` block
proves nothing.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterable, Optional

from repro.analysis.staticcheck.core import (FileContext, Finding,
                                             register_checker, register_rule)

RL001 = register_rule(
    "RL001", "guarded attribute accessed without holding its lock")
RL002 = register_rule(
    "RL002", "inconsistent lock-acquisition order within a class")
RL003 = register_rule(
    "RL003", "blocking call while holding a lock (no-wait dispatch, I4)")


@dataclasses.dataclass(frozen=True)
class ClassLockSpec:
    """Guarded-by map for one class: lock attr -> guarded attr names."""
    locks: dict[str, frozenset[str]]
    exempt_methods: frozenset[str] = frozenset({"__init__"})


# The architectural locking contracts. These override inference: if a
# class name appears here, exactly these relations are enforced.
SPEC: dict[str, ClassLockSpec] = {
    # the seal-swap discipline: the re-entrant write-plane lock serializes
    # ingest/seal/re-shard state, the read-plane lock guards only the
    # pending queue + published snapshot + serving counters. Query compute
    # runs on immutable published views outside BOTH. The only permitted
    # runtime nesting is _ingest_lock -> _serve_lock (the seal-time
    # publish); nothing may acquire the write lock while holding the read
    # lock (RL002 would flag the lexical shape of such a path).
    "GraphQueryServer": ClassLockSpec(locks={
        "_ingest_lock": frozenset({
            "graph", "_seals", "reshard_events",
            # degraded mode (I11): the failed-seal backlog and its
            # lifetime counter mutate only on the write plane (step /
            # reseal); the read plane stamps responses from the
            # lock-free _degraded_hint instead
            "_seal_backlog", "seal_failures",
        }),
        "_serve_lock": frozenset({
            "_pending_cheap", "_pending_expensive", "_serving",
            "_published", "_touch_buffer", "_touch_buffered",
            "served", "windows", "shed_overload", "shed_deadline",
            "latencies_s", "_kind_latencies", "_lane_latencies",
        }),
        # prewarm mailbox: the one-slot coalescing target the publish
        # path hands to the trace-prewarm worker, plus its run counter
        "_prewarm_lock": frozenset({
            "_prewarm_target", "prewarm_runs",
        }),
    }),
    # the RPC listener's only shared mutable state is the live-connection
    # set (reader threads add/remove themselves; stop() snapshots it) —
    # everything else is per-connection locals or the query server's own
    # planes above
    "GraphRPCServer": ClassLockSpec(locks={
        "_conn_lock": frozenset({"_conns"}),
    }),
    # WAL writer lock: guards the control-log file handle and its fsync
    # batcher. The per-shard segment writers are deliberately NOT here —
    # each ShardWal is shard-owned state touched only by its shard's
    # seal (sealcheck's plane rules cover that relation)
    "GraphWal": ClassLockSpec(locks={
        "_lock": frozenset({"_control_f", "_control_synced"}),
    }),
    # chaos hook: armed faults are read from the parallel apply plane
    # (seal entry) and mutated from test/operator threads. The stall
    # sleep and the fault raise happen OUTSIDE the lock (RL003)
    "FaultInjector": ClassLockSpec(locks={
        "_lock": frozenset({"_fail_once", "_down", "_stall",
                            "faults_fired"}),
    }),
    # the engine's own lock guards the rank cache and telemetry counters
    # — including the replica-plane counters (mirror hit/miss, routed
    # windows, fan-out histogram), which concurrent flushers race on —
    # independent of the server's coarser lock. The versioned result
    # cache and the prewarm signature memory ride the same lock: the
    # cheap/expensive dispatchers and the prewarm worker all touch them
    "SnapshotQueryEngine": ClassLockSpec(locks={
        "_rank_lock": frozenset({
            "_rank_cache", "rank_cache_hits", "rank_warm_starts",
            "rank_cold_starts", "vectorized_calls",
            "mirror_hits", "mirror_misses", "routed_windows",
            "fanout_hist",
            "_result_cache", "result_cache_hits", "result_cache_misses",
            "result_cache_evictions", "_warm_signatures",
            "_warmed_traces",
        }),
    }),
}

# attribute-call names that block the calling thread
BLOCKING_ATTRS = frozenset(
    {"result", "block_until_ready", "join", "wait", "sleep"})
# mutator method names that count as writes for guard inference
MUTATOR_ATTRS = frozenset(
    {"append", "extend", "insert", "pop", "popitem", "remove", "clear",
     "update", "add", "discard", "setdefault", "sort"})
_LOCK_CTORS = frozenset({"Lock", "RLock"})


def _self_attr(node: ast.AST) -> Optional[str]:
    """'X' when node is exactly ``self.X``."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _lock_ctor_name(call: ast.AST) -> bool:
    """True for ``threading.Lock()`` / ``Lock()`` / ``threading.RLock()``."""
    if not isinstance(call, ast.Call):
        return False
    fn = call.func
    if isinstance(fn, ast.Attribute):
        return fn.attr in _LOCK_CTORS
    return isinstance(fn, ast.Name) and fn.id in _LOCK_CTORS


def _methods(cls: ast.ClassDef) -> list[ast.FunctionDef]:
    return [n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _infer_spec(cls: ast.ClassDef) -> Optional[ClassLockSpec]:
    """Infer a lock spec for an unregistered class: locks are
    ``self.X = threading.Lock()/RLock()`` in ``__init__``; guarded attrs
    are whatever gets *written* under ``with self.X`` anywhere."""
    lock_names: set[str] = set()
    for m in _methods(cls):
        if m.name != "__init__":
            continue
        for st in ast.walk(m):
            if isinstance(st, ast.Assign) and _lock_ctor_name(st.value):
                for tgt in st.targets:
                    attr = _self_attr(tgt)
                    if attr:
                        lock_names.add(attr)
    if not lock_names:
        return None

    guarded: dict[str, set[str]] = {lk: set() for lk in lock_names}

    def record_writes(stmts: Iterable[ast.stmt], held: frozenset[str]):
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
                continue
            if isinstance(st, (ast.With, ast.AsyncWith)):
                acquired = {a for item in st.items
                            if (a := _self_attr(item.context_expr))
                            in lock_names}
                record_writes(st.body, held | frozenset(acquired))
                continue
            for node in ast.walk(st):
                attr = None
                if isinstance(node, (ast.Assign, ast.AugAssign)):
                    targets = (node.targets
                               if isinstance(node, ast.Assign)
                               else [node.target])
                    for tgt in targets:
                        base = tgt.value if isinstance(tgt, ast.Subscript) \
                            else tgt
                        attr = _self_attr(base)
                        if attr:
                            for lk in held:
                                guarded[lk].add(attr)
                elif (isinstance(node, ast.Call)
                      and isinstance(node.func, ast.Attribute)
                      and node.func.attr in MUTATOR_ATTRS):
                    attr = _self_attr(node.func.value)
                    if attr:
                        for lk in held:
                            guarded[lk].add(attr)
            # statements with nested bodies keep the held set
            for field in ("body", "orelse", "finalbody", "handlers"):
                sub = getattr(st, field, None)
                if sub:
                    record_writes(
                        [s for s in sub if isinstance(s, ast.stmt)], held)

    for m in _methods(cls):
        if m.name != "__init__":
            record_writes(m.body, frozenset())
    locks = {lk: frozenset(attrs - lock_names)
             for lk, attrs in guarded.items() if attrs}
    if not locks:
        return None
    return ClassLockSpec(locks=locks)


class _MethodScanner:
    """Lexical lock-hold walk over one method."""

    def __init__(self, ctx: FileContext, cls_name: str, spec: ClassLockSpec,
                 findings: list[Finding],
                 nest_pairs: list[tuple[str, str, ast.AST]]):
        self.ctx = ctx
        self.cls_name = cls_name
        self.spec = spec
        self.findings = findings
        self.nest_pairs = nest_pairs
        # attr -> the locks that guard it; holding ANY of them satisfies
        # the access (inference can attribute one attr to several locks
        # when it is only ever written under a nested acquisition)
        self.guard_of: dict[str, set[str]] = {}
        for lk, attrs in spec.locks.items():
            for attr in attrs:
                self.guard_of.setdefault(attr, set()).add(lk)

    def scan(self, fn: ast.FunctionDef) -> None:
        self._visit_body(fn.body, frozenset())

    # -- walk ---------------------------------------------------------------
    def _visit_body(self, stmts, held: frozenset[str]) -> None:
        for st in stmts:
            self._visit(st, held)

    def _visit(self, node: ast.AST, held: frozenset[str]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a closure runs later, on an unknown thread: empty held-set
            self._visit_body(node.body, frozenset())
            return
        if isinstance(node, ast.Lambda):
            self._visit(node.body, frozenset())
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            acquired = set()
            for item in node.items:
                attr = _self_attr(item.context_expr)
                if attr in self.spec.locks:
                    acquired.add(attr)
                    for outer in held:
                        if outer != attr:
                            self.nest_pairs.append(
                                (outer, attr, item.context_expr))
                else:
                    self._visit(item.context_expr, held)
            self._visit_body(node.body, held | frozenset(acquired))
            return

        attr = _self_attr(node)
        if attr is not None:
            guards = self.guard_of.get(attr)
            if guards and not (held & guards):
                kind = ("write" if isinstance(
                    getattr(node, "ctx", None), (ast.Store, ast.Del))
                    else "read")
                lock = "'" + "'/'".join(sorted(guards)) + "'"
                self.findings.append(self.ctx.finding(
                    node, RL001,
                    f"{kind} of '{self.cls_name}.{attr}' without holding "
                    f"{lock} (guarded attribute)"))
            # still descend: self.X[i] etc. handled by caller's iteration
        if isinstance(node, ast.Call) and held:
            fn = node.func
            if (isinstance(fn, ast.Attribute)
                    and fn.attr in BLOCKING_ATTRS
                    and not isinstance(fn.value, ast.Constant)):
                self.findings.append(self.ctx.finding(
                    node, RL003,
                    f"blocking call '.{fn.attr}()' while holding "
                    f"{sorted(held)} (I4: no-wait dispatch)"))

        for child in ast.iter_child_nodes(node):
            self._visit(child, held)


@register_checker()   # lock discipline applies everywhere
def check_locks(ctx: FileContext):
    findings: list[Finding] = []
    for cls in [n for n in ast.walk(ctx.tree)
                if isinstance(n, ast.ClassDef)]:
        spec = SPEC.get(cls.name) or _infer_spec(cls)
        if spec is None:
            continue
        nest_pairs: list[tuple[str, str, ast.AST]] = []
        for m in _methods(cls):
            if m.name in spec.exempt_methods:
                continue
            _MethodScanner(ctx, cls.name, spec, findings, nest_pairs).scan(m)
        # RL002: (A inside B) and (B inside A) both observed in this class
        orders = {(a, b) for a, b, _ in nest_pairs}
        for a, b, node in nest_pairs:
            if (b, a) in orders:
                findings.append(ctx.finding(
                    node, RL002,
                    f"'{b}' acquired inside '{a}' but the opposite order "
                    f"also occurs in '{cls.name}' (deadlock seed)"))
    return findings
