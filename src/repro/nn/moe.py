"""Mixture-of-Experts FFN with top-k routing.

Two implementations, selectable via ``cfg.moe_impl``:

* ``dense``    — every expert computes every token, outputs combined with the
  (mostly-zero) routing weights. Simple, exactly differentiable, no token
  dropping — but inflates FLOPs by E/top_k. This is the *baseline* the perf
  log starts from.
* ``dropping`` — capacity-bounded gather/scatter dispatch (Switch-style):
  each expert processes at most C = ceil(T/E · top_k · capacity_factor)
  tokens, selected by routing weight. FLOPs ∝ top_k·capacity_factor instead
  of E. The beyond-baseline §Perf path.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.nn.layers import Init, dense


def init_moe(key, cfg):
    d = cfg.d_model
    e = cfg.n_experts
    ffe = cfg.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": Init(ks[0], (d, e), jnp.float32),
        "w1": Init(ks[1], (e, d, ffe), cfg.param_dtype),
        "w3": Init(ks[2], (e, d, ffe), cfg.param_dtype),
        "w2": Init(ks[3], (e, ffe, d), cfg.param_dtype),
    }


def _routing(p, x, cfg):
    """x: (T,D) -> (weights (T,E) with zeros off top-k, aux losses)."""
    logits = x.astype(jnp.float32) @ p["router"]            # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, cfg.top_k)        # (T,K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(top_idx, cfg.n_experts, dtype=jnp.float32)  # (T,K,E)
    combine = (onehot * top_w[..., None]).sum(axis=1)       # (T,E)
    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    f = onehot.sum(axis=1).mean(axis=0)                     # fraction routed
    pbar = probs.mean(axis=0)
    aux = cfg.n_experts * jnp.sum(f * pbar)
    return combine, top_idx, top_w, aux


def _expert_ffn(p, x, accum=jnp.float32):
    """Batched-over-experts gated FFN. x: (E,C,D) -> (E,C,D)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, p["w1"].astype(x.dtype),
                               preferred_element_type=jnp.float32))
    h3 = jnp.einsum("ecd,edf->ecf", x, p["w3"].astype(x.dtype),
                    preferred_element_type=jnp.float32)
    h = (h * h3).astype(x.dtype)
    return jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(x.dtype),
                      preferred_element_type=accum).astype(x.dtype)


def moe_dense(p, x, cfg):
    """x: (B,S,D). Every expert computes every token."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    combine, _, _, aux = _routing(p, xt, cfg)
    from repro.nn.layers import accum_dtype
    xe = jnp.broadcast_to(xt[None], (cfg.n_experts,) + xt.shape)  # (E,T,D)
    ye = _expert_ffn(p, xe, accum=accum_dtype(cfg))               # (E,T,D)
    y = jnp.einsum("etd,te->td", ye.astype(jnp.float32), combine)
    return y.reshape(B, S, D).astype(x.dtype), aux


def moe_dropping(p, x, cfg):
    """Capacity-bounded dispatch: gather top-C tokens per expert.

    With ``cfg.moe_groups > 1`` the token axis is split into G groups that
    align with the DP shards (the group axis carries the 'batch' sharding
    constraint), so the gather/scatter never crosses data shards — expert
    parallelism without the all-shard token shuffle (§Perf: this removed the
    dominant (E, C_global, d) all-reduces on mixtral)."""
    from repro.launch.sharding import constrain
    from repro.nn.layers import accum_dtype
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    combine, top_idx, top_w, aux = _routing(p, xt, cfg)     # combine: (T,E)
    E = cfg.n_experts
    G = cfg.moe_groups if cfg.moe_groups > 1 and T % cfg.moe_groups == 0 else 1
    Tl = T // G
    C = int(math.ceil(Tl / E * cfg.top_k * cfg.capacity_factor))
    C = min(C, Tl)
    xg_t = constrain(xt.reshape(G, Tl, D), ("batch", None, None))
    gate = constrain(combine.reshape(G, Tl, E), ("batch", None, None))

    def dispatch(xt_l, gate_l):
        # per-group: select, per expert, the C tokens with largest weight
        sel_w, sel_idx = jax.lax.top_k(gate_l.T, C)          # (E,C)
        xg = jnp.take(xt_l, sel_idx.reshape(-1), axis=0).reshape(E, C, D)
        yg = _expert_ffn(p, xg, accum=accum_dtype(cfg))      # (E,C,D)
        yg = yg.astype(jnp.float32) * sel_w[..., None]
        y = jnp.zeros((Tl, D), jnp.float32)
        return y.at[sel_idx.reshape(-1)].add(yg.reshape(E * C, D))

    if G == 1:
        y = dispatch(xt, combine)
    else:
        y = jax.vmap(dispatch)(xg_t, gate)
        y = constrain(y, ("batch", None, None))
    return y.reshape(B, S, D).astype(x.dtype), aux


def moe_forward(p, x, cfg):
    if cfg.moe_impl == "dropping":
        return moe_dropping(p, x, cfg)
    return moe_dense(p, x, cfg)
