"""Distributed views — paper §2.3.2.

A distributed view is an immutable dataset *expressed by the computation from
which it is generated* (like RDD lineage). Fault tolerance = re-running the
lineage path. Views are how online and offline computations share data: the
online side reads materialized views; the offline side (re)builds them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.core.versioned import Version


@dataclasses.dataclass(frozen=True)
class ViewSpec:
    name: str
    compute: Callable[..., Any]           # parents' values -> value
    parents: tuple["View", ...] = ()
    snapshot: Optional[Version] = None    # pin to a graph snapshot


class View:
    """Immutable, lineage-carrying, lazily-materialized dataset."""

    def __init__(self, spec: ViewSpec):
        self.spec = spec
        self._value: Any = None
        self._materialized = False

    @staticmethod
    def source(name: str, produce: Callable[[], Any],
               snapshot: Optional[Version] = None) -> "View":
        return View(ViewSpec(name, lambda: produce(), (), snapshot))

    def map(self, name: str, fn: Callable[[Any], Any]) -> "View":
        return View(ViewSpec(name, fn, (self,), self.spec.snapshot))

    @staticmethod
    def join(name: str, fn: Callable[..., Any], *parents: "View") -> "View":
        snap = max((p.spec.snapshot for p in parents
                    if p.spec.snapshot is not None), default=None)
        return View(ViewSpec(name, fn, tuple(parents), snap))

    def value(self):
        if not self._materialized:
            args = [p.value() for p in self.spec.parents]
            self._value = self.spec.compute(*args)
            self._materialized = True
        return self._value

    # ---------------------------------------------------------- fault path
    def invalidate(self, *, recursive: bool = False) -> None:
        """Simulate loss of the materialized partition (node failure)."""
        self._value, self._materialized = None, False
        if recursive:
            for p in self.spec.parents:
                p.invalidate(recursive=True)

    def recover(self):
        """Recompute along the lineage path (paper: 'trace back its lineage
        and redo the computations')."""
        return self.value()

    def lineage(self) -> list[str]:
        out: list[str] = []

        def walk(v: "View"):
            for p in v.spec.parents:
                walk(p)
            out.append(v.spec.name)
        walk(self)
        return out
