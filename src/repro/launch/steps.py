"""jit-able step functions: train_step, prefill_step, decode_step.

These close over the ModelConfig (static) and take pytrees of arrays, so the
same function objects are used by the CPU examples, the smoke tests, and the
512-device dry-run lowering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.train.loss import chunked_cross_entropy
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state

AUX_LOSS_WEIGHT = 0.01


def make_positions(batch, seq):
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))


def loss_fn(params, cfg: ModelConfig, batch):
    inputs = batch["inputs"]
    B, S = inputs.shape[:2]
    positions = make_positions(B, S)
    hidden, aux = tf.forward(params, cfg, inputs, positions)
    loss_sum, cnt = chunked_cross_entropy(
        params["lm_head"], hidden, batch["labels"],
        chunk=cfg.loss_chunk, softcap=cfg.logit_softcap)
    loss = loss_sum / jnp.maximum(cnt, 1.0)
    return loss + AUX_LOSS_WEIGHT * aux, {"ce": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, oc: OptConfig | None = None):
    oc = OptConfig() if oc is None else oc
    mb = max(cfg.microbatches, 1)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, batch)

    def train_step(state, batch):
        if mb == 1:
            (loss, metrics), grads = grads_of(state["params"], batch)
        else:
            # gradient accumulation: scan over microbatches so only one
            # microbatch's activations are live at a time (capacity /= mb)
            def split(x):
                return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(carry, mbatch):
                gsum, loss_sum = carry
                (loss, _), g = grads_of(state["params"], mbatch)
                return (jax.tree.map(jnp.add, gsum, g),
                        loss_sum + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state["params"])
            (gsum, loss_sum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / mb, gsum)
            loss = loss_sum / mb
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        params, opt, gnorm = adamw_update(oc, state["params"], grads,
                                          state["opt"])
        new_state = {"params": params, "opt": opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_state, metrics
    return train_step


def init_train_state(cfg: ModelConfig, key):
    params = tf.init_params(cfg, key)
    return {"params": params, "opt": init_opt_state(params),
            "step": jnp.zeros((), jnp.int32)}


def train_state_shapes(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(init_train_state, cfg),
                          jax.random.PRNGKey(0))


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, cache = tf.prefill(params, cfg, batch["inputs"])
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, inputs, pos):
        logits, cache = tf.decode_step(params, cfg, cache, inputs, pos)
        return logits, cache
    return decode_step
