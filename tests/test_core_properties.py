"""Property tests (hypothesis) for the paper's core invariants.

``hypothesis`` is optional (not installable in network-less environments):
without it the ``@given`` property tests are skipped but the plain tests in
this module still collect and run.
"""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:        # pragma: no cover - exercised in offline envs
    class _StrategyStub:
        """Stands in for hypothesis.strategies at decoration time only."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn

from repro.core.clock import Event, EventLog, LamportClock
from repro.core.replica import ReplicaManager
from repro.core.snapshotter import (DataNode, IngestNode, Mutation,
                                    SnapshotCoordinator)
from repro.core.versioned import Version, VersionedArray, VersionedStore
from repro.core.views import View


# ------------------------------------------------------------- versioned
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 100)),
                min_size=1, max_size=40, unique=True),
       st.integers(0, 5), st.integers(0, 100))
def test_snapshot_rule_matches_max_leq(writes, qe, qn):
    """snapshot(v) returns d(i_v) with i_v = max{v' <= v} — paper §2.3.1."""
    store = VersionedStore()
    for e, n in writes:
        store.put("k", Version(e, n), (e, n))
    q = Version(qe, qn)
    eligible = [Version(e, n) for e, n in writes if Version(e, n) <= q]
    if not eligible:
        with pytest.raises(KeyError):
            store.get("k", q)
    else:
        expect = max(eligible)
        assert store.get("k", q) == (expect.epoch, expect.number)


@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 100)),
                min_size=2, max_size=30, unique=True))
def test_snapshot_monotone(writes):
    """Later snapshots never see older values than earlier snapshots."""
    store = VersionedStore()
    for e, n in writes:
        store.put("k", Version(e, n), Version(e, n).pack())
    versions = sorted(Version(e, n) for e, n in writes)
    seen = []
    for v in versions:
        seen.append(store.get("k", v))
    assert seen == sorted(seen)


def test_versioned_store_immutable_versions():
    store = VersionedStore()
    store.put("k", Version(0, 1), "a")
    with pytest.raises(ValueError):
        store.put("k", Version(0, 1), "b")


def test_versioned_store_gc():
    store = VersionedStore()
    for i in range(10):
        store.put("k", Version(0, i), i)
    dropped = store.gc_below(Version(0, 5))
    assert dropped == 5
    assert store.get("k", Version(0, 5)) == 5   # still resolvable
    assert store.get("k", Version(0, 9)) == 9


def test_versioned_array_matches_store():
    va = VersionedArray(4, 8)
    store = VersionedStore()
    for t, (item, val) in enumerate([(0, 1.0), (1, 2.0), (0, 3.0), (2, 4.0)]):
        v = Version(0, t + 1)
        va.write(np.array([item]), v, np.array([val]))
        store.put(item, v, val)
    for q in range(5):
        got = np.asarray(va.read_snapshot(Version(0, q), default=-1.0))
        for item in range(4):
            try:
                expect = store.get(item, Version(0, q))
            except KeyError:
                expect = -1.0
            assert got[item] == expect, (item, q)


# ----------------------------------------------------------------- clocks
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3)),
                min_size=1, max_size=50))
def test_lamport_condition(sends):
    """If e1 -> e2 then T(e1) < T(e2): message receive is after its send."""
    clocks = [LamportClock(i) for i in range(4)]
    for src, dst in sends:
        s = clocks[src].send()
        r = clocks[dst].receive(s)
        assert s < r   # total order extends the causal order


def test_event_log_causal_delivery():
    log = EventLog()
    c1, c2 = LamportClock(1), LamportClock(2)
    s = c1.send()
    log.record(Event(s, "send", {"id": 1}))
    r = c2.receive(s)
    log.record(Event(r, "recv", {"id": 1}))
    log.register_relation(
        lambda e1, e2: True if (e1.kind == "send" and e2.kind == "recv"
                                and e1.payload["id"] == e2.payload["id"])
        else None)
    delivered = log.deliver()
    assert [e.kind for e in delivered] == ["send", "recv"]
    assert log.check_causal_consistency(delivered)


# -------------------------------------------------------------- snapshotter
@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7), st.integers(0, 3)),
                min_size=1, max_size=60))
def test_no_wait_dispatch_and_monotone_global(muts):
    """Mutations dispatch when the TARGET node's local frontier covers prior
    epochs (never waiting on the global frontier); global frontier is
    monotone and trails local frontiers."""
    nodes = [DataNode(i) for i in range(4)]
    coord = SnapshotCoordinator(nodes)
    ingest = IngestNode(nodes, route=lambda k: k % 4)
    max_epoch = 3
    frontiers = []
    by_epoch = sorted(muts, key=lambda m: m[1])
    for epoch in range(max_epoch + 1):
        for key, e in by_epoch:
            if e == epoch:
                ingest.dispatch(Mutation(key, e))
        for n in nodes:
            n.seal_epoch(epoch)
        ingest.retry_blocked()
        g = coord.advance()
        frontiers.append(g)
        assert g <= min(n.local_frontier for n in nodes)
    assert frontiers == sorted(frontiers)
    assert not ingest.blocked


def test_straggler_shard_holds_global_frontier():
    """A shard whose epoch is unsealed gates the global frontier: healthy
    shards may run arbitrarily far ahead, the min still rules."""
    nodes = [DataNode(i) for i in range(3)]
    coord = SnapshotCoordinator(nodes)
    for epoch in range(4):
        for n in nodes[:-1]:
            n.seal_epoch(epoch)
        assert coord.advance() == -1      # straggler never sealed anything
    assert [n.local_frontier for n in nodes] == [3, 3, -1]
    nodes[-1].seal_epoch(0)
    assert coord.advance() == 0           # frontier = straggler's frontier
    for epoch in range(1, 4):
        nodes[-1].seal_epoch(epoch)
        assert coord.advance() == epoch
    # monotone history throughout
    assert coord._history == sorted(coord._history)


def test_schedule_on_snapshot_fires_exactly_once():
    """Callbacks run exactly once: immediately if the snapshot is already
    global, else on the first advance() that covers them — never again on
    later advances."""
    nodes = [DataNode(0), DataNode(1)]
    coord = SnapshotCoordinator(nodes)
    fired = []
    coord.schedule_on_snapshot(1, lambda: fired.append("e1"))
    nodes[0].seal_epoch(0)
    nodes[0].seal_epoch(1)
    for _ in range(3):                    # straggler: repeated advances
        coord.advance()                   # must not fire (or double-fire)
    assert fired == []
    nodes[1].seal_epoch(0)
    nodes[1].seal_epoch(1)
    coord.advance()
    assert fired == ["e1"]
    for _ in range(3):
        coord.advance()                   # already-fired callback stays gone
    assert fired == ["e1"]
    coord.schedule_on_snapshot(0, lambda: fired.append("past"))
    assert fired == ["e1", "past"]        # past snapshot: immediate, once
    coord.advance()
    assert fired == ["e1", "past"]


def test_computation_waits_for_global_snapshot():
    nodes = [DataNode(0), DataNode(1)]
    coord = SnapshotCoordinator(nodes)
    ran = []
    coord.schedule_on_snapshot(1, lambda: ran.append("job"))
    nodes[0].seal_epoch(0)
    nodes[0].seal_epoch(1)
    coord.advance()
    assert not ran          # node 1 hasn't sealed epoch 1
    nodes[1].seal_epoch(0)
    nodes[1].seal_epoch(1)
    coord.advance()
    assert ran == ["job"]


# ------------------------------------------------------------------ replica
def test_replica_coherence_invalidate_on_write():
    rm = ReplicaManager(4, mirror_threshold=2)
    rm.add_item("x", owner=0, value=1)
    # node 2 reads often -> mirror created at rebalance
    for _ in range(3):
        rm.read(2, "x")
    rm.rebalance()
    assert rm.holds(2, "x")
    # write at owner invalidates mirror; next mirror read re-pulls new value
    rm.write(0, "x", Version(0, 1), 42)
    assert rm.read(2, "x") == 42


def test_replica_rebalance_reduces_cost():
    rm = ReplicaManager(4, mirror_threshold=4)
    for i in range(16):
        rm.add_item(i, owner=i % 4, value=i)
    rng = np.random.default_rng(0)
    def workload():
        for _ in range(200):
            item = int(rng.integers(0, 16))
            rm.read((item * 2 + 1) % 4, item)   # skewed remote access
    workload()
    before = rm.stats()["hit_rate"]
    rm.rebalance()
    rm.local_hits = rm.remote_misses = 0
    workload()
    after = rm.stats()["hit_rate"]
    assert after > before


def test_stale_write_rejected():
    rm = ReplicaManager(2)
    rm.add_item("x", owner=0, value=0)
    rm.write(0, "x", Version(0, 2), 1)
    with pytest.raises(ValueError):
        rm.write(0, "x", Version(0, 1), 2)


# -------------------------------------------------------------------- views
def test_view_lineage_recovery():
    calls = {"n": 0}
    def produce():
        calls["n"] += 1
        return list(range(10))
    base = View.source("base", produce)
    doubled = base.map("doubled", lambda xs: [2 * x for x in xs])
    total = doubled.map("total", sum)
    assert total.value() == 90
    assert calls["n"] == 1
    total.invalidate(recursive=True)
    assert total.recover() == 90        # recomputed along lineage
    assert calls["n"] == 2
    assert total.lineage() == ["base", "doubled", "total"]
