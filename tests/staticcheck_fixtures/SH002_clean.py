"""SH002 clean twin: stamps stay int32 end to end."""
import numpy as np


def liveness_mask(created, deleted, q):
    return (created <= q) & (q < deleted)


class Store:
    def __init__(self, e_max):
        self.created = np.zeros(e_max, np.int32)
        self.deleted = np.zeros(e_max, np.int32)

    def poison(self, rows):
        self.deleted[rows] = np.int32(7)

    def query(self, q):
        return liveness_mask(self.created, self.deleted, np.int32(q))
