"""CI benchmark smoke check for BENCH_ingest.json.

Validates that a fresh benchmark run produced every required section/metric
and that the scale-free ratio metrics (speedups — robust across machine
speeds, unlike raw latencies) have not collapsed versus the committed
baseline. "Regressed" means a ratio fell below half its baseline value:
generous enough for noisy CI runners, tight enough to catch the
vectorized/delta/sharded fast paths silently degrading to their fallbacks.

Several checks are absolute rather than baseline-relative:

* the ``resharding`` section must show splits firing and adaptive routing
  beating static dst-hash (speedup > 1.0) on the skewed stream — the
  claim itself, not just its trend;
* the 1-shard sharded configuration (the passthrough fast path) must run
  at >= 0.9x of the single store (the benchmark itself asserts the
  stricter 0.95x; this is the CI backstop against a partial report);
* the MEASURED 4-shard ``parallel_wall_s`` must beat the single store by
  > 1.3x — threads need cores, so this gate applies when the runner that
  produced the fresh report had >= 4 CPUs (recorded in the report; the
  GitHub CI runners qualify). On smaller hosts the measurement is
  reported but not gated: a 2-core shared VM thrashes the pool instead
  of overlapping it, and any threshold there gates host noise, not code;
* the ``serve_rpc`` serving-tier claims: epoch-pipelined reads must beat
  the serialized single-lock discipline > 1.2x on sustained QPS and
  > 1.2x on the median client round trip (the lock convoy holds on any
  host — see the gate comments), with p99 no worse than 2x, >= 8
  concurrent clients, and zero replay-oracle mismatches;
* the ``replica_locality`` replica-plane claims: on the zipf-hot stream
  replica-first routing must touch >= 1.5x fewer shards per window than
  global-view execution and improve the p99 round trip > 1.15x, with
  every answer replay-audited byte-identical (I10: mirrors are never
  visible in answers);
* the ``serve_fastpath`` low-latency claims: the two-lane scheduler +
  versioned result cache + publish-time prewarm must improve the
  cheap-kind p99 round trip >= 2x over the single-queue baseline under
  an expensive-query convoy with concurrent ingest, with non-zero cache
  hits and zero replay-oracle mismatches.

    python benchmarks/check_bench.py --fresh BENCH_ingest.json \
        --baseline /tmp/baseline.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REQUIRED = {
    "mutation_ingest": ["speedup", "vectorized_muts_per_s"],
    "view_build": [],          # at least one churn entry, checked below
    "sharded_ingest": ["single_store_muts_per_s", "shards"],
    "resharding": ["adaptive_vs_static_speedup", "adaptive_tail_muts_per_s",
                   "static_tail_muts_per_s", "splits", "final_shards",
                   "static_tail_max_shard_s", "adaptive_tail_max_shard_s"],
    "serve_graph": ["query_p50_s", "query_p95_s", "warm_pagerank_iters",
                    "cold_pagerank_iters", "warm_start_iter_reduction"],
    "serve_rpc": ["pipelined_vs_single_lock_speedup", "p50_improvement",
                  "p99_improvement", "n_clients", "answers_audited",
                  "oracle_mismatches", "single_lock", "pipelined"],
    "replica_locality": ["fanout_reduction", "routed_mean_fanout",
                         "routed_windows", "mirror_hit_rate",
                         "p50_improvement", "p99_improvement",
                         "answers_audited", "oracle_mismatches",
                         "no_replica", "replicated"],
    "serve_fastpath": ["cheap_p99_improvement", "cheap_p50_improvement",
                       "cache_hits", "cache_hit_rate", "prewarm_runs",
                       "n_clients", "answers_audited", "oracle_mismatches",
                       "single_queue", "fastpath"],
    "recovery": ["wal_overhead", "wal_on_muts_per_s", "wal_off_muts_per_s",
                 "recovery_long_tail_s", "recovery_short_tail_s",
                 "durable_frontier", "views_audited",
                 "recovered_mismatches"],
}
SHARD_COUNTS = ("1", "2", "4")
SHARD_METRICS = ["parallel_wall_s", "parallel_muts_per_s",
                 "parallel_speedup_vs_single", "speedup_vs_single",
                 "per_shard_muts_per_s", "stitch_s"]
# measured 4-shard parallel ingest must beat the single store by this
# factor on runners with >= PARALLEL_GATE_CPUS cores
PARALLEL_GATE = 1.3
PARALLEL_GATE_CPUS = 4
# epoch-pipelined RPC serving must beat the serialized single-lock
# discipline. Two speedups, two gates, neither with a CPU floor: the
# single-lock mode loses to a lock CONVOY — window pins wait out the
# in-flight whole-epoch apply, and the lock-held fraction does not
# shrink with core count — so both the sustained-QPS ratio (median over
# paired repeats) and the median-round-trip improvement hold even on a
# one-core host (measured ~1.5x each there; wider with real overlap).
# The benchmark keeps the effect structural rather than noise by sizing
# epochs so one apply takes at least a warm query round trip.
RPC_PIPELINE_GATE = 1.2
RPC_P50_GATE = 1.2
# ...and must not blow up tail latency while doing it: pipelined p99 may
# be at worst 2x the single-lock p99 (p99_improvement >= 1/2; the tail
# is a handful of samples per run, so this only catches blowups)
RPC_P99_FLOOR = 1 / 2
RPC_MIN_CLIENTS = 8
# the replica plane's locality claims, absolute like the serving gates:
# on the zipf-hot stream at 4 shards, replica-first routing must touch
# >= 1.5x fewer shards per window than global-view execution, and the
# shape-stable routed subsets must improve the p99 round trip > 1.15x —
# both hold on any host (the fan-out is counted, not timed, and the p99
# gap is structural: routed windows run pow2-bucketed edge subsets far
# smaller than the global CSR), with zero replay-oracle mismatches
REPLICA_FANOUT_GATE = 1.5
REPLICA_P99_GATE = 1.15
# the fast-path serving claims, absolute: under an expensive-query
# convoy (~10% multi-iteration PageRank windows) with concurrent ingest,
# the two-lane + result-cache + prewarm discipline must improve the
# cheap-kind (k-hop + degree-top-k) p99 round trip >= 2x over the PR 8
# single-queue baseline. The convoy is structural, not a tuning
# artifact: in the single queue every cheap round trip can land behind
# an in-flight PageRank window (tens of ms), while the cheap lane
# drains independently and cache hits skip execution entirely — so the
# gap holds on any host, one-core included. Cache hits must be non-zero
# (the zipf-hot workload guarantees repeat fingerprints within a
# version) and every audited answer byte-identical to the replay oracle.
FASTPATH_P99_GATE = 2.0
# the durability claim, absolute: with the default batched-fsync policy
# the write-ahead log may cost at most 15% of ingest wall clock
# (wal_on_wall_s / wal_off_wall_s, median of paired repeats — the WAL
# append CRCs and writes straight from the seal's row buffer with
# group-committed fsync, so the ratio is structural, not host-bound), the
# recovered
# store must land on the full durable frontier, and every audited view
# must be byte-identical to the uncrashed store
WAL_OVERHEAD_GATE = 1.15
# (path-description, getter) pairs of scale-free ratios compared 2x
REGRESSION_FACTOR = 2.0


def _ratio_metrics(report: dict) -> dict[str, float]:
    out = {"mutation_ingest.speedup": report["mutation_ingest"]["speedup"]}
    for churn, entry in report["view_build"].items():
        out[f"view_build.{churn}.speedup"] = entry["speedup"]
    for ns, entry in report["sharded_ingest"]["shards"].items():
        # the SERIAL wall ratio: stable across runners, unlike the
        # thread-scaling ratio, which the absolute core-aware gate covers
        out[f"sharded_ingest.shards.{ns}.speedup_vs_single"] = \
            entry["speedup_vs_single"]
    # iteration counts are deterministic and scale-free; raw query
    # latencies are machine-bound, so only the warm-start ratio is gated
    out["serve_graph.warm_start_iter_reduction"] = \
        report["serve_graph"]["warm_start_iter_reduction"]
    out["resharding.adaptive_vs_static_speedup"] = \
        report["resharding"]["adaptive_vs_static_speedup"]
    # the round-trip median improvement, not the QPS ratio: the QPS
    # ratio is core-count-bound (the absolute core-aware gate covers it)
    # while the convoy effect in the median holds on any host
    out["serve_rpc.p50_improvement"] = \
        report["serve_rpc"]["p50_improvement"]
    # the cheap-lane tail ratio: the convoy dodge is structural (see the
    # absolute gate), so a collapse here means the lanes or the cache
    # silently stopped doing their job, not a slower host
    out["serve_fastpath.cheap_p99_improvement"] = \
        report["serve_fastpath"]["cheap_p99_improvement"]
    return out


def check(fresh: dict, baseline: dict | None) -> list[str]:
    errors = []
    for section, metrics in REQUIRED.items():
        if section not in fresh:
            errors.append(f"missing section {section!r}")
            continue
        for m in metrics:
            if m not in fresh[section]:
                errors.append(f"missing metric {section}.{m}")
    if not fresh.get("view_build"):
        errors.append("view_build has no churn entries")
    # the re-sharding claim is absolute, not baseline-relative: on the
    # skewed stream the planner must fire and adaptive routing must beat
    # static dst-hash outright
    resh = fresh.get("resharding", {})
    if resh:
        if not resh.get("splits"):
            errors.append("resharding: no splits fired on the skewed stream")
        speedup = resh.get("adaptive_vs_static_speedup")
        if speedup is not None and speedup <= 1.0:
            errors.append(
                "resharding: adaptive routing does not beat static "
                f"dst-hash (speedup {speedup:.2f} <= 1.0)")
    shards = fresh.get("sharded_ingest", {}).get("shards", {})
    for ns in SHARD_COUNTS:
        if ns not in shards:
            errors.append(f"missing sharded_ingest.shards[{ns!r}]")
            continue
        for m in SHARD_METRICS:
            if m not in shards[ns]:
                errors.append(f"missing sharded_ingest.shards.{ns}.{m}")
    if "4" in shards and all(m in shards["4"] for m in SHARD_METRICS):
        # the measured-parallel claim, gated by the cores the producing
        # runner actually had (threads cannot beat the GIL-released share
        # of the apply plane on fewer cores than shards)
        cpus = fresh["sharded_ingest"].get("cpu_count") or 0
        got = shards["4"]["parallel_speedup_vs_single"]
        if cpus >= PARALLEL_GATE_CPUS:
            if got <= PARALLEL_GATE:
                errors.append(
                    "sharded_ingest: measured 4-shard parallel ingest "
                    f"does not beat the single store >{PARALLEL_GATE}x "
                    f"(x{got:.2f} on {cpus} CPUs)")
        else:
            # threads cannot overlap on cores that are not there (and a
            # 2-core shared host thrashes instead) — informational only
            print(f"note: runner has {cpus} CPUs (<{PARALLEL_GATE_CPUS}); "
                  f"parallel gate skipped (measured x{got:.2f} vs single, "
                  f"parallel {shards['4']['parallel_wall_s']:.3f}s vs "
                  f"serial {shards['4']['wall_s']:.3f}s)")
    # the serving-tier claim is absolute too: epoch-pipelined reads must
    # beat the serialized single-lock discipline outright under the same
    # concurrent-client + heavy-ingest load, without wrecking the tail,
    # and every served answer must have matched the replay oracle
    srv = fresh.get("serve_rpc", {})
    if srv:
        speedup = srv.get("pipelined_vs_single_lock_speedup")
        if speedup is not None and speedup <= RPC_PIPELINE_GATE:
            errors.append(
                "serve_rpc: pipelined reads do not beat the single-lock "
                f"baseline >{RPC_PIPELINE_GATE}x QPS (x{speedup:.2f} with "
                f"{srv.get('n_clients')} clients)")
        p50_imp = srv.get("p50_improvement")
        if p50_imp is not None and p50_imp <= RPC_P50_GATE:
            errors.append(
                "serve_rpc: pipelining does not beat the single-lock "
                f"median round trip >{RPC_P50_GATE}x "
                f"(improvement x{p50_imp:.2f})")
        p99_imp = srv.get("p99_improvement")
        if p99_imp is not None and p99_imp < RPC_P99_FLOOR:
            errors.append(
                "serve_rpc: pipelining regressed p99 beyond "
                f"{1 / RPC_P99_FLOOR:.1f}x the single-lock tail "
                f"(improvement x{p99_imp:.2f})")
        n_clients = srv.get("n_clients", 0)
        if n_clients < RPC_MIN_CLIENTS:
            errors.append(
                f"serve_rpc: measured with {n_clients} concurrent clients "
                f"(>= {RPC_MIN_CLIENTS} required)")
        if srv.get("oracle_mismatches", 0) != 0:
            errors.append(
                f"serve_rpc: {srv['oracle_mismatches']} served answers "
                "diverged from the replay oracle")
        if not srv.get("answers_audited"):
            errors.append("serve_rpc: replay oracle audited no answers")
    # the replica plane's locality claim, absolute: replica-first routing
    # must shrink both per-window shard fan-out and the p99 round trip on
    # the zipf-hot stream, and every answer must have matched the oracle
    # (mirrors may never be visible in answers — I10)
    rl = fresh.get("replica_locality", {})
    if rl:
        fr = rl.get("fanout_reduction")
        if fr is not None and fr < REPLICA_FANOUT_GATE:
            errors.append(
                "replica_locality: routed windows touch only "
                f"x{fr:.2f} fewer shards than global-view execution "
                f"(>= {REPLICA_FANOUT_GATE}x required at "
                f"{rl.get('n_shards')} shards)")
        p99_imp = rl.get("p99_improvement")
        if p99_imp is not None and p99_imp <= REPLICA_P99_GATE:
            errors.append(
                "replica_locality: replica-first routing does not beat "
                f"the no-replica p99 >{REPLICA_P99_GATE}x "
                f"(improvement x{p99_imp:.2f})")
        if not rl.get("routed_windows"):
            errors.append(
                "replica_locality: no windows were replica-routed "
                "(mirror nomination never fired)")
        if rl.get("oracle_mismatches", 0) != 0:
            errors.append(
                f"replica_locality: {rl['oracle_mismatches']} answers "
                "diverged from the replay oracle")
        if not rl.get("answers_audited"):
            errors.append("replica_locality: replay oracle audited "
                          "no answers")
    # the fast-path claim, absolute: the two-lane + result-cache +
    # prewarm discipline must dodge the expensive-query convoy the
    # single-queue baseline pays, with real cache hits and a clean audit
    fp = fresh.get("serve_fastpath", {})
    if fp:
        p99_imp = fp.get("cheap_p99_improvement")
        if p99_imp is not None and p99_imp < FASTPATH_P99_GATE:
            errors.append(
                "serve_fastpath: cheap-lane p99 improves only "
                f"x{p99_imp:.2f} over the single-queue baseline "
                f"(>= {FASTPATH_P99_GATE}x required)")
        if not fp.get("cache_hits"):
            errors.append(
                "serve_fastpath: the versioned result cache served no "
                "hits on the zipf-hot workload")
        n_clients = fp.get("n_clients", 0)
        if n_clients < RPC_MIN_CLIENTS:
            errors.append(
                f"serve_fastpath: measured with {n_clients} concurrent "
                f"clients (>= {RPC_MIN_CLIENTS} required)")
        if fp.get("oracle_mismatches", 0) != 0:
            errors.append(
                f"serve_fastpath: {fp['oracle_mismatches']} served "
                "answers diverged from the replay oracle")
        if not fp.get("answers_audited"):
            errors.append("serve_fastpath: replay oracle audited "
                          "no answers")
    # the durability claims, absolute: the WAL must be cheap under the
    # default batched fsync, recovery complete, and the audit clean
    rv = fresh.get("recovery", {})
    if rv:
        overhead = rv.get("wal_overhead")
        if overhead is not None and overhead > WAL_OVERHEAD_GATE:
            errors.append(
                "recovery: WAL-on ingest costs "
                f"x{overhead:.3f} of WAL-off "
                f"(<= {WAL_OVERHEAD_GATE}x required with batched fsync)")
        frontier = rv.get("durable_frontier")
        want = rv.get("epochs", 0) - 1
        if frontier is not None and frontier != want:
            errors.append(
                f"recovery: recovered frontier {frontier} != sealed "
                f"frontier {want} (nothing was crashed mid-epoch here — "
                "recovery must land on the full log)")
        if rv.get("recovered_mismatches", 0) != 0:
            errors.append(
                f"recovery: {rv['recovered_mismatches']} recovered views "
                "diverged from the uncrashed store")
        if not rv.get("views_audited"):
            errors.append("recovery: equivalence audit compared no views")
    if "1" in shards and "speedup_vs_single" in shards.get("1", {}):
        ratio = shards["1"]["speedup_vs_single"]
        if ratio < 0.9:
            errors.append(
                "sharded_ingest: 1-shard passthrough runs at "
                f"{ratio:.2f}x of the single store (>= 0.9x required)")
    if errors or baseline is None:
        return errors
    try:
        base_ratios = _ratio_metrics(baseline)
    except KeyError as exc:   # old-format baseline: keys-only check
        print(f"note: baseline lacks {exc}; skipping regression check")
        return errors
    try:
        fresh_ratios = _ratio_metrics(fresh)
    except KeyError as exc:   # e.g. a partially-written report
        return errors + [f"fresh report lacks ratio metric {exc}"]
    for name, base in base_ratios.items():
        got = fresh_ratios.get(name)
        if got is None:
            errors.append(f"ratio {name} missing from fresh report")
        elif got < base / REGRESSION_FACTOR:
            errors.append(
                f"{name} regressed >{REGRESSION_FACTOR}x: "
                f"{got:.2f} vs baseline {base:.2f}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True, type=pathlib.Path)
    ap.add_argument("--baseline", type=pathlib.Path, default=None,
                    help="committed BENCH_ingest.json to diff ratios against"
                         " (omit for a keys-only check)")
    args = ap.parse_args()
    if not args.fresh.exists():
        print(f"FAIL: {args.fresh} was not produced")
        return 1
    fresh = json.loads(args.fresh.read_text())
    baseline = (json.loads(args.baseline.read_text())
                if args.baseline and args.baseline.exists() else None)
    errors = check(fresh, baseline)
    for e in errors:
        print(f"FAIL: {e}")
    if not errors:
        try:
            ratios = ", ".join(f"{k}={v:.2f}"
                               for k, v in _ratio_metrics(fresh).items())
        except KeyError:
            ratios = "(not all ratio metrics present)"
        print(f"OK: all required metrics present; ratios: {ratios}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
