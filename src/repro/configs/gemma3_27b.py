"""Gemma3-27B [hf:google/gemma-3 family]: 62L, d_model=5376, 32 heads GQA
kv=16, head_dim=128, d_ff=21504 (geglu), vocab 262144, 5:1 local:global
(window 1024), qk-norm, sandwich norms, 128k context. Mostly-local attention
=> runs long_500k (global layers linear-cost at decode)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    pattern=("local", "local", "local", "local", "local", "global"),
    ffn="geglu",
    norm="rms",
    rope=True,
    rope_theta=1_000_000.0,
    local_window=1024,
    qk_norm=True,
    sandwich_norm=True,
    scale_embeddings=True,
    subquadratic=True,   # 5:1 local:global; global layers linear at decode
))
