"""RL001 clean twin: every guarded access holds the inferred lock."""
import threading


class WindowQueue:
    def __init__(self):
        self._lock = threading.Lock()
        self.pending = []
        self.count = 0

    def add(self, item):
        with self._lock:
            self.pending.append(item)
            self.count += 1

    def drain(self):
        with self._lock:
            items, self.pending = self.pending, []
        return items

    def size(self):
        with self._lock:
            return self.count
