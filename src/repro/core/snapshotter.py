"""Asynchronous global-snapshot progress tracking — paper §2.3.1 (Fig 4).

Kineograph uses a *central* snapshoter: all mutations of epoch e+1 wait until
the global snapshot of epoch e is sealed. The paper's improvement (which we
implement) is *no-wait dispatch*: the ingest node only checks that the target
data node's **local** snapshot frontier covers the previous epochs; mutations
from different epochs dispatch concurrently. The global snapshot frontier is
the min over local frontiers and advances in the background (in the real
system via a Paxos quorum; here a deterministic state machine with the same
external guarantees — see DESIGN.md §2 'Paxos').

Invariants (property-tested):
  * the global frontier is monotone non-decreasing,
  * a computation scheduled on snapshot v only launches once global >= v,
  * dispatch never blocks on the *global* frontier (only on the target
    node's local frontier).

This is layer 2 of the pipeline mapped in ``docs/ARCHITECTURE.md``
(ingest -> seal -> view -> query); ``graph/sharded.py`` stacks the
sharded graph store on these primitives via the ``on_seal`` hook.

Thread-safety: none of these classes lock internally — the serving layer
serializes every touch (see ``launch/serve_graph.py``); the benchmark and
test drivers are single-threaded. The sharded store's parallel apply
plane (``ShardedDynamicGraph.seal_epoch`` with ``parallel_apply > 1``)
may run ``DataNode.seal_epoch`` for *different* nodes concurrently: a
node's pending maps, frontier, and ``on_seal`` state are touched only by
the one thread sealing that node, while ingest-side state (``IngestNode``
queues, the coordinator) stays on the calling thread between rounds.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable

import numpy as np


@dataclasses.dataclass
class Mutation:
    key: int          # routing key (e.g. destination vertex id)
    epoch: int
    payload: object = None


class DataNode:
    """Holds a shard of the data; seals local snapshots per epoch.

    ``on_seal(epoch, payloads)`` (optional) is the hook that turns the node
    from a progress tracker into a real store: it fires inside
    :meth:`seal_epoch` with the payload arrays received for that epoch, in
    arrival order — the sharded graph store applies its slice of each
    mutation batch there, so the local snapshot and the shard's state seal
    atomically.
    """

    def __init__(self, node_id: int,
                 on_seal: Callable[[int, list], None] | None = None):
        self.node_id = node_id
        self.on_seal = on_seal
        self.pending: dict[int, list[Mutation]] = defaultdict(list)
        self.pending_batches: dict[int, list[np.ndarray]] = defaultdict(list)
        self.pending_payloads: dict[int, list] = defaultdict(list)
        self.local_frontier = -1          # highest epoch locally sealed
        self.applied: list[Mutation] = []
        # batched ingress is counted, not retained: the payloads were
        # handed to on_seal and the keys would otherwise pin O(stream)
        # memory per node
        self.applied_batch_count = 0

    def receive(self, mut: Mutation) -> None:
        """Scalar ingress: queue one mutation for its epoch's seal."""
        self.pending[mut.epoch].append(mut)

    def receive_batch(self, epoch: int, keys: np.ndarray,
                      payload=None) -> None:
        """Vectorized ingress: a whole key array for one epoch at once.
        ``payload`` is an optional object riding along with the keys —
        usually an array-like with the same leading dimension, but opaque
        to this layer (the sharded store's single-shard passthrough sends
        whole ``MutationBatch`` objects) — handed to ``on_seal`` when the
        epoch seals."""
        self.pending_batches[epoch].append(np.asarray(keys))
        if payload is not None:
            self.pending_payloads[epoch].append(payload)

    def seal_epoch(self, epoch: int) -> None:
        """Define the local snapshot for `epoch` (applies its mutations).

        ``on_seal`` runs first and the seal only commits (pending drained,
        frontier advanced) if it returns: a failing hook — e.g. a shard
        hitting capacity — leaves the epoch pending and re-sealable instead
        of silently destroying its mutations.

        Raises:
            ValueError: ``epoch`` is not ``local_frontier + 1`` (local
                snapshots seal strictly in order).
        """
        if epoch != self.local_frontier + 1:
            raise ValueError(
                f"node {self.node_id}: seal {epoch} out of order "
                f"(local frontier {self.local_frontier})")
        if self.on_seal is not None:
            self.on_seal(epoch, self.pending_payloads.get(epoch, []))
        self.applied.extend(self.pending.pop(epoch, []))
        self.applied_batch_count += sum(
            len(a) for a in self.pending_batches.pop(epoch, []))
        self.pending_payloads.pop(epoch, None)
        self.local_frontier = epoch

    @property
    def applied_count(self) -> int:
        return len(self.applied) + self.applied_batch_count


class SnapshotCoordinator:
    """Tracks the global frontier = min(local frontiers); runs callbacks of
    computations whose snapshot dependency becomes available."""

    def __init__(self, nodes: list[DataNode]):
        self.nodes = nodes
        self._global = -1
        self._waiting: list[tuple[int, Callable[[], None]]] = []
        self._history: list[int] = []
        self._subscribers: list[Callable[[int], None]] = []

    def subscribe(self, fn: Callable[[int], None]) -> None:
        """Register a seal-notification callback: ``fn(new_frontier)`` fires
        from :meth:`advance` every time the global frontier actually moves
        (an epoch became globally sealed). Unlike
        :meth:`schedule_on_snapshot` — one-shot, per-epoch — a subscriber is
        permanent: the online serving layer uses it to learn that a newer
        consistent snapshot exists without polling."""
        self._subscribers.append(fn)

    @property
    def global_frontier(self) -> int:
        """Highest epoch sealed on EVERY node (-1 before the first)."""
        return self._global

    def advance(self) -> int:
        """Recompute the global frontier (min over local frontiers), run
        any newly-eligible scheduled computations, and notify subscribers
        if it moved. Returns the (possibly unchanged) frontier. Raises
        ``AssertionError`` if the frontier would regress — impossible
        unless a node's local frontier was rolled back externally."""
        new = min(n.local_frontier for n in self.nodes)
        if new < self._global:
            raise AssertionError("global snapshot frontier went backwards")
        moved = new > self._global
        self._global = new
        self._history.append(new)
        still = []
        for epoch, cb in self._waiting:
            if epoch <= self._global:
                cb()
            else:
                still.append((epoch, cb))
        self._waiting = still
        if moved:
            for fn in self._subscribers:
                fn(self._global)
        return self._global

    def schedule_on_snapshot(self, epoch: int, fn: Callable[[], None]):
        """Paper: 'the computing is launched until all the global snapshots
        it will process become available'."""
        if epoch <= self._global:
            fn()
        else:
            self._waiting.append((epoch, fn))


class IngestNode:
    """Dispatches mutations asynchronously (paper's no-wait rule).

    ``route`` maps a routing key to a node index; the sharded store swaps
    it at a re-sharding cutover (``RoutingPlan.assign`` of the successor
    plan), which is safe because cutover requires quiescence — nothing
    in-flight is ever re-routed. Ineligible mutations park in ``blocked``
    / ``blocked_batches`` until :meth:`retry_blocked` /
    :meth:`retry_blocked_batches` re-dispatches them.
    """

    def __init__(self, nodes: list[DataNode], route: Callable[[int], int]):
        self.nodes = nodes
        self.route = route
        self.blocked: list[Mutation] = []
        self.blocked_batches: list[tuple[int, np.ndarray, object]] = []
        self.dispatched = 0

    def dispatch(self, mut: Mutation) -> bool:
        """Dispatch if the target node's LOCAL snapshot of all previous
        epochs is defined; never consults the global frontier."""
        node = self.nodes[self.route(mut.key)]
        if node.local_frontier >= mut.epoch - 1:
            node.receive(mut)
            self.dispatched += 1
            return True
        self.blocked.append(mut)
        return False

    def retry_blocked(self) -> int:
        """Re-dispatch every parked scalar mutation; returns how many
        landed (the rest park again)."""
        muts, self.blocked = self.blocked, []
        return sum(self.dispatch(m) for m in muts)

    def dispatch_batch(self, keys: np.ndarray, epochs: np.ndarray,
                       payload=None, *,
                       node_ids: np.ndarray | None = None) -> int:
        """Vectorized no-wait dispatch: route a whole mutation array at once.

        Applies the same per-mutation rule as :meth:`dispatch` (target
        node's LOCAL frontier must cover prior epochs), but routing,
        eligibility, and (node, epoch) grouping are NumPy ops — one Python
        step per distinct (node, epoch) group instead of per mutation.
        Ineligible mutations are parked in ``blocked_batches``. Returns the
        number dispatched now.

        ``payload`` optionally carries per-mutation data (any array-like
        supporting fancy row indexing, same leading dimension as ``keys``);
        each (node, epoch) group's payload slice is delivered with its keys
        and surfaced to the node's ``on_seal`` hook at seal time. Grouping
        is stable, so a group's payload rows keep their original order.

        ``node_ids`` overrides ``route`` with an explicit per-mutation
        target array (same shape as ``keys``). The re-sharding migration
        uses this: its delete half must land on the *source* shard even
        though the migrating keys already route to the target under the
        newly-activated plan. Eligibility, parking, and seal semantics are
        unchanged — an overridden mutation is still an ordinary payload.
        Parked slices are re-dispatched through ``route``, so overrides
        require eligible targets (the migration's quiescence precondition
        guarantees this).
        """
        keys = np.asarray(keys)
        epochs = np.asarray(epochs)
        if keys.size == 0:
            return 0
        if node_ids is not None:
            node_ids = np.asarray(node_ids)
            if node_ids.shape != keys.shape:
                raise ValueError("node_ids must match keys elementwise")
        else:
            try:
                node_ids = np.asarray(self.route(keys))
                if node_ids.shape != keys.shape:
                    raise TypeError
            except Exception:  # route not vectorizable — apply elementwise
                node_ids = np.asarray([self.route(int(k)) for k in keys],
                                      np.int64)
        frontiers = np.asarray([n.local_frontier for n in self.nodes])
        ok = frontiers[node_ids] >= epochs - 1
        # steady-state fast path: one epoch, every node caught up — group
        # by node with a single stable sort, then reorder keys/payload
        # ONCE and hand each node a contiguous (zero-copy) slice instead
        # of a fancy-indexed gather per group
        if ok.all() and (epochs == epochs[0]).all():
            epoch = int(epochs[0])
            order = np.argsort(node_ids, kind="stable")
            sorted_nodes = node_ids[order]
            keys_s = keys[order]
            payload_s = payload[order] if payload is not None else None
            starts = np.flatnonzero(
                np.r_[True, sorted_nodes[1:] != sorted_nodes[:-1]])
            bounds = np.r_[starts, len(order)]
            for a, b in zip(bounds[:-1], bounds[1:], strict=True):
                self.nodes[int(sorted_nodes[a])].receive_batch(
                    epoch, keys_s[a:b],
                    payload_s[a:b] if payload_s is not None else None)
            self.dispatched += len(keys)
            return len(keys)
        for eligible, sink in ((ok, True), (~ok, False)):
            idx = np.flatnonzero(eligible)
            if not idx.size:
                continue
            order = idx[np.lexsort((epochs[idx], node_ids[idx]))]
            group = node_ids[order].astype(np.int64) << 32 | epochs[order]
            starts = np.flatnonzero(np.r_[True, group[1:] != group[:-1]])
            bounds = np.r_[starts, len(order)]
            for a, b in zip(bounds[:-1], bounds[1:], strict=True):
                rows = order[a:b]
                epoch = int(epochs[rows[0]])
                rows_payload = payload[rows] if payload is not None else None
                if sink:
                    self.nodes[int(node_ids[rows[0]])].receive_batch(
                        epoch, keys[rows], rows_payload)
                else:
                    self.blocked_batches.append(
                        (epoch, keys[rows], rows_payload))
        n_ok = int(ok.sum())
        self.dispatched += n_ok
        return n_ok

    def retry_blocked_batches(self) -> int:
        """Re-dispatch every parked batch slice (through ``route``);
        returns how many mutations landed (the rest park again)."""
        batches, self.blocked_batches = self.blocked_batches, []
        done = 0
        for epoch, keys, payload in batches:
            done += self.dispatch_batch(keys, np.full(len(keys), epoch),
                                        payload)
        return done
