"""InternVL2-76B [arXiv:2404.16821]: InternLM2-76B language backbone
(80L, d_model=8192, 64 heads GQA kv=8, d_ff=28672, vocab 128256, SwiGLU,
RMSNorm, RoPE). InternViT frontend is a stub; input_specs() supplies
precomputed patch embeddings. Full attention => long_500k skip."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    pattern=("attn",),
    ffn="swiglu",
    norm="rms",
    rope=True,
    rope_theta=1_000_000.0,
    embed_mode="frames",
    subquadratic=False,
))
