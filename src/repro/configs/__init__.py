from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeCell, SHAPES, get_config, all_configs, register, reduced,
    ATTN_KINDS, RECURRENT_KINDS,
)
