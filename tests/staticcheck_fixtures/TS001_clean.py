"""TS001 clean twin: branching on statics, shapes and None only."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("negate",))
def relu_or_neg(x, negate=False):
    if negate:                        # static argument: fine
        return -x
    return jnp.where(x > 0, x, -x)    # traced select: fine


@jax.jit
def normalize(x, scale=None):
    m, _ = x.shape                    # shape access breaks taint
    if m == 0:                        # shape-derived: fine
        return x
    if scale is None:                 # identity test: fine
        return x / jnp.maximum(jnp.abs(x).max(), 1e-30)
    return x * scale
