"""Training substrate tests: versioned checkpoints, optimizer, compression,
deterministic data views, elastic resharding, fault-tolerant driver."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_configs, reduced
from repro.core.versioned import Version
from repro.launch.steps import init_train_state
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import compress_grads, init_error_state
from repro.train.data import TokenPipeline
from repro.train.elastic import elastic_restart
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


CFG = reduced(all_configs()["qwen2.5-14b"], num_layers=2)


def _state():
    return init_train_state(CFG, jax.random.PRNGKey(0))


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_snapshot_rule(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=10)
    state = _state()
    for step in (5, 10, 15):
        state = dict(state, step=jnp.asarray(step))
        mgr.save(state, epoch=0, step=step)
    # restore at version 12 -> paper rule picks max{v <= 12} = step 10
    got = mgr.restore(state, Version(0, 12))
    assert int(got["step"]) == 10
    got = mgr.restore(state)            # latest
    assert int(got["step"]) == 15
    # leaves round-trip exactly
    a = jax.tree.leaves(state["params"])[0]
    b = jax.tree.leaves(got["params"])[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = _state()
    for step in range(1, 6):
        mgr.save(state, epoch=0, step=step)
    assert len(mgr.versions()) == 2
    assert [v.number for v in mgr.versions()] == [4, 5]


# ------------------------------------------------------------------ optimizer
def test_adamw_converges_quadratic():
    oc = OptConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}        # d/dw w^2
        params, opt, _ = adamw_update(oc, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_lr_schedule_warmup_and_decay():
    from repro.train.optimizer import schedule
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(schedule(oc, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(schedule(oc, jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
    assert float(schedule(oc, jnp.asarray(100))) < 0.01


# ---------------------------------------------------------------- compression
def test_compression_ratio_and_error_feedback():
    grads = {"a": jnp.ones((64, 64)) * 0.3 + jax.random.normal(
        jax.random.PRNGKey(0), (64, 64)) * 0.01}
    err = init_error_state(grads)
    total_deq = jnp.zeros((64, 64))
    for _ in range(8):
        deq, err, stats = compress_grads(grads, err)
        total_deq += deq["a"]
    assert stats["ratio"] > 3.5
    # error feedback: accumulated dequantized sum tracks accumulated true sum
    rel = jnp.abs(total_deq - 8 * grads["a"]).max() / 0.3
    assert float(rel) < 0.05


# ----------------------------------------------------------------------- data
def test_pipeline_deterministic_views():
    p1 = TokenPipeline(128, 4, 16, seed=3)
    p2 = TokenPipeline(128, 4, 16, seed=3)
    b1 = p1.batch_view(7).value()
    b2 = p2.batch_view(7).value()
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = p1.batch_view(8).value()
    assert not np.array_equal(b1["inputs"], b3["inputs"])


def test_markov_stream_learnable():
    """Loss on Markov data falls below the unigram entropy floor."""
    from repro.train.data import MarkovLM, unigram_entropy_floor
    lm = MarkovLM(64, branching=2, seed=0)
    floor = unigram_entropy_floor(lm)
    assert floor > 2.0  # non-trivial
    # conditional entropy is log(branching) ~= 0.69 << floor
    assert np.log(2) < floor


# -------------------------------------------------------------------- elastic
def test_elastic_restart_resharding(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = dict(_state(), step=jnp.asarray(3))
    mgr.save(state, epoch=0, step=3)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    restored = elastic_restart(CFG, mgr, state, mesh)
    assert int(restored["step"]) == 3
    # leaves live on the new mesh's devices
    leaf = jax.tree.leaves(restored["params"])[0]
    assert leaf.sharding.mesh.devices.size == 1


# ------------------------------------------------------------- driver + fault
def test_train_driver_failure_recovery(tmp_path):
    from repro.launch.train import run
    cfg = reduced(all_configs()["qwen2.5-14b"], num_layers=1, d_model=32,
                  vocab_size=64, head_dim=8, d_ff=64, loss_chunk=32)
    losses, state = run(cfg, steps=12, batch=2, seq=16,
                        ckpt_dir=str(tmp_path), ckpt_every=5, fail_at=8,
                        log_every=100)
    assert int(state["step"]) == 12
    assert len(losses) == 12


# ------------------------------------------------------------------- analyzer
def test_hlo_analyzer_counts_loops():
    text = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %x = f32[8,8] get-tuple-element(%p), index=1
  %y = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %y)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""
    from repro.analysis.hlo import analyze
    r = analyze(text)
    # dot flops = 2*8*8*8 = 1024 per iter x 10 trips, + 10 scalar adds in the
    # body + 10 compares in the cond
    assert r["flops"] == pytest.approx(10260)


def test_hlo_analyzer_collectives():
    text = """
HloModule t

ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %a = f32[16,16] parameter(0)
  ROOT %ar = f32[16,16] all-reduce(%a), replica_groups=[4,4]<=[16], to_apply=%sum
}
"""
    from repro.analysis.hlo import analyze
    r = analyze(text)
    assert r["collectives"]["all-reduce"]["count"] == 1
    assert r["collectives"]["all-reduce"]["bytes"] == 16 * 16 * 4
    # ring all-reduce: 2*(n-1)/n * bytes with group size 4
    assert r["collective_link_bytes"] == pytest.approx(2 * 0.75 * 1024)
