"""Versioned dynamic graph store — the JAX data plane of the paper's data
model.

JAX needs static shapes, so the graph is a capacity-bounded *multi-version*
edge/vertex store: a mutation never overwrites — an edge add writes a row
stamped ``created=v``; an edge delete stamps ``deleted=v``. A snapshot is a
*mask* (``created <= v < deleted``), which is exactly the paper's Fig 3(b)
multi-version item semantics (every version stays addressable), vectorized.

The per-snapshot CSR ("join view", §2.3.3.2) is built once per queried
version and cached — it is what makes the join-group-by operator a segment
reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.versioned import Version

MAXV = np.iinfo(np.int64).max


@dataclasses.dataclass
class MutationBatch:
    """One epoch's worth of mutations (vectorized)."""
    version: Version
    add_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    add_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    del_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    del_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    add_vertices: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    vertex_types: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))

    @property
    def size(self) -> int:
        return (len(self.add_src) + len(self.del_src) + len(self.add_vertices))


@dataclasses.dataclass
class JoinView:
    """CSR of one snapshot: dst-grouped in-edges (the join view)."""
    version: Version
    n: int
    offsets: jnp.ndarray       # (n+1,)
    src: jnp.ndarray           # (m,) source vertex per in-edge
    dst: jnp.ndarray           # (m,)
    out_degree: jnp.ndarray    # (n,)
    in_degree: jnp.ndarray     # (n,)

    @property
    def m(self) -> int:
        return int(self.src.shape[0])


class DynamicGraph:
    """Capacity-bounded versioned edge store + vertex table."""

    def __init__(self, n_max: int, e_max: int):
        self.n_max = n_max
        self.e_max = e_max
        self.src = np.zeros(e_max, np.int32)
        self.dst = np.zeros(e_max, np.int32)
        self.created = np.full(e_max, MAXV, np.int64)
        self.deleted = np.full(e_max, MAXV, np.int64)
        self.n_edges = 0
        self.v_created = np.full(n_max, MAXV, np.int64)
        self.v_type = np.zeros(n_max, np.int32)
        self.n_vertices = 0
        self.versions: list[Version] = []
        self._views: dict[int, JoinView] = {}

    # -- ingestion ---------------------------------------------------------
    def apply(self, batch: MutationBatch) -> None:
        v = batch.version.pack()
        if self.versions and v <= self.versions[-1].pack():
            raise ValueError("mutation batches must have increasing versions")
        # vertex adds
        for vid, vt in zip(batch.add_vertices, batch.vertex_types):
            if self.v_created[vid] == MAXV:
                self.v_created[vid] = v
                self.v_type[vid] = vt
                self.n_vertices += 1
        # edge adds: append rows
        k = len(batch.add_src)
        if k:
            if self.n_edges + k > self.e_max:
                raise MemoryError("edge capacity exceeded")
            sl = slice(self.n_edges, self.n_edges + k)
            self.src[sl] = batch.add_src
            self.dst[sl] = batch.add_dst
            self.created[sl] = v
            self.deleted[sl] = MAXV
            # auto-create endpoint vertices
            for vid in np.concatenate([batch.add_src, batch.add_dst]):
                if self.v_created[vid] == MAXV:
                    self.v_created[vid] = v
                    self.n_vertices += 1
            self.n_edges += k
        # edge deletes: stamp the *live* row matching (src, dst)
        for s, d in zip(batch.del_src, batch.del_dst):
            live = np.flatnonzero(
                (self.src[:self.n_edges] == s) & (self.dst[:self.n_edges] == d)
                & (self.deleted[:self.n_edges] == MAXV))
            if live.size:
                self.deleted[live[-1]] = v
        self.versions.append(batch.version)

    # -- snapshots -----------------------------------------------------------
    def snapshot_mask(self, version: Version) -> np.ndarray:
        """created <= v < deleted — the paper's snapshot rule on edges."""
        v = version.pack()
        e = self.n_edges
        return (self.created[:e] <= v) & (v < self.deleted[:e])

    def num_vertices(self, version: Optional[Version] = None) -> int:
        if version is None:
            return self.n_vertices
        return int((self.v_created <= version.pack()).sum())

    def join_view(self, version: Version) -> JoinView:
        """Build (and cache) the dst-grouped CSR for a snapshot."""
        key = version.pack()
        if key in self._views:
            return self._views[key]
        mask = self.snapshot_mask(version)
        src = self.src[:self.n_edges][mask]
        dst = self.dst[:self.n_edges][mask]
        n = self.n_max
        order = np.argsort(dst, kind="stable")
        src_s, dst_s = src[order], dst[order]
        counts = np.bincount(dst_s, minlength=n)
        offsets = np.zeros(n + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        out_deg = np.bincount(src, minlength=n)
        view = JoinView(version, n, jnp.asarray(offsets),
                        jnp.asarray(src_s), jnp.asarray(dst_s),
                        jnp.asarray(out_deg.astype(np.float32)),
                        jnp.asarray(counts.astype(np.float32)))
        self._views[key] = view
        return view

    def gc_views(self, keep_latest: int = 4) -> int:
        """Collect obsolete join views (paper §2.2 obsolete-replica GC)."""
        if len(self._views) <= keep_latest:
            return 0
        keys = sorted(self._views)
        drop = keys[:-keep_latest]
        for k in drop:
            del self._views[k]
        return len(drop)


# ----------------------------------------------------------- synthetic data
def synthesize_stream(n_vertices: int, n_epochs: int, adds_per_epoch: int,
                      *, seed: int = 0, delete_frac: float = 0.05,
                      n_types: int = 3) -> tuple[DynamicGraph, list[MutationBatch]]:
    """Preferential-attachment mutation stream (citation-graph-like: papers
    cite earlier papers; new vertex types appear in later epochs — the
    paper's Fig 1 evolution)."""
    rng = np.random.default_rng(seed)
    e_max = n_epochs * adds_per_epoch * 2 + 16
    g = DynamicGraph(n_vertices, e_max)
    batches = []
    deg = np.ones(n_vertices, np.float64)
    grown = 8
    live: list[tuple[int, int]] = []
    for epoch in range(n_epochs):
        grown = min(n_vertices, grown + max(1, n_vertices // (n_epochs + 1)))
        p = deg[:grown] / deg[:grown].sum()
        dsts = rng.choice(grown, size=adds_per_epoch, p=p).astype(np.int32)
        srcs = rng.integers(0, grown, size=adds_per_epoch).astype(np.int32)
        keep = srcs != dsts
        srcs, dsts = srcs[keep], dsts[keep]
        deg_update = np.bincount(dsts, minlength=n_vertices)
        deg += deg_update
        n_del = int(len(live) * delete_frac)
        if n_del:
            idx = rng.choice(len(live), size=n_del, replace=False)
            dels = [live[i] for i in idx]
            live = [e for i, e in enumerate(live) if i not in set(idx)]
            del_src = np.array([d[0] for d in dels], np.int32)
            del_dst = np.array([d[1] for d in dels], np.int32)
        else:
            del_src = del_dst = np.zeros(0, np.int32)
        live.extend(zip(srcs.tolist(), dsts.tolist()))
        # vertex type evolution: later epochs introduce new types
        vtypes = np.minimum(epoch * n_types // max(n_epochs, 1), n_types - 1)
        batch = MutationBatch(
            version=Version(epoch, 0),
            add_src=srcs, add_dst=dsts,
            del_src=del_src, del_dst=del_dst,
            add_vertices=np.zeros(0, np.int32),
            vertex_types=np.full(0, vtypes, np.int32))
        g.apply(batch)
        batches.append(batch)
    return g, batches
