"""Lamport logical clocks (Leslie1978) — §2.3.3.2 event delivery.

Guarantee: if e1 → e2 (application-defined causal order) then T(e1) < T(e2).
Property-tested in tests/test_core_properties.py.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Optional


@dataclasses.dataclass(frozen=True, order=True)
class Stamp:
    """(time, node_id) — node_id breaks ties so stamps are a total order."""
    time: int
    node_id: int


class LamportClock:
    def __init__(self, node_id: int):
        self.node_id = node_id
        self._time = 0

    def tick(self) -> Stamp:
        """Local event."""
        self._time += 1
        return Stamp(self._time, self.node_id)

    def send(self) -> Stamp:
        """Stamp an outgoing message."""
        return self.tick()

    def receive(self, msg_stamp: Stamp) -> Stamp:
        """Merge an incoming stamp; the receive event is after the send."""
        self._time = max(self._time, msg_stamp.time) + 1
        return Stamp(self._time, self.node_id)


@dataclasses.dataclass(frozen=True)
class Event:
    stamp: Stamp
    kind: str
    payload: Any = None


class EventLog:
    """Collects events from many vertices and delivers them to observers in
    stamp order while preserving any registered causal `->` relation.

    Each program model registers its own ``happens_before(e1, e2)`` check
    (paper: "each program model ... needs to register its own function to
    check the causal-effect relation").
    """

    def __init__(self):
        self._events: list[Event] = []
        self._observers: dict[str, list[Callable[[Event], None]]] = {}
        self._relations: list[Callable[[Event, Event], Optional[bool]]] = []

    def register_relation(self, fn: Callable[[Event, Event], Optional[bool]]):
        self._relations.append(fn)

    def observe(self, kind: str, fn: Callable[[Event], None]):
        self._observers.setdefault(kind, []).append(fn)

    def record(self, event: Event):
        self._events.append(event)

    def happens_before(self, e1: Event, e2: Event) -> bool:
        for rel in self._relations:
            r = rel(e1, e2)
            if r is not None:
                return r
        return False

    def deliver(self) -> list[Event]:
        """Deliver all recorded events in total (stamp) order. Because every
        vertex stamps with a Lamport clock, stamp order extends every causal
        order: e1 -> e2 implies T(e1) < T(e2) implies delivery order."""
        order = sorted(self._events, key=lambda e: e.stamp)
        for ev in order:
            for fn in self._observers.get(ev.kind, ()):
                fn(ev)
        delivered, self._events = order, []
        return delivered

    def check_causal_consistency(self, delivered: list[Event]) -> bool:
        """Validate the delivery respected every registered -> relation."""
        for i, j in itertools.combinations(range(len(delivered)), 2):
            if self.happens_before(delivered[j], delivered[i]):
                return False
        return True
