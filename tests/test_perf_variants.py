"""Correctness of the §Perf (beyond-paper) variants against their
paper-faithful baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_configs, reduced
from repro.launch.steps import init_train_state, make_train_step
from repro.nn import layers
from repro.nn.moe import init_moe, moe_dense, moe_dropping
from repro.nn.recurrent import init_mlstm_block, init_slstm_block, \
    mlstm_forward, slstm_forward


def test_chunkwise_mlstm_equals_sequential():
    cfg = reduced(all_configs()["xlstm-1.3b"])
    p = init_mlstm_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.float32)
    y_seq, st_seq = mlstm_forward(p, x, cfg, return_state=True)
    cfg_c = dataclasses.replace(cfg, mlstm_impl="chunkwise", mlstm_chunk=16)
    y_chk, st_chk = mlstm_forward(p, x, cfg_c, return_state=True)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chk),
                               atol=1e-5, rtol=1e-4)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(st_seq[k]), np.asarray(st_chk[k]),
                                   atol=1e-5, rtol=1e-3)


def test_chunked_slstm_equals_plain():
    cfg = reduced(all_configs()["xlstm-1.3b"])
    p = init_slstm_block(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model),
                          jnp.float32)
    y0 = slstm_forward(p, x, cfg)
    y1 = slstm_forward(p, x, dataclasses.replace(cfg, mlstm_chunk=16))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=1e-5, rtol=1e-4)


def test_moe_dropping_close_to_dense_at_high_capacity():
    """With capacity >= T the dropping impl loses no tokens -> equals dense."""
    cfg = dataclasses.replace(reduced(all_configs()["mixtral-8x22b"]),
                              capacity_factor=8.0)  # C == T (no drops)
    p = init_moe(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, cfg.d_model),
                          jnp.float32)
    yd, _ = moe_dense(p, x, cfg)
    yq, _ = moe_dropping(p, x, cfg)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yq),
                               atol=1e-4, rtol=1e-3)


def test_moe_grouped_dispatch_matches_global():
    cfg = dataclasses.replace(reduced(all_configs()["mixtral-8x22b"]),
                              capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(6), cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 16, cfg.d_model),
                          jnp.float32)
    y1, _ = moe_dropping(p, x, dataclasses.replace(cfg, moe_groups=0))
    y4, _ = moe_dropping(p, x, dataclasses.replace(cfg, moe_groups=4))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               atol=1e-4, rtol=1e-3)


def test_bf16_backward_scope_grads_close():
    """custom-VJP bf16-backward dense: grads close to the f32 path."""
    x = jax.random.normal(jax.random.PRNGKey(8), (8, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(9), (16, 4), jnp.float32) * 0.1

    def loss(x, w):
        return (layers.dense(x, w) ** 2).sum()

    g0 = jax.grad(loss, argnums=1)(x, w)
    with layers.bf16_backward_scope(True):
        g1 = jax.grad(loss, argnums=1)(x, w)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1),
                               atol=0.1, rtol=0.05)


def test_microbatched_train_step_matches_plain():
    cfg = reduced(all_configs()["qwen2.5-14b"], num_layers=2)
    cfg_mb = dataclasses.replace(cfg, microbatches=2)
    state = init_train_state(cfg, jax.random.PRNGKey(10))
    from repro.train.data import TokenPipeline
    batch = TokenPipeline(cfg.vocab_size, 4, 16, seed=1).batch_view(0).value()
    s1, m1 = jax.jit(make_train_step(cfg))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg_mb))(state, batch)
    # same data, same init: losses agree; params close (grad averaging only
    # reorders float sums)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
    p1 = jax.tree.leaves(s1["params"])[0]
    p2 = jax.tree.leaves(s2["params"])[0]
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               atol=5e-3, rtol=1e-2)


def test_smoke_xlstm_chunkwise_train_step():
    """End-to-end train step through the optimized xlstm path."""
    cfg = reduced(all_configs()["xlstm-1.3b"],
                  mlstm_impl="chunkwise", mlstm_chunk=16)
    state = init_train_state(cfg, jax.random.PRNGKey(11))
    from repro.train.data import TokenPipeline
    batch = TokenPipeline(cfg.vocab_size, 2, 32, seed=2).batch_view(0).value()
    state, metrics = jax.jit(make_train_step(cfg))(state, batch)
    assert jnp.isfinite(metrics["loss"])
