"""Online graph-query layer: typed queries, window batching, per-snapshot
result caching.

The paper's online half answers low-latency queries against the newest
*consistent* snapshot while mutations stream. This module is the snapshot-
local piece: a :class:`SnapshotQueryEngine` takes a window of typed queries
(:class:`KHop`, :class:`Reachability`, :class:`DegreeTopK`,
:class:`PageRankQuery`) and answers the whole window with as few vectorized
calls as possible —

* all k-hop queries with the same ``k`` become ONE ``batched_k_hop`` sweep,
* all reachability queries become ONE multi-source ``batched_reachability``
  frontier,
* degree top-k queries group by (k, direction),
* PageRank is computed at most once per snapshot version: results are
  cached per packed version and **warm-started** from the nearest older
  cached ranks via ``incremental_pagerank`` (the paper's "adapt to the
  changes first" rule), so an epoch's ranks converge in a fraction of the
  cold-start iterations. The cache is GC'd with the same version-spaced
  ``ladder_keep`` retention the view caches use, so serving memory stays
  bounded under churn.

The serving fast path adds a **versioned result cache** on top: every
answered query is memoized under ``(packed version, kind,
canonical-args fingerprint)`` — see :func:`query_fingerprint` — so a
repeated query at the same sealed snapshot is a dict lookup, not a jitted
call. Invalidation is by construction, not by protocol: a mutation can
only land in a LATER sealed version, which is a brand-new key space, so
no entry can ever go stale (the same argument as the replica plane's I10
coherence). A pinned replay keys into its own pinned version's space and
therefore can never observe another version's cache. The outer
per-version dict is GC'd by the same ladder the rank cache uses; the
inner per-version dict is capped (``result_cache_entries``). The engine
also records the jit-trace *signatures* windows actually hit (kind,
static args, pow2-padded source width) so :meth:`SnapshotQueryEngine
.warm_traces` — the publish-time prewarm — can retrace exactly the
shapes real clients use against a new snapshot's edge bucket.

The engine is deliberately snapshot-agnostic — the serving loop
(``launch.serve_graph``) picks WHICH snapshot (always
``ShardedDynamicGraph.latest_sealed()``) and hands the view in. It is
layer 4 of the pipeline mapped in ``docs/ARCHITECTURE.md``; the
:func:`query_touch_vertices` helper is the access-pattern feed for the
re-sharding planner described there.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.versioned import Version
from repro.graph import compute as gc
from repro.graph.dyngraph import JoinView, prune_retired, prune_views
from repro.graph.sharded import ReplicaPlan, replica_route


# ------------------------------------------------------------- query types
@dataclasses.dataclass(frozen=True)
class KHop:
    """Vertices within ``k`` out-hops of ``source`` -> (n,) bool mask."""
    source: int
    k: int


@dataclasses.dataclass(frozen=True)
class Reachability:
    """Is ``dst`` reachable from ``src``? -> bool."""
    src: int
    dst: int
    max_hops: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class DegreeTopK:
    """Top-k vertices by degree -> (ids, degrees) arrays."""
    k: int
    direction: str = "in"


@dataclasses.dataclass(frozen=True)
class PageRankQuery:
    """PageRank ranks -> (n,) array, or (ids, ranks) when ``top_k`` set."""
    top_k: Optional[int] = None


Query = Union[KHop, Reachability, DegreeTopK, PageRankQuery]

_KIND_OF = {KHop: "k_hop", Reachability: "reachability",
            DegreeTopK: "degree_topk", PageRankQuery: "pagerank"}


def query_kind(q) -> Optional[str]:
    """Stable kind tag for a query (``"k_hop"`` / ``"reachability"`` /
    ``"degree_topk"`` / ``"pagerank"``), or None for an object that is not
    a known query type — the admission-time validity check the typed
    request path uses instead of letting an unknown type poison a whole
    execution window."""
    return _KIND_OF.get(type(q))


# ------------------------------------------------- typed request envelope
#
# One envelope shared VERBATIM by the in-process scheduler
# (``launch.serve_graph.GraphQueryServer.submit_request``) and the wire
# path (``launch.rpc`` encodes/decodes exactly these dataclasses): a
# request names its query, an id the caller correlates the answer by, an
# optional snapshot pin and an optional latency budget; a response is
# either an answer (value + the sealed version it was computed at) or a
# typed error. The legacy ``submit()``/``flush()`` surface is a thin shim
# over this envelope.

# error codes a response can carry (stable wire names)
ERR_OVERLOADED = "overloaded"     # admission control shed the request
ERR_DEADLINE = "deadline"         # latency budget expired before execution
ERR_UNSEALED = "unsealed"         # no globally sealed snapshot yet
ERR_BAD_PIN = "bad_pin"           # pinned version not sealed / not served
ERR_BAD_QUERY = "bad_query"       # unknown query kind / malformed fields


@dataclasses.dataclass(frozen=True)
class QueryRequest:
    """One typed query submission.

    ``request_id`` is the caller's correlation token (unique per
    connection on the wire path; auto-assigned on the in-process
    conveniences). ``pin_version`` pins execution to a specific *sealed*
    snapshot instead of the newest one — a pinned replay is how the soak
    tests prove byte-identity, and how a training run stays reproducible.
    ``deadline_s`` is a relative latency budget from submission: a request
    still queued when it expires is answered with an ``ERR_DEADLINE``
    error instead of stale data."""
    query: Query
    request_id: Union[int, str] = 0
    pin_version: Optional[Version] = None
    deadline_s: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class QueryError:
    """Typed failure surface of a :class:`QueryResponse` (never an
    exception string a client has to parse): ``code`` is one of the
    ``ERR_*`` constants, ``message`` is human-readable detail."""
    code: str
    message: str = ""


@dataclasses.dataclass(frozen=True)
class QueryResponse:
    """The answer envelope: exactly one of ``value`` (with the sealed
    ``version`` it was computed at) or ``error`` is meaningful, selected
    by ``ok``. ``latency_s`` is submit-to-answer, server-side.
    ``degraded`` marks an answer served while the write plane cannot
    seal (a shard fault): still correct — computed at the last published
    sealed snapshot, never a partial one — but possibly stale."""
    request_id: Union[int, str]
    ok: bool
    value: object = None
    version: Optional[Version] = None
    latency_s: float = 0.0
    error: Optional[QueryError] = None
    degraded: bool = False

    @classmethod
    def answered(cls, request_id, value, version: Version,
                 latency_s: float,
                 degraded: bool = False) -> "QueryResponse":
        return cls(request_id, True, value=value, version=version,
                   latency_s=latency_s, degraded=degraded)

    @classmethod
    def failed(cls, request_id, code: str, message: str = "",
               latency_s: float = 0.0) -> "QueryResponse":
        return cls(request_id, False, latency_s=latency_s,
                   error=QueryError(code, message))


@dataclasses.dataclass
class QueryResult:
    """One answered query: the query itself, its value, the snapshot
    ``version`` it was answered at, and the submit-to-answer latency."""
    query: Query
    value: object
    version: Version
    latency_s: float = 0.0


def query_fingerprint(q: Query, n: int) -> Optional[tuple]:
    """Canonical cache key for one query at a snapshot with ``n``
    vertices, or None for an unknown query type.

    Canonicalization makes semantically identical argument spellings
    share one entry: a falsy reachability hop bound (``None`` or ``0``)
    means "unbounded" on every execution path, so both spell the same
    key; a degree top-k larger than ``n`` returns all ``n`` vertices, so
    ``k`` clamps to ``n``. The snapshot version is NOT part of this
    fingerprint — the result cache keys the version as the outer dict, so
    sealing an epoch opens a fresh key space (invalidation by
    construction)."""
    if isinstance(q, KHop):
        return ("k_hop", int(q.source), int(q.k))
    if isinstance(q, Reachability):
        return ("reachability", int(q.src), int(q.dst),
                int(q.max_hops or 0))
    if isinstance(q, DegreeTopK):
        return ("degree_topk", min(int(q.k), int(n)), q.direction)
    if isinstance(q, PageRankQuery):
        return ("pagerank",
                None if q.top_k is None else int(q.top_k))
    return None


def query_touch_vertices(queries: Sequence[Query]) -> np.ndarray:
    """Vertex ids a query window touches — the access-pattern feed for the
    re-sharding planner.

    Point-query anchors count (k-hop sources, reachability endpoints);
    whole-graph queries (degree top-k, PageRank) touch every shard evenly
    and would only dilute the imbalance signal, so they contribute
    nothing. The serving layer bins these ids to shards via
    ``ShardedDynamicGraph.record_query_touches``. Returns an int64 array
    (possibly empty). Raises nothing: unknown query types are ignored
    here — ``SnapshotQueryEngine.execute`` is the layer that rejects
    them."""
    touched: list[int] = []
    for q in queries:
        if isinstance(q, KHop):
            touched.append(q.source)
        elif isinstance(q, Reachability):
            touched.append(q.src)
            touched.append(q.dst)
    return np.asarray(touched, np.int64)


@dataclasses.dataclass(frozen=True)
class _SubView:
    """Edge-restricted stand-in for a :class:`JoinView`: exactly the
    surface the batched frontier kernels read (``n``/``m``/``src``/
    ``dst``), holding the routed edge subset instead of the global CSR."""
    n: int
    src: np.ndarray
    dst: np.ndarray

    @property
    def m(self) -> int:
        return len(self.src)


@dataclasses.dataclass(frozen=True)
class RoutedSnapshot:
    """Replica-first routing context for one serving snapshot: the
    snapshot's :class:`~repro.graph.sharded.ReplicaPlan` plus the
    per-shard views it indexes. Built by the serving layer at publish
    (both pieces derive from the SAME sealed version — that pairing is
    the I10 coherence invariant) and handed to
    :meth:`SnapshotQueryEngine.execute`, which ignores it unless its
    version matches the view being queried (pinned replays at other
    versions fall back to the global view)."""
    plan: ReplicaPlan
    shard_views: list[JoinView]


_MISS = object()          # result-cache sentinel (None is a legal value)


def _freeze_result(val: object) -> object:
    """Make a to-be-memoized value safe to hand out by reference. Cache
    hits return the stored object itself, so an in-process caller that
    mutated a returned ndarray would poison every later hit at that
    version; marking arrays read-only (recursing into tuples) turns that
    silent corruption into an immediate ``ValueError`` at the caller."""
    if isinstance(val, np.ndarray):
        val.flags.writeable = False
    elif isinstance(val, tuple):
        for item in val:
            _freeze_result(item)
    return val
# jit-trace signature memory: enough distinct (kind, static-arg, width)
# shapes for a realistic client mix, small enough that prewarm stays a
# few-millisecond background errand
MAX_WARM_SIGNATURES = 64


class SnapshotQueryEngine:
    """Answers query windows against one snapshot view, vectorized.

    ``pagerank_kw`` is forwarded to :func:`compute.pagerank` (damping, tol,
    max_iter); keep it fixed across a serving session so the warm-start
    chain stays meaningful.

    ``result_cache`` enables the versioned result cache (see module
    docs); ``result_cache_entries`` caps the per-version entry count —
    past it, new results are served but not memoized (counted in
    ``result_cache_evictions``), so one version of a high-cardinality
    query stream cannot pin unbounded memory.
    """

    def __init__(self, *, result_cache: bool = True,
                 result_cache_entries: int = 4096, **pagerank_kw):
        self.pagerank_kw = pagerank_kw
        self.result_cache = result_cache
        self.result_cache_entries = result_cache_entries
        self._rank_cache: dict[int, gc.PageRankResult] = {}
        # packed version -> {query fingerprint -> answered value}; the
        # versioned result cache (ladder-GC'd with the rank cache)
        self._result_cache: dict[int, dict[tuple, object]] = {}
        # serving runs queries on one thread while the ingest thread
        # prewarms/GCs the rank cache — this lock is the cache's own, so
        # cache integrity never depends on the server's coarser lock
        self._rank_lock = threading.Lock()
        # telemetry the serving benchmark and tests read — guarded by
        # _rank_lock too: concurrent flushers race on these counters
        self.vectorized_calls = {"k_hop": 0, "reachability": 0,
                                 "degree_topk": 0, "pagerank": 0}
        self.rank_cache_hits = 0
        self.rank_warm_starts = 0
        self.rank_cold_starts = 0
        self.result_cache_hits = 0
        self.result_cache_misses = 0
        self.result_cache_evictions = 0
        # jit-trace signatures real windows hit (insertion-ordered, so
        # overflow drops the stalest) — what warm_traces() replays
        self._warm_signatures: dict[tuple, None] = {}
        # (signature, edge width) pairs already replayed: a signature is
        # only re-run when the snapshot's pow2 edge bucket steps (a new
        # width IS a new trace), so steady-state publishes cost nothing —
        # a replay executes the kernel for real, and burning a core on
        # sweeps whose traces are already warm starves serving on small
        # hosts for zero cache benefit
        self._warmed_traces: set[tuple] = set()
        # replica-plane telemetry (same lock): per frontier vertex, did
        # its adjacency come from a mirror; per routed group, how many
        # shards the frontier closure actually touched
        self.mirror_hits = 0
        self.mirror_misses = 0
        self.routed_windows = 0
        self.fanout_hist: dict[int, int] = {}

    # -- PageRank cache ----------------------------------------------------
    def pagerank(self, view: JoinView) -> gc.PageRankResult:
        """Ranks for ``view``'s version: cached per version; warm-started
        from the nearest older cached version's ranks when one exists.
        Thread-safe: the lock covers only cache reads/writes — the
        iteration itself runs outside it, so a concurrent GC or a
        cache-hit at another version never waits on rank compute. Two
        threads racing on the SAME uncached version may both compute it
        (deterministic result; first insert wins)."""
        key = view.version.pack()
        with self._rank_lock:
            cached = self._rank_cache.get(key)
            if cached is not None:
                self.rank_cache_hits += 1
                return cached
            self.vectorized_calls["pagerank"] += 1
            older = [k for k in self._rank_cache if k < key]
            base = self._rank_cache[max(older)] if older else None
        if base is not None:
            res = gc.incremental_pagerank(base, None, view,
                                          **self.pagerank_kw)
        else:
            res = gc.pagerank(view, **self.pagerank_kw)
        with self._rank_lock:
            if base is not None:
                self.rank_warm_starts += 1
            else:
                self.rank_cold_starts += 1
            return self._rank_cache.setdefault(key, res)

    def gc(self, keep_latest: int = 4, *, retire_below: int = 0) -> int:
        """Ladder-GC the per-version rank cache (same retention policy as
        the join-view caches: a version-spaced ladder, so any past version
        keeps a warm-start base within ~2x its distance from the
        frontier). Returns the number of entries dropped.

        ``retire_below`` (a packed version; the serving layer passes
        ``ShardedDynamicGraph.plan_floor()``) additionally drops every
        entry below it once a newer entry exists: after a re-sharding
        cutover those ranks are keyed by snapshots of a retired routing
        plan and will never be served again — but the newest one is
        retained until the first post-cutover ranks are cached, so the
        warm-start chain crosses the cutover instead of restarting cold.
        Thread-safe (holds the cache lock)."""
        with self._rank_lock:
            dropped = prune_retired(self._rank_cache, retire_below)
            dropped += prune_views(self._rank_cache, keep_latest)
            # the result cache rides the same ladder: whole key spaces
            # (versions) drop at once, entries never drop individually
            evicted = prune_retired(self._result_cache, retire_below)
            evicted += prune_views(self._result_cache, keep_latest)
            self.result_cache_evictions += evicted
            return dropped + evicted

    @property
    def cached_rank_versions(self) -> list[int]:
        with self._rank_lock:
            return sorted(self._rank_cache)

    def result_cache_stats(self) -> dict:
        """Snapshot of the result-cache telemetry (thread-safe):
        hit/miss/eviction counters, live entry count across every cached
        version, and the hit rate over all lookups so far."""
        with self._rank_lock:
            total = self.result_cache_hits + self.result_cache_misses
            return {"hits": self.result_cache_hits,
                    "misses": self.result_cache_misses,
                    "evictions": self.result_cache_evictions,
                    "entries": sum(len(s)
                                   for s in self._result_cache.values()),
                    "hit_rate": self.result_cache_hits / max(total, 1)}

    def has_cached_result(self, version: Version, q: Query,
                          n: Optional[int] = None) -> bool:
        """True when ``q``'s answer at ``version`` is already memoized —
        the serving layer's lane classifier asks this so an expensive-kind
        query that will be a dict lookup can ride the cheap lane. ``n``
        is the snapshot's vertex count (only degree-top-k fingerprints
        clamp on it; omitting it leaves k unclamped). Thread-safe; a
        False answer may race a concurrent insert (the query then just
        executes on the expensive lane, still correct)."""
        fp = query_fingerprint(q, n if n is not None else 1 << 30)
        if fp is None:
            return False
        with self._rank_lock:
            slot = self._result_cache.get(version.pack())
            return slot is not None and fp in slot

    def replica_stats(self) -> dict:
        """Snapshot of the replica-routing telemetry (thread-safe)."""
        with self._rank_lock:
            total = self.mirror_hits + self.mirror_misses
            return {"mirror_hits": self.mirror_hits,
                    "mirror_misses": self.mirror_misses,
                    "mirror_hit_rate": self.mirror_hits / max(total, 1),
                    "routed_windows": self.routed_windows,
                    "fanout_hist": dict(self.fanout_hist)}

    def _route(self, routed: Optional[RoutedSnapshot], view: JoinView,
               anchors: np.ndarray, hops: Optional[int], *,
               record: bool = True) -> Optional[_SubView]:
        """Resolve one same-kind group through the replica plane, or None
        to fall back to the global view. The version check is the
        coherence gate: a RoutedSnapshot only ever speaks for its own
        sealed version, so a pinned replay at another version can never
        be answered from these mirrors."""
        if routed is None or routed.plan.version.pack() != view.version.pack():
            return None
        sub_src, sub_dst, fanout, hits, misses = replica_route(
            routed.plan, routed.shard_views, anchors, hops)
        # pow2-pad the routed subset on the host, with the kernels' own
        # phantom-row convention (src 0 gathers harmlessly, dst ``n`` is
        # the sliced-off segment). Routed edge counts vary per window —
        # handing raw lengths to ``_padded_edges`` would compile its
        # eager pad op once per distinct m; pre-bucketing collapses
        # routed windows onto a few stable shapes, so the replica path
        # keeps its traces warm even while the global CSR drifts
        width = gc.pad_pow2(sub_src.size)
        if width > sub_src.size:
            extra = width - sub_src.size
            sub_src = np.concatenate(
                [sub_src, np.zeros(extra, sub_src.dtype)])
            sub_dst = np.concatenate(
                [sub_dst, np.full(extra, view.n, sub_dst.dtype)])
        if record:
            # prewarm passes record=False: a trace-warming sweep must not
            # pollute the mirror-hit / fan-out telemetry real windows feed
            with self._rank_lock:
                self.mirror_hits += hits
                self.mirror_misses += misses
                self.routed_windows += 1
                self.fanout_hist[fanout] = \
                    self.fanout_hist.get(fanout, 0) + 1
        return _SubView(view.n, sub_src, sub_dst)

    # -- window execution --------------------------------------------------
    def execute(self, view: JoinView, queries: Sequence[Query], *,
                routed: Optional[RoutedSnapshot] = None,
                use_cache: Optional[bool] = None) -> list[object]:
        """Answer a window of queries against ``view`` with one vectorized
        call per (kind, shape) group. Returns values aligned with
        ``queries``.

        With the result cache enabled (``use_cache`` overrides the
        engine-wide default), each query is first looked up under
        ``(view.version, fingerprint)`` — hits skip compute entirely and
        are byte-identical to the value originally computed at this
        version, because they ARE that value (the cached object itself;
        memoized ndarrays are marked read-only, so a caller that tried to
        mutate a hit would fault instead of poisoning the cache). The
        misses execute through the grouped path below and are then
        memoized, subject to the per-version entry cap.

        With ``routed`` (and only when it speaks for ``view``'s exact
        version), the frontier kernels (k-hop, reachability) run on the
        replica-routed edge subset instead of the global CSR — byte-
        identical answers (the subset contains every edge the sweep can
        read), touching only shards that own or mirror the frontier.
        Whole-graph kernels (degree top-k, PageRank) always use the
        global view."""
        cache_on = self.result_cache if use_cache is None else use_cache
        if not cache_on:
            return self._execute_groups(view, queries, routed)
        values: list[object] = [None] * len(queries)
        fps = [query_fingerprint(q, view.n) for q in queries]
        key = view.version.pack()
        misses: list[int] = []
        with self._rank_lock:
            slot = self._result_cache.get(key)
            for i, fp in enumerate(fps):
                hit = (slot.get(fp, _MISS)
                       if slot is not None and fp is not None else _MISS)
                if hit is not _MISS:
                    self.result_cache_hits += 1
                    values[i] = hit
                else:
                    self.result_cache_misses += 1
                    misses.append(i)
        if not misses:
            return values
        computed = self._execute_groups(
            view, [queries[i] for i in misses], routed)
        for i, val in zip(misses, computed, strict=True):
            values[i] = val
        with self._rank_lock:
            slot = self._result_cache.setdefault(key, {})
            for i in misses:
                fp = fps[i]
                if fp is None or fp in slot:
                    continue
                if len(slot) >= self.result_cache_entries:
                    # cap reached: serve but don't memoize (no point
                    # churning entries — a version's key space is
                    # short-lived; the ladder drops it whole)
                    self.result_cache_evictions += 1
                    continue
                slot[fp] = _freeze_result(values[i])
        return values

    def _record_signatures(self, khops, reaches, topks, n: int) -> None:
        """Remember the jit-trace signatures this window hit so a later
        :meth:`warm_traces` can replay them against a new snapshot.
        Insertion-ordered with a cap: overflow drops the stalest."""
        sigs = []
        for k, idxs in khops.items():
            sigs.append(("k_hop", int(k), gc.pad_pow2(len(idxs))))
        for _max_hops, idxs in reaches.items():
            sigs.append(("reachability", gc.pad_pow2(len(idxs))))
        for (k, direction), _idxs in topks.items():
            sigs.append(("degree_topk", min(int(k), n), direction))
        if not sigs:
            return
        with self._rank_lock:
            for sig in sigs:
                self._warm_signatures.pop(sig, None)   # refresh recency
                self._warm_signatures[sig] = None
            while len(self._warm_signatures) > MAX_WARM_SIGNATURES:
                self._warm_signatures.pop(
                    next(iter(self._warm_signatures)))

    def warm_traces(self, view: JoinView,
                    routed: Optional[RoutedSnapshot] = None, *,
                    max_anchors: int = 8) -> int:
        """Publish-time trace prewarm: replay every recorded jit-trace
        signature against ``view`` so the first real query after a seal
        pays a dict-cache hit, not a compile/retrace.

        A live stream grows the snapshot's pow2 edge bucket over time;
        whenever the bucket steps, every batched-kernel trace goes cold
        and the first window at the new bucket pays the retrace. Running
        the recorded signatures here (on the ingest side's background
        prewarm thread, against the freshly published immutable view)
        moves that cost off the query path. With ``routed``, the hottest
        ``max_anchors`` mirrored vertices additionally warm the
        replica-routed buckets (via :meth:`_route` with telemetry
        recording off — prewarm is invisible in the mirror stats).

        Idempotent and safe to race with queries or the next seal: it
        only reads the immutable snapshot and the jit trace caches, and
        touches no result-cache or telemetry state real windows read.
        A ``(signature, edge width)`` pair is replayed at most once —
        the width is the trace key, so replaying a combination that
        already ran would execute a full kernel sweep for a guaranteed
        jit-cache hit; steady-state publishes (no bucket step) are
        therefore near-free. Returns the number of replays executed
        (0 once everything recorded is warm at the current widths)."""
        with self._rank_lock:
            sigs = list(self._warm_signatures)
        hot = None
        if routed is not None \
                and routed.plan.version.pack() == view.version.pack() \
                and routed.plan.n_mirrored:
            hot = np.flatnonzero(routed.plan.mirrored)[:max_anchors] \
                .astype(np.int32)
        m = int(view.src.size)
        warmed = 0

        def fresh(key):
            with self._rank_lock:
                if key in self._warmed_traces:
                    return False
                if len(self._warmed_traces) > 4096:   # distinct widths are
                    self._warmed_traces.clear()       # few; belt and braces
                self._warmed_traces.add(key)
            return True

        for sig in sigs:
            if sig[0] == "k_hop":
                _, k, width = sig
                anchors = np.zeros(width, np.int32)
                if fresh((sig, m)):
                    gc.batched_k_hop(view, anchors, k)
                    warmed += 1
                if hot is not None:
                    sub = self._route(routed, view, hot, k, record=False)
                    if sub is not None and fresh((sig, int(sub.src.size))):
                        gc.batched_k_hop(sub, anchors, k)
                        warmed += 1
            elif sig[0] == "reachability":
                _, width = sig
                anchors = np.zeros(width, np.int32)
                # src == dst, so the while_loop exits on round one: the
                # warm is the trace, not a graph sweep
                if fresh((sig, m)):
                    gc.batched_reachability(view, anchors, anchors, 1)
                    warmed += 1
                if hot is not None:
                    sub = self._route(routed, view, hot, 1, record=False)
                    if sub is not None and fresh((sig, int(sub.src.size))):
                        gc.batched_reachability(sub, anchors, anchors, 1)
                        warmed += 1
            elif sig[0] == "degree_topk":
                _, k, direction = sig
                if fresh((sig, m)):
                    gc.degree_topk(view, k, direction=direction)
                    warmed += 1
        return warmed

    def _execute_groups(self, view: JoinView, queries: Sequence[Query],
                        routed: Optional[RoutedSnapshot]) -> list[object]:
        """The grouped vectorized path under :meth:`execute` (one jitted
        call per (kind, shape) group; no caching at this layer)."""
        values: list[object] = [None] * len(queries)

        khops: dict[int, list[int]] = {}        # k -> query indices
        reaches: dict[Optional[int], list[int]] = {}   # max_hops -> indices
        topks: dict[tuple[int, str], list[int]] = {}
        ranks: list[int] = []
        for i, q in enumerate(queries):
            if isinstance(q, KHop):
                khops.setdefault(q.k, []).append(i)
            elif isinstance(q, Reachability):
                # grouped by hop bound: answering a bounded query with a
                # bigger shared bound could flip False -> True
                reaches.setdefault(q.max_hops, []).append(i)
            elif isinstance(q, DegreeTopK):
                topks.setdefault((q.k, q.direction), []).append(i)
            elif isinstance(q, PageRankQuery):
                ranks.append(i)
            else:
                raise TypeError(f"unknown query type {type(q).__name__}")
        self._record_signatures(khops, reaches, topks, view.n)

        for k, idxs in khops.items():
            sources = np.asarray([queries[i].source for i in idxs], np.int32)
            target = self._route(routed, view, sources, k) or view
            reach = np.asarray(gc.batched_k_hop(target, sources, k))
            with self._rank_lock:
                self.vectorized_calls["k_hop"] += 1
            for row, i in enumerate(idxs):
                values[i] = reach[row]

        for max_hops, idxs in reaches.items():
            srcs = np.asarray([queries[i].src for i in idxs], np.int32)
            dsts = np.asarray([queries[i].dst for i in idxs], np.int32)
            # frontier expansion only ever walks forward from the
            # sources, so they alone anchor the route
            target = self._route(routed, view, srcs, max_hops) or view
            got = np.asarray(gc.batched_reachability(target, srcs, dsts,
                                                     max_hops))
            with self._rank_lock:
                self.vectorized_calls["reachability"] += 1
            for row, i in enumerate(idxs):
                values[i] = bool(got[row])

        for (k, direction), idxs in topks.items():
            ids, degs = gc.degree_topk(view, k, direction=direction)
            with self._rank_lock:
                self.vectorized_calls["degree_topk"] += 1
            pair = (np.asarray(ids), np.asarray(degs))
            for i in idxs:
                values[i] = pair

        if ranks:
            res = self.pagerank(view)
            full = np.asarray(res.ranks)
            for i in ranks:
                top_k = queries[i].top_k
                if top_k is None:
                    values[i] = full
                else:
                    ids = np.argsort(-full, kind="stable")[:top_k]
                    values[i] = (ids, full[ids])

        return values
