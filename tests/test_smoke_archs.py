"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness. The FULL configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_configs, reduced
from repro.launch.steps import (init_train_state, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.models import transformer as tf

ARCHS = sorted(all_configs().keys())


def _batch(cfg, B=2, S=32):
    key = jax.random.PRNGKey(0)
    if cfg.embed_mode == "tokens":
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(all_configs()[arch])
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg)
    B, S = batch["labels"].shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    hidden, aux = tf.forward(params, cfg, batch["inputs"], positions)
    assert hidden.shape == (B, S, cfg.d_model)
    assert jnp.isfinite(hidden.astype(jnp.float32)).all()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = reduced(all_configs()[arch])
    state = init_train_state(cfg, jax.random.PRNGKey(2))
    step = jax.jit(make_train_step(cfg))
    state, metrics = step(state, _batch(cfg))
    assert jnp.isfinite(metrics["loss"])
    assert metrics["loss"] > 0
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = reduced(all_configs()[arch])
    params = tf.init_params(cfg, jax.random.PRNGKey(3))
    B, S = 2, 16
    batch = _batch(cfg, B, S + 1)
    prompt = (batch["inputs"][:, :S] if cfg.embed_mode == "tokens"
              else batch["inputs"][:, :S, :])
    logits, cache = jax.jit(make_prefill_step(cfg))(params, {"inputs": prompt})
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    nxt = (batch["inputs"][:, S:S + 1] if cfg.embed_mode == "tokens"
           else batch["inputs"][:, S:S + 1, :])
    # decode against a capacity-S+8 cache
    cache2 = tf.init_cache(cfg, B, S + 8)
    dlogits, cache2 = jax.jit(make_decode_step(cfg))(params, cache2, nxt, 0)
    assert dlogits.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(dlogits).all()


def test_decode_matches_forward_full_attention():
    """Teacher-forced decode must reproduce the forward logits (qwen-style)."""
    cfg = reduced(all_configs()["qwen2.5-14b"], num_layers=2)
    params = tf.init_params(cfg, jax.random.PRNGKey(4))
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab_size)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    hidden, _ = tf.forward(params, cfg, tokens, positions)
    full_logits = tf.logits_fn(params, cfg, hidden)     # (B,S,V)
    cache = tf.init_cache(cfg, B, S)
    step = jax.jit(make_decode_step(cfg))
    for t in range(S):
        dlogits, cache = step(params, cache, tokens[:, t:t + 1], t)
        assert jnp.allclose(dlogits[:, 0], full_logits[:, t], atol=2e-2), t
