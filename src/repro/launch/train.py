"""Training driver — the training loop IS a protocol-dataflow program.

    ingress (data pipeline views) -> step vertex (jitted train_step)
        -> egress (metrics) + checkpoint vertex (versioned snapshots)

Fault tolerance demonstrated end-to-end: ``--fail-at N`` kills the step
vertex at step N; the driver restores ``snapshot(latest)`` (paper §2.3.1
rule), rebuilds the pipeline at the restored batch index (deterministic
views => no data loss/duplication) and continues. ``--compress`` enables
int8 error-feedback gradient compression.

Usage (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck --fail-at 23
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import all_configs, reduced
from repro.core.protocol_dataflow import Dataflow, Egress, Ingress, Protocol, Vertex
from repro.launch.steps import init_train_state, make_train_step
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import compress_grads, init_error_state
from repro.train.data import TokenPipeline

TRAIN = Protocol("train-loop", validate=lambda m: isinstance(m, tuple))


class SimulatedFailure(RuntimeError):
    pass


def build_step_vertex(cfg, state_box, oc_kw, *, compress=False, fail_at=None):
    step_fn = jax.jit(make_train_step(cfg))
    err_box = {"err": None}

    def fn(vertex, port, payloads):
        outs = []
        for (idx, batch) in payloads:
            if fail_at is not None and idx == fail_at and \
                    not state_box.get("failed_once"):
                state_box["failed_once"] = True
                raise SimulatedFailure(f"injected failure at step {idx}")
            state = state_box["state"]
            if compress:
                # quantize/dequantize grads with error feedback around the
                # (SPMD-implicit) all-reduce
                from repro.launch.steps import loss_fn
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state["params"], cfg, batch)
                if err_box["err"] is None:
                    err_box["err"] = init_error_state(grads)
                grads, err_box["err"], cstats = compress_grads(
                    grads, err_box["err"])
                from repro.train.optimizer import OptConfig, adamw_update
                params, opt, gnorm = adamw_update(
                    OptConfig(), state["params"], grads, state["opt"])
                state = {"params": params, "opt": opt,
                         "step": state["step"] + 1}
                metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                               compress_ratio=cstats["ratio"])
            else:
                state, metrics = step_fn(state, batch)
            state_box["state"] = state
            outs.append(("out", (idx, {k: float(v) for k, v in metrics.items()})))
        return outs

    return Vertex("train_step", TRAIN, fn)


def run(cfg, *, steps, batch, seq, ckpt_dir, ckpt_every=10, fail_at=None,
        compress=False, log_every=10, seed=0):
    pipeline = TokenPipeline(
        cfg.vocab_size, batch, seq, seed=seed,
        frames_dim=cfg.d_model if cfg.embed_mode == "frames" else None)
    state_box = {"state": init_train_state(cfg, jax.random.PRNGKey(seed))}
    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    losses = {}

    df = Dataflow("training")
    ingress = df.add(Ingress("data", TRAIN))
    stepv = df.add(build_step_vertex(cfg, state_box, {}, compress=compress,
                                     fail_at=fail_at))

    def on_metrics(payload):
        idx, metrics = payload
        losses[idx] = metrics["loss"]
        if idx % log_every == 0:
            print(f"  step {idx:4d} loss={metrics['loss']:.4f} "
                  + (f"ratio={metrics.get('compress_ratio', 0):.1f}x"
                     if compress else ""))
        if ckpt and idx and idx % ckpt_every == 0:
            done = int(state_box["state"]["step"])
            ckpt.save(state_box["state"], epoch=0, step=done)

    egress = df.add(Egress("metrics", TRAIN, on_metrics))
    ingress.connect("out", stepv)
    stepv.connect("out", egress)

    i = 0
    while i < steps:
        try:
            ingress.push([(i, pipeline.batch_view(i).value())])
            df.run_until_quiescent()
            i += 1
        except SimulatedFailure as e:
            print(f"  !! {e} — restoring snapshot + replaying")
            if ckpt and ckpt.versions():
                state_box["state"] = ckpt.restore(state_box["state"])
                i = int(state_box["state"]["step"])
            else:
                state_box["state"] = init_train_state(
                    cfg, jax.random.PRNGKey(seed))
                i = 0
    df.deliver_events()
    return losses, state_box["state"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (TPU pods), not the reduced one")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = all_configs()[args.arch]
    if not args.full_size:
        cfg = reduced(cfg)
    print(f"training {cfg.name}: {cfg.param_count():,} params, "
          f"{args.steps} steps of {args.batch}x{args.seq}")
    t0 = time.time()
    losses, state = run(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                        fail_at=args.fail_at, compress=args.compress,
                        seed=args.seed)
    first = np.mean([losses[i] for i in sorted(losses)[:5]])
    last = np.mean([losses[i] for i in sorted(losses)[-5:]])
    print(f"loss {first:.4f} -> {last:.4f} in {time.time()-t0:.1f}s "
          f"({len(losses)} steps)")
    assert last < first, "loss did not improve"


if __name__ == "__main__":
    main()
