"""Pure-jnp oracles for every Pallas kernel (the ground truth the
interpret-mode sweeps assert against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum(values, segment_ids, num_segments):
    """values: (m, F); segment_ids: (m,) sorted; -> (n, F)."""
    return jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B,H,S,hd); k,v: (B,Hkv,S,hd) with H % Hkv == 0. Full softmax
    reference (materializes S x S — test sizes only)."""
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    scale = scale if scale is not None else hd ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, S, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    pos_q = jnp.arange(S)[:, None]
    pos_k = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= (pos_q - pos_k) < window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(B, H, S, hd).astype(q.dtype)


def lru_scan(a, b):
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + b_t over axis 1.
    a, b: (B, S, C) f32. h_0 = b_0 (h_{-1} = 0)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
