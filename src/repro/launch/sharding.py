"""Logical-axis sharding rules (MaxText-style) + param-spec derivation.

The model code annotates activations with *logical* axis names
(``constrain(x, ("batch", "seq", "dmodel"))``); a :class:`ShardingRules`
context maps logical names to mesh axes. Param specs are derived from pytree
paths so the model definition stays sharding-agnostic.

This module is also where the paper's §2.2 *replica-coherence policy* meets
the LM half of the framework: ``repro.core.replica.SharedTensorPolicy``
proposes replicate-vs-shard decisions per tensor; the accepted decisions are
expressed as these rules.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def _current() -> Optional["ShardingRules"]:
    return getattr(_STATE, "rules", None)


class ShardingRules:
    """Maps logical axis names -> mesh axis (or None = replicate)."""

    def __init__(self, mesh, mapping):
        self.mesh = mesh
        self.mapping = dict(mapping)
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))

    def spec(self, logical_axes, dims=None) -> P:
        """Resolve logical axes to a PartitionSpec, dropping non-divisible
        or unmapped axes (replica-coherence fallback: replicate)."""
        out = []
        for i, name in enumerate(logical_axes):
            axis = self.mapping.get(name)
            if axis is None:
                out.append(None)
                continue
            size = (self.axis_sizes[axis] if isinstance(axis, str)
                    else _prod(self.axis_sizes[a] for a in axis))
            if dims is not None and dims[i] % size != 0:
                out.append(None)  # uneven -> replicate this dim
            else:
                out.append(axis)
        return P(*out)

    @contextlib.contextmanager
    def active(self):
        prev = _current()
        _STATE.rules = self
        try:
            yield self
        finally:
            _STATE.rules = prev


def _prod(it):
    r = 1
    for v in it:
        r *= v
    return r


def constrain(x, logical_axes):
    """Apply a sharding constraint if rules are active; no-op otherwise."""
    rules = _current()
    if rules is None:
        return x
    spec = rules.spec(logical_axes, dims=x.shape)
    sharding = jax.sharding.NamedSharding(rules.mesh, spec)
    return jax.lax.with_sharding_constraint(x, sharding)


# --------------------------------------------------------------------------
# Baseline logical->mesh mappings (the "paper-faithful" starting point):
# DP/FSDP over `data` (and `pod` for batch), Megatron TP over `model`.
# --------------------------------------------------------------------------
def baseline_mapping(multi_pod: bool, *, long_context: bool = False,
                     serve: bool = False, expert_sharding: str = "tensor"):
    batch_axes = ("pod", "data") if multi_pod else "data"
    m = {
        "batch": batch_axes,
        "seq": None,
        "dmodel": None,
        "dmodel_w": "data",      # FSDP shard of weight d_model dims
        "ff": "model",
        "qdim": "model",
        "kvdim": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "vocab": "model",
        # MoE: EP over the model axis when E % model == 0 (phi3.5), else TP
        # inside each expert's ffn dims (mixtral).
        "expert": "model" if expert_sharding == "expert" else None,
        "ff_exp": None if expert_sharding == "expert" else "model",
        "lru": "model",
        "inner": "model",        # mLSTM/sLSTM inner projection dim
        "cache_seq": None,
        "cache_batch": batch_axes,
    }
    if long_context:
        # batch=1: context/sequence parallelism over the data axis instead.
        m["cache_batch"] = None
        m["cache_seq"] = "data"
        m["seq"] = "data"
    if serve:
        # Serving has no optimizer state; weights stay TP-sharded and are
        # additionally FSDP-sharded over `data` only to fit HBM (gathered
        # per-layer on use).
        pass
    return m


# --------------------------------------------------------------------------
# Param logical axes by (leaf name, ndim). Stacked scan units prepend a
# "layers" dim which is never sharded.
# --------------------------------------------------------------------------
_PARAM_AXES = {
    ("embed", 2): ("vocab", "dmodel_w"),
    ("lm_head", 2): ("dmodel_w", "vocab"),
    ("wq", 2): ("dmodel_w", "qdim"),
    ("wk", 2): ("dmodel_w", "kvdim"),
    ("wv", 2): ("dmodel_w", "kvdim"),
    ("wo", 2): ("qdim", "dmodel_w"),
    ("bq", 1): ("qdim",),
    ("bk", 1): ("kvdim",),
    ("bv", 1): ("kvdim",),
    ("w1", 2): ("dmodel_w", "ff"),
    ("w3", 2): ("dmodel_w", "ff"),
    ("w2", 2): ("ff", "dmodel_w"),
    ("b1", 1): ("ff",),
    ("b2", 1): (None,),
    ("router", 2): ("dmodel_w", None),
    ("w1", 3): ("expert", "dmodel_w", "ff_exp"),
    ("w3", 3): ("expert", "dmodel_w", "ff_exp"),
    ("w2", 3): ("expert", "ff_exp", "dmodel_w"),
    ("in_x", 2): ("dmodel_w", "lru"),
    ("in_gate", 2): ("dmodel_w", "lru"),
    ("out", 2): ("lru", "dmodel_w"),
    ("w_ig", 1): ("lru",),
    ("b_ig", 1): ("lru",),
    ("w_rg", 1): ("lru",),
    ("b_rg", 1): ("lru",),
    ("a_param", 1): ("lru",),
    ("up", 2): ("dmodel_w", "inner"),
    ("down", 2): ("inner", "dmodel_w"),
    ("w_if", 2): ("inner", None),
    ("b_if", 1): (None,),
    ("head_norm", 1): (None,),
    ("w_gates", 2): ("dmodel_w", "inner"),
    ("r_gates", 3): (None, None, None),
    ("b_gates", 1): (None,),
    ("up1", 2): ("dmodel_w", "inner"),
    ("up2", 2): ("dmodel_w", "inner"),
    ("w", 2): (None, "lru"),        # conv kernels (width, channels)
    ("wq", 3): (None, None, None),  # mLSTM per-head block-diag projections
    ("wk", 3): (None, None, None),
    ("wv", 3): (None, None, None),
}


def _leaf_logical_axes(path, ndim):
    name = None
    stacked = False
    for entry in path:
        key = getattr(entry, "key", None)
        if key == "units":
            stacked = True
        if isinstance(key, str) and key != "units":
            name = key
    # scanned stacks have a leading layer dim; try the right rank first so a
    # stacked 2D weight isn't confused with a native 3D (MoE) weight.
    order = (1, 0) if stacked else (0, 1)
    for extra in order:
        axes = _PARAM_AXES.get((name, ndim - extra))
        if axes is not None:
            return (None,) * extra + tuple(axes)
    return (None,) * ndim  # norms, scalars, unknown -> replicate


def param_specs(params, rules: ShardingRules):
    """PartitionSpec pytree matching ``params``."""
    def leaf_spec(path, leaf):
        axes = _leaf_logical_axes(path, leaf.ndim)
        return rules.spec(axes, dims=leaf.shape)
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def cache_specs(cache, rules: ShardingRules):
    """Specs for decode caches: KV caches (layers,B,Hkv,S,hd) and recurrent
    states (leading layers dim, then batch)."""
    def leaf_spec(path, leaf):
        names = [getattr(e, "key", None) for e in path]
        if "k" in names or "v" in names:
            axes = ("layers", "cache_batch", "kv_heads", "cache_seq", "head_dim")
            axes = axes[-leaf.ndim:]
        else:
            axes = ("layers", "cache_batch") + (None,) * (leaf.ndim - 2)
            axes = axes[:leaf.ndim]
        axes = tuple(a if a not in ("layers",) else None for a in axes)
        spec = rules.spec(axes, dims=leaf.shape)
        # GQA caches with kv_heads < model-axis size: fall back to sharding
        # head_dim over 'model' so big-arch caches still split 16 ways
        if ("k" in names or "v" in names) and leaf.ndim >= 2:
            parts = list(spec)
            try:
                kv_pos = axes.index("kv_heads")
                hd_pos = axes.index("head_dim")
            except ValueError:
                return spec
            model_size = rules.axis_sizes.get("model", 1)
            if (parts[kv_pos] is None and parts[hd_pos] is None
                    and leaf.shape[hd_pos] % model_size == 0
                    and rules.mapping.get("kv_heads") == "model"):
                parts[hd_pos] = "model"
                return P(*parts)
        return spec
    return jax.tree_util.tree_map_with_path(leaf_spec, cache)
