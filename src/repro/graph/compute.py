"""Graph computing on protocol dataflow — paper §2.3.3.2.

The core primitive is **join-group-by**: join each vertex with its neighbors'
values, group by destination, reduce. With the per-snapshot CSR (*join view*)
this is a segment reduction — ``jax.ops.segment_sum`` portably, the Pallas
``segment_sum`` kernel on TPU.

On top of it: PageRank (offline, full) and **incremental PageRank** (online:
warm-start from the previous snapshot's result — the paper's
"adapt to the graph changes first, then reschedule on the entire graph"),
SSSP with *priority scheduling* (the paper's Dijkstra-via-priority-queue
example), WCC, degree/temporal analytics, and online BFS/k-hop queries, all
usable while mutations stream (snapshot isolation via the versioned store).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.versioned import Version
from repro.graph.dyngraph import DynamicGraph, JoinView


# ----------------------------------------------------------- join-group-by
def join_group_by(view: JoinView, values: jnp.ndarray, *, reduce: str = "sum",
                  use_kernel: bool = False) -> jnp.ndarray:
    """For every vertex d: reduce_{(s,d) in E} values[s].

    values: (n,) or (n, F). Returns same feature shape grouped by dst.
    """
    gathered = values[view.src]
    if use_kernel and reduce == "sum":
        from repro.kernels import ops
        if values.ndim == 1:
            # CSR rows are dst-sorted, so the Pallas sorted-segment-sum
            # applies directly; lift to (m, 1) for the MXU formulation
            return ops.segment_sum(gathered[:, None], view.dst, view.n)[:, 0]
        return ops.segment_sum(gathered, view.dst, view.n)
    if reduce == "sum":
        return jax.ops.segment_sum(gathered, view.dst, num_segments=view.n)
    if reduce == "max":
        return jax.ops.segment_max(gathered, view.dst, num_segments=view.n)
    if reduce == "min":
        return jax.ops.segment_min(gathered, view.dst, num_segments=view.n)
    raise ValueError(reduce)


# ------------------------------------------------------------------ PageRank
@dataclasses.dataclass
class PageRankResult:
    ranks: jnp.ndarray
    iterations: int
    residual: float


def pagerank(view: JoinView, *, damping: float = 0.85, tol: float = 1e-6,
             max_iter: int = 100, init: Optional[jnp.ndarray] = None,
             handle_dangling: bool = True,
             use_kernel: bool = False) -> PageRankResult:
    """Offline PageRank on one snapshot; supports warm start (``init``).
    ``handle_dangling`` redistributes sink mass uniformly (sum(pr)==1)."""
    n = view.n
    out_deg = jnp.maximum(view.out_degree, 1.0)
    dangling = view.out_degree == 0
    pr = jnp.full((n,), 1.0 / n) if init is None else init

    def body(carry):
        pr, _, it = carry
        contrib = pr / out_deg
        agg = join_group_by(view, contrib, use_kernel=use_kernel)
        if handle_dangling:
            # dangling-mass redistribution keeps sum(pr) == 1
            dmass = jnp.sum(jnp.where(dangling, pr, 0.0))
            agg = agg + dmass / n
        new = (1.0 - damping) / n + damping * agg
        resid = jnp.abs(new - pr).sum()
        return new, resid, it + 1

    def cond(carry):
        _, resid, it = carry
        return (resid > tol) & (it < max_iter)

    pr, resid, it = jax.lax.while_loop(
        cond, body, (pr, jnp.asarray(jnp.inf), jnp.asarray(0)))
    return PageRankResult(pr, int(it), float(resid))


def incremental_pagerank(old: PageRankResult, old_view: JoinView,
                         new_view: JoinView, **kw) -> PageRankResult:
    """Online path: warm-start from the previous snapshot's ranks. The
    changed region re-converges locally; unchanged regions are already at
    their fixed point, so iterations drop sharply vs cold start."""
    return pagerank(new_view, init=old.ranks, **kw)


# ---------------------------------------------------------------------- SSSP
@dataclasses.dataclass
class SSSPResult:
    dist: jnp.ndarray
    rounds: int
    relaxations: int


def sssp(view: JoinView, source: int, *, weights: Optional[jnp.ndarray] = None,
         priority_fraction: float = 0.0, max_rounds: int = 10_000) -> SSSPResult:
    """Label-correcting SSSP over in-edges (dst pulls from src).

    ``priority_fraction > 0`` enables the paper's application-specific
    scheduling: only frontier vertices whose tentative distance is within the
    smallest ``priority_fraction`` quantile relax their out-edges each round
    (a vectorized Dijkstra/delta-stepping hybrid). Fewer total relaxations at
    the cost of more rounds — exactly the trade the input scheduler exposes.
    """
    n = view.n
    w = weights if weights is not None else jnp.ones((view.m,), jnp.float32)
    inf = jnp.asarray(jnp.inf, jnp.float32)
    dist0 = jnp.full((n,), jnp.inf, jnp.float32).at[source].set(0.0)
    frontier0 = jnp.zeros((n,), bool).at[source].set(True)

    def body(carry):
        dist, frontier, rounds, relax = carry
        if priority_fraction > 0.0:
            fd = jnp.where(frontier, dist, inf)
            k = jnp.maximum(
                1, jnp.int32(priority_fraction * jnp.sum(frontier)))
            kth = jnp.sort(fd)[jnp.minimum(k - 1, n - 1)]
            active = frontier & (dist <= kth)
        else:
            active = frontier
        # relax in-edges whose src is active
        src_d = dist[view.src]
        src_act = active[view.src]
        cand = jnp.where(src_act, src_d + w, inf)
        best = jax.ops.segment_min(cand, view.dst, num_segments=n)
        improved = best < dist
        dist = jnp.where(improved, best, dist)
        frontier = (frontier & ~active) | improved
        return dist, frontier, rounds + 1, relax + jnp.sum(src_act)

    def cond(carry):
        _, frontier, rounds, _ = carry
        return jnp.any(frontier) & (rounds < max_rounds)

    dist, _, rounds, relax = jax.lax.while_loop(
        cond, body, (dist0, frontier0, jnp.asarray(0), jnp.asarray(0)))
    return SSSPResult(dist, int(rounds), int(relax))


# ----------------------------------------------------------------------- WCC
def wcc(view: JoinView, max_rounds: int = 1000) -> jnp.ndarray:
    """Weakly-connected components by min-label propagation (both directions)."""
    n = view.n
    labels0 = jnp.arange(n)

    def body(carry):
        labels, _, it = carry
        fwd = jax.ops.segment_min(labels[view.src], view.dst, num_segments=n)
        bwd = jax.ops.segment_min(labels[view.dst], view.src, num_segments=n)
        new = jnp.minimum(labels, jnp.minimum(fwd, bwd))
        return new, jnp.any(new != labels), it + 1

    def cond(carry):
        _, changed, it = carry
        return changed & (it < max_rounds)

    labels, _, _ = jax.lax.while_loop(
        cond, body, (labels0, jnp.asarray(True), jnp.asarray(0)))
    return labels


# ------------------------------------------------------------ online queries
def k_hop(view: JoinView, sources: jnp.ndarray, k: int) -> jnp.ndarray:
    """Vertices reachable within k hops (out-direction) — online low-latency
    query; runs on a snapshot while mutations stream."""
    n = view.n
    reach = jnp.zeros((n,), bool).at[sources].set(True)
    for _ in range(k):
        # dst reachable if any in-neighbor src reachable
        hop = jax.ops.segment_max(reach[view.src].astype(jnp.int32),
                                  view.dst, num_segments=n) > 0
        reach = reach | hop
    return reach


def reachability(view: JoinView, src: int, dst: int,
                 max_hops: Optional[int] = None) -> bool:
    n = view.n
    max_hops = max_hops or n
    reach = jnp.zeros((n,), bool).at[src].set(True)
    for _ in range(max_hops):
        hop = jax.ops.segment_max(reach[view.src].astype(jnp.int32),
                                  view.dst, num_segments=n) > 0
        new = reach | hop
        if bool(jnp.all(new == reach)) or bool(new[dst]):
            reach = new
            break
        reach = new
    return bool(reach[dst])


# --------------------------------------------- batched/jitted online queries
# Serving entry points: one jitted call answers a whole window of same-kind
# queries. The traced functions are cached by (padded_m, n, S[, k]) shape:
# query sources are padded to a power-of-two width and the snapshot's edge
# list to a power-of-two length (padding rows target a phantom segment ``n``
# that is sliced off inside the kernel), so consecutive snapshots of a live
# stream and windows of varying size hit the jit cache instead of retracing
# per call.

def pad_pow2(size: int, floor: int = 1) -> int:
    """Next power of two >= size (>= floor) — the padding rule the serving
    layer uses to keep batched-query shapes (and so jit traces) stable."""
    return max(floor, 1 << max(0, int(size - 1).bit_length()))


def _padded_edges(view: JoinView,
                  pad_edges: bool) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(src, dst) with the edge list padded to a pow2 length; padded rows
    gather vertex 0 (harmless) and scatter into phantom segment ``n``
    (sliced off). Keeps the jitted query trace stable while a live stream
    grows/shrinks m within the bucket."""
    m = view.m
    if not pad_edges:
        return view.src, view.dst
    width = pad_pow2(m)
    src = jnp.zeros((width,), view.src.dtype).at[:m].set(view.src)
    dst = jnp.full((width,), view.n, view.dst.dtype).at[:m].set(view.dst)
    return src, dst


@functools.partial(jax.jit, static_argnames=("n", "k"))
def _batched_khop(src, dst, reach0, n, k):
    def step(_, reach):
        # num_segments=n+1: the phantom segment swallows padded edges
        hop = jax.ops.segment_max(reach[src].astype(jnp.int32), dst,
                                  num_segments=n + 1)[:n] > 0
        return reach | hop
    return jax.lax.fori_loop(0, k, step, reach0)


def batched_k_hop(view: JoinView, sources: jnp.ndarray, k: int, *,
                  pad_sources: bool = True,
                  pad_edges: bool = True) -> jnp.ndarray:
    """Per-source k-hop reachability for a whole query window at once.

    Unlike :func:`k_hop` (which unions its sources into ONE frontier), this
    answers S independent queries in a single vectorized sweep: returns
    (S, n) bool, row i = vertices within k out-hops of ``sources[i]``.
    Row i equals ``k_hop(view, sources[i:i+1], k)`` bit for bit.
    """
    sources = jnp.asarray(sources).reshape(-1)
    s = int(sources.shape[0])
    if s == 0:
        return jnp.zeros((0, view.n), bool)
    width = pad_pow2(s) if pad_sources else s
    padded = jnp.zeros((width,), sources.dtype).at[:s].set(sources)
    reach0 = jnp.zeros((view.n, width), bool).at[
        padded, jnp.arange(width)].set(True)
    src, dst = _padded_edges(view, pad_edges)
    reach = _batched_khop(src, dst, reach0, view.n, int(k))
    return reach.T[:s]


@functools.partial(jax.jit, static_argnames=("n",))
def _batched_reach(src, dst, reach0, dst_ids, max_hops, n):
    cols = jnp.arange(dst_ids.shape[0])

    def cond(carry):
        reach, changed, it = carry
        found = jnp.all(reach[dst_ids, cols])
        return changed & ~found & (it < max_hops)

    def body(carry):
        reach, _, it = carry
        hop = jax.ops.segment_max(reach[src].astype(jnp.int32), dst,
                                  num_segments=n + 1)[:n] > 0
        new = reach | hop
        return new, jnp.any(new != reach), it + 1

    reach, _, _ = jax.lax.while_loop(
        cond, body, (reach0, jnp.asarray(True), jnp.asarray(0)))
    return reach[dst_ids, cols]


def batched_reachability(view: JoinView, src_ids: jnp.ndarray,
                         dst_ids: jnp.ndarray,
                         max_hops: Optional[int] = None, *,
                         pad_sources: bool = True,
                         pad_edges: bool = True) -> jnp.ndarray:
    """Multi-source frontier reachability: answers S (src -> dst) queries in
    one frontier sweep — the batched counterpart of :func:`reachability`.
    Returns (S,) bool. The shared frontier stops early once every target is
    found or no per-source frontier changed; ``max_hops`` is a traced
    scalar, so varying it never retraces."""
    src_ids = jnp.asarray(src_ids).reshape(-1)
    dst_ids = jnp.asarray(dst_ids).reshape(-1)
    if src_ids.shape != dst_ids.shape:
        raise ValueError("src_ids and dst_ids must have the same length")
    s = int(src_ids.shape[0])
    if s == 0:
        return jnp.zeros((0,), bool)
    width = pad_pow2(s) if pad_sources else s
    psrc = jnp.zeros((width,), src_ids.dtype).at[:s].set(src_ids)
    pdst = jnp.zeros((width,), dst_ids.dtype).at[:s].set(dst_ids)
    reach0 = jnp.zeros((view.n, width), bool).at[
        psrc, jnp.arange(width)].set(True)
    # falsy max_hops (None or 0) means unbounded — same promotion the
    # scalar reachability() applies, so the two entry points agree
    hops = jnp.asarray(max_hops or view.n)
    src, dst = _padded_edges(view, pad_edges)
    return _batched_reach(src, dst, reach0, pdst, hops, view.n)[:s]


@functools.partial(jax.jit, static_argnames=("k",))
def _topk(deg, k):
    return jax.lax.top_k(deg, k)


def degree_topk(view: JoinView, k: int, *,
                direction: str = "in") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k vertices by in/out-degree on one snapshot — (ids, degrees),
    degrees descending (ties by lowest vertex id, matching a stable sort on
    (-degree, id)). ``k`` larger than n returns all n vertices."""
    if direction not in ("in", "out"):
        raise ValueError(direction)
    deg = view.in_degree if direction == "in" else view.out_degree
    vals, ids = _topk(deg, min(int(k), view.n))
    return ids, vals


# --------------------------------------------------------- temporal analytics
def degree_timeline(g: DynamicGraph, versions: list[Version],
                    use_kernel: bool = False) -> np.ndarray:
    """(T, n) in-degree per snapshot — 'who makes the most friends this
    month?' is an argmax over a diff of this. ``use_kernel`` resolves the
    snapshot masks through the Pallas ``snapshot_resolve`` kernel."""
    out = []
    for v in versions:
        view = g.join_view(v, use_kernel=use_kernel)
        out.append(np.asarray(view.in_degree))
    return np.stack(out)


def pagerank_timeline(g: DynamicGraph, versions: list[Version],
                      incremental: bool = True, use_kernel: bool = False,
                      **kw) -> list[PageRankResult]:
    """PageRank over an evolving sequence of snapshots; incremental mode
    warm-starts each epoch from the previous one (paper stage-4 temporal
    mining). ``use_kernel`` routes both the snapshot resolve and the
    segment reductions through the Pallas kernels."""
    results: list[PageRankResult] = []
    prev: Optional[PageRankResult] = None
    prev_view: Optional[JoinView] = None
    for v in versions:
        view = g.join_view(v, use_kernel=use_kernel)
        if incremental and prev is not None:
            res = incremental_pagerank(prev, prev_view, view,
                                       use_kernel=use_kernel, **kw)
        else:
            res = pagerank(view, use_kernel=use_kernel, **kw)
        results.append(res)
        prev, prev_view = res, view
    return results


def emerging_vertices(g: DynamicGraph, v_old: Version, v_new: Version,
                      top_k: int = 10) -> np.ndarray:
    """Temporal pattern: vertices with the largest in-degree growth between
    two snapshots ('who made the most friends this month?')."""
    d_old = np.asarray(g.join_view(v_old).in_degree)
    d_new = np.asarray(g.join_view(v_new).in_degree)
    growth = d_new - d_old
    return np.argsort(-growth)[:top_k]
