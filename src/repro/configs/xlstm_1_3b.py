"""xLSTM-1.3B [arXiv:2405.04517]: 48 blocks, alternating mLSTM / sLSTM,
d_model=2048, 4 heads, no external FFN (d_ff=0; blocks carry their own
projections), vocab 50304. Sub-quadratic => runs long_500k."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=("mlstm", "slstm"),
    ffn="none",
    norm="ln",
    rope=False,
    pos_emb="none",
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    conv_width=4,
    subquadratic=True,
))
