"""Distributed graph execution — partitioning + replica-coherence mirrors.

The paper's data manager adjusts partitions and replicas from access
patterns. TPU adaptation (DESIGN.md §2): partitions are SPMD shards over the
``data`` mesh axis (``shard_map``), and "replicas" become either
  * **all-gather mode** — every partition replicates all vertex values per
    superstep (maximal replication: cheapest compute, highest traffic), or
  * **scatter mode** — edge-to-src-partition placement with per-partition
    partial aggregates merged by ``psum_scatter`` (no replication), or
  * **hub-mirror mode** — the replica-coherence policy: only high-degree
    ("hub") vertex values are mirrored everywhere (Trinity's hub buffering /
    PowerGraph vertex-cut insight); the tail uses the scatter path.

Access statistics that drive the hub set are exactly the out-degrees (how
often a vertex's value is read by other partitions), i.e. the paper's
"predictive model of the data access pattern".

``comm_model()`` reports the per-superstep bytes each mode moves so the
benchmark (and tests) can verify the policy's decision analytically — on the
1-CPU container the collectives run but don't cross real links.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.graph.dyngraph import JoinView


@dataclasses.dataclass
class PartitionedGraph:
    n: int                      # padded global vertex count (divisible by P)
    n_parts: int
    # edges grouped by SOURCE partition, padded to uniform length
    src: jnp.ndarray            # (P, m_pad) global src ids
    dst: jnp.ndarray            # (P, m_pad) global dst ids
    mask: jnp.ndarray           # (P, m_pad) validity
    out_degree: jnp.ndarray     # (n,)
    hubs: jnp.ndarray           # (k,) global ids of mirrored hub vertices
    is_hub: jnp.ndarray         # (n,) bool
    # "src": contiguous src-range placement (edge values local at scatter
    # time — all modes valid). "dst_hash": pre-sharded by destination hash
    # (the ShardedDynamicGraph layout — allgather mode only).
    placement: str = "src"

    @property
    def n_local(self) -> int:
        return self.n // self.n_parts


def partition_graph(view: JoinView, n_parts: int, *, hub_k: int = 0,
                    pad_to: int | None = None) -> PartitionedGraph:
    """Contiguous-range vertex partitioning; edges placed at their source's
    partition (values are local at scatter time)."""
    n = ((view.n + n_parts - 1) // n_parts) * n_parts
    n_local = n // n_parts
    src = np.asarray(view.src)
    dst = np.asarray(view.dst)
    part_of = src // n_local
    m_pad = pad_to or max(1, int(np.bincount(part_of, minlength=n_parts).max()))
    ps = np.zeros((n_parts, m_pad), np.int32)
    pd = np.zeros((n_parts, m_pad), np.int32)
    pm = np.zeros((n_parts, m_pad), bool)
    for p in range(n_parts):
        idx = np.flatnonzero(part_of == p)[:m_pad]
        ps[p, :len(idx)] = src[idx]
        pd[p, :len(idx)] = dst[idx]
        pm[p, :len(idx)] = True
    deg = np.zeros(n, np.float32)
    deg[:view.n] = np.asarray(view.out_degree)
    hubs = np.argsort(-deg)[:hub_k].astype(np.int32) if hub_k else \
        np.zeros(0, np.int32)
    is_hub = np.zeros(n, bool)
    is_hub[hubs] = True
    return PartitionedGraph(n, n_parts, jnp.asarray(ps), jnp.asarray(pd),
                            jnp.asarray(pm), jnp.asarray(deg),
                            jnp.asarray(hubs), jnp.asarray(is_hub))


def partition_graph_sharded(shard_views, *, hub_k: int = 0,
                            pad_to: int | None = None,
                            placement: str = "dst_hash") -> PartitionedGraph:
    """Build a PartitionedGraph from pre-sharded per-shard join views
    (``ShardedDynamicGraph.shard_views``).

    ``placement="dst_hash"`` (default) is the zero-copy fast path: each
    shard's rows ARE its partition's rows, so construction is one padded
    copy per shard — but only the ``allgather`` compute mode is valid
    (partial aggregates merge by ``psum_scatter`` regardless of edge
    placement). ``placement="src"`` re-buckets the concatenated shard
    rows by source range in one vectorized grouping pass (no O(P·m)
    mask-and-gather like ``partition_graph``), making every edge's source
    value local to its partition — which is what unlocks the
    ``scatter``/``hub`` modes of ``distributed_join_group_by``, i.e. lets
    hub-mirror placement compose with the sharded store's views.
    """
    if not shard_views:
        raise ValueError("no shard views")
    if placement not in ("dst_hash", "src"):
        raise ValueError(f"unknown placement {placement!r}")
    n_parts = len(shard_views)
    n = ((shard_views[0].n + n_parts - 1) // n_parts) * n_parts
    deg = np.zeros(n, np.float32)
    for view in shard_views:
        deg[:view.n] += view.np_out_deg
    if placement == "src":
        n_local = n // n_parts
        src = np.concatenate([v.np_src for v in shard_views])
        dst = np.concatenate([v.np_dst for v in shard_views])
        part_of = src // n_local
        order = np.argsort(part_of, kind="stable")
        counts = np.bincount(part_of, minlength=n_parts)
        widest = max(1, int(counts.max()))
        m_pad = pad_to or widest
        if m_pad < widest:
            raise ValueError(
                f"pad_to={m_pad} would silently drop edges (widest "
                f"partition has {widest}); pass pad_to >= {widest}")
        ps = np.zeros((n_parts, m_pad), np.int32)
        pd = np.zeros((n_parts, m_pad), np.int32)
        pm = np.zeros((n_parts, m_pad), bool)
        bounds = np.r_[0, np.cumsum(counts)]
        for p in range(n_parts):
            rows = order[bounds[p]:bounds[p + 1]]
            ps[p, :len(rows)] = src[rows]
            pd[p, :len(rows)] = dst[rows]
            pm[p, :len(rows)] = True
    else:
        widest = max(v.m for v in shard_views)
        m_pad = pad_to or max(1, widest)
        if m_pad < widest:
            raise ValueError(
                f"pad_to={m_pad} would silently drop edges (widest shard "
                f"has {widest}); pass pad_to >= {widest}")
        ps = np.zeros((n_parts, m_pad), np.int32)
        pd = np.zeros((n_parts, m_pad), np.int32)
        pm = np.zeros((n_parts, m_pad), bool)
        for p, view in enumerate(shard_views):
            m = view.m
            ps[p, :m] = view.np_src
            pd[p, :m] = view.np_dst
            pm[p, :m] = True
    hubs = np.argsort(-deg)[:hub_k].astype(np.int32) if hub_k else \
        np.zeros(0, np.int32)
    is_hub = np.zeros(n, bool)
    is_hub[hubs] = True
    return PartitionedGraph(n, n_parts, jnp.asarray(ps), jnp.asarray(pd),
                            jnp.asarray(pm), jnp.asarray(deg),
                            jnp.asarray(hubs), jnp.asarray(is_hub),
                            placement=placement)


def _local_partials(src, dst, mask, values_full, n, exclude_hubs=None):
    contrib = values_full[src] * mask
    if exclude_hubs is not None:
        contrib = contrib * (~exclude_hubs[src])
    return jax.ops.segment_sum(contrib, dst, num_segments=n)


def distributed_join_group_by(pg: PartitionedGraph, values: jnp.ndarray,
                              mesh, *, mode: str = "scatter") -> jnp.ndarray:
    """values: (n,) globally sharded over 'data' as (P, n_local) rows.
    Returns the aggregate, sharded the same way."""
    n, nl = pg.n, pg.n_local
    if pg.placement != "src" and mode in ("scatter", "hub"):
        raise ValueError(
            f"mode {mode!r} needs src-placed edges (local values at scatter "
            f"time); this graph is {pg.placement!r}-placed — use 'allgather'")
    values = values.reshape(pg.n_parts, nl)

    if mode == "allgather":
        def fn(vals_l, src, dst, mask):
            vals = jax.lax.all_gather(vals_l[0], "data", tiled=True)  # (n,)
            part = _local_partials(src[0], dst[0], mask[0], vals, n)
            # edges live at src partitions; results must still merge by dst
            out = jax.lax.psum_scatter(part, "data", tiled=True)
            return out[None]
    elif mode == "scatter":
        def fn(vals_l, src, dst, mask):
            # local values only: every edge's src IS local to this shard
            vals = jnp.zeros((n,), values.dtype)
            idx = jax.lax.axis_index("data")
            vals = jax.lax.dynamic_update_slice(vals, vals_l[0], (idx * nl,))
            part = _local_partials(src[0], dst[0], mask[0], vals, n)
            out = jax.lax.psum_scatter(part, "data", tiled=True)
            return out[None]
    elif mode == "hub":
        def fn(vals_l, src, dst, mask):
            idx = jax.lax.axis_index("data")
            vals = jnp.zeros((n,), values.dtype)
            vals = jax.lax.dynamic_update_slice(vals, vals_l[0], (idx * nl,))
            # mirror ONLY hub values everywhere (small all-gather)
            hub_vals_l = vals_l[0][jnp.clip(pg.hubs - idx * nl, 0, nl - 1)]
            hub_vals_l = hub_vals_l * ((pg.hubs >= idx * nl)
                                       & (pg.hubs < (idx + 1) * nl))
            hub_vals = jax.lax.psum(hub_vals_l, "data")     # (k,) replicated
            vals = vals.at[pg.hubs].set(hub_vals)
            part = _local_partials(src[0], dst[0], mask[0], vals, n)
            out = jax.lax.psum_scatter(part, "data", tiled=True)
            return out[None]
    else:
        raise ValueError(mode)

    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data"), P("data")),
        out_specs=P("data"))
    out = mapped(values, pg.src, pg.dst, pg.mask)
    return out.reshape(n)


def comm_model(pg: PartitionedGraph, *, bytes_per_value: int = 4) -> dict:
    """Per-superstep bytes moved per device, by mode (ring collectives).
    This is the access-pattern model the replica-coherence policy consults."""
    p = pg.n_parts
    n = pg.n
    k = int(pg.hubs.shape[0])
    ag = (p - 1) / p * n * bytes_per_value          # all-gather values
    ps = (p - 1) / p * n * bytes_per_value          # psum-scatter partials
    return {
        "allgather": ag + ps,
        "scatter": ps,
        "hub": ps + 2 * (p - 1) / p * k * bytes_per_value,
        "n": n, "parts": p, "hubs": k,
    }
