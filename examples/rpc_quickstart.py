"""RPC serving-tier quickstart: a real server process, real socket clients.

Launches ``python -m repro.launch.serve_graph --rpc-port 0`` as a
subprocess (its own process, its own GIL), parses the ephemeral port off
the one ``RPC listening on host:port`` line it prints, then drives it
with N concurrent ``GraphRPCClient`` threads while the server is still
ingesting its synthetic stream in the background — queries are answered
at the newest *sealed* epoch while the next epoch's applies run
concurrently (the epoch-pipelined read plane; ``docs/ARCHITECTURE.md``
section 6 has the lock-split argument).

Each client issues typed k-hop / reachability / degree-top-k queries and
checks the typed ``QueryResponse`` envelope; one client additionally
re-asks an answered query pinned to the version the first answer was
served at and verifies the replay is byte-identical — the wire codec
ships ndarrays as raw dtype+shape+bytes precisely so this holds across
the socket. Closing the subprocess's stdin is the shutdown signal.

    PYTHONPATH=src python examples/rpc_quickstart.py
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import threading

import numpy as np

from repro.core.versioned import Version
from repro.graph.query import DegreeTopK, KHop, Reachability
from repro.launch.rpc import GraphRPCClient

N_CLIENTS = 4
QUERIES_PER_CLIENT = 12
N_VERTICES = 800


def serve_subprocess() -> subprocess.Popen:
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve_graph",
         "--rpc-port", "0", "--vertices", str(N_VERTICES),
         "--epochs", "6", "--adds-per-epoch", "600",
         "--shards", "2", "--ingest-delay-s", "0.05"],
        stdin=subprocess.PIPE, stdout=subprocess.PIPE, text=True, env=env)


def parse_address(proc: subprocess.Popen) -> tuple[str, int]:
    line = proc.stdout.readline()
    m = re.match(r"RPC listening on (\S+):(\d+)", line)
    if not m:
        raise RuntimeError(f"server did not announce a port: {line!r}")
    return m.group(1), int(m.group(2))


def client_worker(host: str, port: int, seed: int,
                  out: list[str]) -> None:
    rng = np.random.default_rng(seed)
    ok = shed = 0
    with GraphRPCClient(host, port) as cli:
        for i in range(QUERIES_PER_CLIENT):
            kind = i % 3
            if kind == 0:
                q = KHop(source=int(rng.integers(N_VERTICES)), k=2)
            elif kind == 1:
                q = Reachability(src=int(rng.integers(N_VERTICES)),
                                 dst=int(rng.integers(N_VERTICES)),
                                 max_hops=6)
            else:
                q = DegreeTopK(k=8)
            r = cli.query(q, deadline_s=30.0)
            if not r.ok:
                shed += 1          # typed shed (overload/deadline), not a crash
                continue
            ok += 1
            if kind == 0 and ok == 1:
                # replay the same query pinned to the version it was just
                # answered at: byte-identical even though newer epochs may
                # have sealed in between
                pinned = cli.query(q, pin_version=r.version)
                assert pinned.ok and np.array_equal(
                    np.asarray(pinned.value), np.asarray(r.value)), \
                    "pinned replay diverged from the live answer"
                out.append(f"client {seed}: pinned replay at "
                           f"epoch {r.version.epoch} is byte-identical")
    out.append(f"client {seed}: {ok} answered, {shed} shed (typed)")


def main() -> None:
    proc = serve_subprocess()
    try:
        host, port = parse_address(proc)
        print(f"server subprocess up at {host}:{port}")
        lines: list[str] = []
        threads = [threading.Thread(target=client_worker,
                                    args=(host, port, seed, lines))
                   for seed in range(N_CLIENTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for line in sorted(lines):
            print(f"  {line}")
        with GraphRPCClient(host, port) as cli:
            s = cli.stats()
        serving = (Version.unpack(s["serving_version"])
                   if s["serving_version"] is not None else None)
        print(f"server: {s['served']} served over {s['windows']} windows "
              f"(cross-client batching collapses same-kind queries), "
              f"serving {serving}, shed {s['shed_overload']} overload / "
              f"{s['shed_deadline']} deadline")
    finally:
        proc.stdin.close()        # the shutdown signal
        proc.wait(timeout=30)
    print("OK: concurrent RPC clients served during live ingest")


if __name__ == "__main__":
    main()
