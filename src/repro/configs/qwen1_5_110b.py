"""Qwen1.5-110B [hf:Qwen/Qwen1.5 family]: 80L, d_model=8192, 64 heads GQA kv=8,
d_ff=49152, vocab 152064, QKV bias, RoPE theta 1e6, SwiGLU, RMSNorm."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    pattern=("attn",),
    ffn="swiglu",
    norm="rms",
    qkv_bias=True,
    rope=True,
    rope_theta=1_000_000.0,
    subquadratic=False,
))
