"""Chunked-vocab cross entropy.

Never materializes the full (B·S, V) logits: tokens are processed in chunks
(scan) and each chunk is rematerialized in the backward pass
(``jax.checkpoint``), bounding peak memory at (chunk, V). This is the memory
trick that keeps the 262k-vocab gemma3 train cell inside 16 GB/chip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.nn.layers import dense


def chunked_cross_entropy(lm_head, hidden, labels, *, chunk=2048,
                          softcap=0.0):
    """hidden: (B,S,D); labels: (B,S) int32, -1 = ignore.
    Returns (sum_loss, token_count)."""
    B, S, D = hidden.shape
    T = B * S
    h = hidden.reshape(T, D)
    y = labels.reshape(T)
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        y = jnp.pad(y, (0, pad), constant_values=-1)
    n = (T + pad) // chunk
    h = h.reshape(n, chunk, D)
    y = y.reshape(n, chunk)

    @jax.checkpoint
    def chunk_loss(hc, yc):
        logits = dense(hc, lm_head).astype(jnp.float32)     # (chunk, V)
        logits = constrain(logits, ("batch", "vocab"))
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        yc_safe = jnp.maximum(yc, 0)
        ll = jnp.take_along_axis(logits, yc_safe[:, None], axis=-1)[:, 0]
        mask = (yc >= 0).astype(jnp.float32)
        return jnp.sum((lse - ll) * mask), jnp.sum(mask)

    def body(carry, xs):
        loss, cnt = carry
        l, c = chunk_loss(*xs)
        return (loss + l, cnt + c), None

    (loss, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (h, y))
    return loss, cnt
